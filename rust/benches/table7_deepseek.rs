//! Regenerates the paper's table7 (see eval::tablegen::table7 for the
//! workload and protocol). harness=false: criterion is not vendored.

fn main() {
    let t0 = std::time::Instant::now();
    let table = resmoe::eval::tablegen::table7();
    table.print();
    table.save_json("table7_deepseek");
    eprintln!("(table7_deepseek generated in {:.1}s)", t0.elapsed().as_secs_f64());
}
