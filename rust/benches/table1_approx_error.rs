//! Regenerates the paper's table1 (see eval::tablegen::table1 for the
//! workload and protocol). harness=false: criterion is not vendored.

fn main() {
    let t0 = std::time::Instant::now();
    let table = resmoe::eval::tablegen::table1();
    table.print();
    table.save_json("table1_approx_error");
    eprintln!("(table1_approx_error generated in {:.1}s)", t0.elapsed().as_secs_f64());
}
