//! Regenerates Figure 4: accuracy vs compression rate sweep on the
//! LAMBADA analog (eval::tablegen::fig4).

fn main() {
    let t0 = std::time::Instant::now();
    let fast = std::env::args().any(|a| a == "--fast") || std::env::var("RESMOE_FAST").is_ok();
    let rates: &[f64] = if fast {
        &[0.10, 0.25, 0.50]
    } else {
        &[0.10, 0.15, 0.20, 0.25, 0.30, 0.40, 0.50]
    };
    let table = resmoe::eval::tablegen::fig4(rates);
    table.print();
    table.save_json("fig4_sweep");
    eprintln!("(fig4_sweep generated in {:.1}s)", t0.elapsed().as_secs_f64());
}
