//! Regenerates the paper's table11 (see eval::tablegen::table11 for the
//! workload and protocol). harness=false: criterion is not vendored.

fn main() {
    let t0 = std::time::Instant::now();
    let table = resmoe::eval::tablegen::table11();
    table.print();
    table.save_json("table11_runtime");
    eprintln!("(table11_runtime generated in {:.1}s)", t0.elapsed().as_secs_f64());
}
