//! Regenerates the paper's table5 (see eval::tablegen::table5 for the
//! workload and protocol). harness=false: criterion is not vendored.

fn main() {
    let t0 = std::time::Instant::now();
    let table = resmoe::eval::tablegen::table5();
    table.print();
    table.save_json("table5_scale16");
    eprintln!("(table5_scale16 generated in {:.1}s)", t0.elapsed().as_secs_f64());
}
