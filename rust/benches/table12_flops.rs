//! Regenerates the paper's table12 (see eval::tablegen::table12 for the
//! workload and protocol). harness=false: criterion is not vendored.

fn main() {
    let t0 = std::time::Instant::now();
    let table = resmoe::eval::tablegen::table12();
    table.print();
    table.save_json("table12_flops");
    eprintln!("(table12_flops generated in {:.1}s)", t0.elapsed().as_secs_f64());
}
