//! Ablation (paper §6 future work): per-layer ADAPTIVE compression rates vs
//! the uniform protocol, at the same global parameter budget.

use resmoe::compress::{adaptive, ResMoE};
use resmoe::eval::{perplexity, tablegen, Assets};
use resmoe::moe::ModelConfig;
use resmoe::util::bench::Table;
use resmoe::Rng;

fn main() {
    let assets = Assets::load(&ModelConfig::mixtral_mini());
    let n = tablegen::bench_n(150);
    let lam = assets.lambada(n);
    let mut t = Table::new(
        "Ablation — uniform vs adaptive per-layer rates (ResMoE-UP, 25 % budget)",
        &["allocation", "mean layer err", "params kept", "PPL", "LAMBADA (ACC)"],
    );
    let top = 5;
    // Uniform.
    let uni = tablegen::compress_with(&assets, "resmoe-up", 0.25, 0);
    t.row(vec![
        "uniform 25 %".into(),
        format!("{:.4}", uni.report.mean_approx_error()),
        format!("{}", uni.report.total_params_after()),
        format!("{:.3}", perplexity(&uni.model, &assets.valid, 128)),
        format!("{:.2}", resmoe::eval::lambada_accuracy(&uni.model, &lam) * 100.0),
    ]);
    // Adaptive.
    let mut rng = Rng::new(0);
    let ada = adaptive::compress_model_with_budget(&assets.model, &ResMoE::up(), 0.25, top, None, &mut rng);
    let rates: Vec<String> = ada
        .report
        .layers
        .iter()
        .map(|l| format!("L{}:{:.0}%", l.block, 100.0 * l.params_after as f64 / l.params_before as f64))
        .collect();
    t.row(vec![
        format!("adaptive ({})", rates.join(" ")),
        format!("{:.4}", ada.report.mean_approx_error()),
        format!("{}", ada.report.total_params_after()),
        format!("{:.3}", perplexity(&ada.model, &assets.valid, 128)),
        format!("{:.2}", resmoe::eval::lambada_accuracy(&ada.model, &lam) * 100.0),
    ]);
    t.print();
    t.save_json("ablation_adaptive");
}
