//! Regenerates the paper's table3 (see eval::tablegen::table3 for the
//! workload and protocol). harness=false: criterion is not vendored.

fn main() {
    let t0 = std::time::Instant::now();
    let table = resmoe::eval::tablegen::table3();
    table.print();
    table.save_json("table3_nlg");
    eprintln!("(table3_nlg generated in {:.1}s)", t0.elapsed().as_secs_f64());
}
