//! Regenerates the paper's table4 (see eval::tablegen::table4 for the
//! workload and protocol). harness=false: criterion is not vendored.

fn main() {
    let t0 = std::time::Instant::now();
    let table = resmoe::eval::tablegen::table4();
    table.print();
    table.save_json("table4_ablation");
    eprintln!("(table4_ablation generated in {:.1}s)", t0.elapsed().as_secs_f64());
}
