//! Regenerates the paper's table2 (see eval::tablegen::table2 for the
//! workload and protocol). harness=false: criterion is not vendored.

fn main() {
    let t0 = std::time::Instant::now();
    let table = resmoe::eval::tablegen::table2();
    table.print();
    table.save_json("table2_nlu");
    eprintln!("(table2_nlu generated in {:.1}s)", t0.elapsed().as_secs_f64());
}
