//! Regenerates the paper's table10 (see eval::tablegen::table10 for the
//! workload and protocol). harness=false: criterion is not vendored.

fn main() {
    let t0 = std::time::Instant::now();
    let table = resmoe::eval::tablegen::table10();
    table.print();
    table.save_json("table10_memory");
    eprintln!("(table10_memory generated in {:.1}s)", t0.elapsed().as_secs_f64());
}
