//! §Perf micro-benchmarks over the L3 hot paths: matmul kernels, the
//! barycenter solver (ResMoE's joint solve vs OT-Fusion's layer-by-layer
//! procedure — the paper's §5.5/B.2 ">4 days vs <1 day" claim in relative
//! time), expert restoration, the restore cache, and end-to-end engine
//! scoring. Results feed EXPERIMENTS.md §Perf.

use resmoe::baselines::OtFusion;
use resmoe::compress::{compress_model, CompressCtx, Compressor, ResMoE};
use resmoe::coordinator::{Engine, ExpertCache, Request};
use resmoe::moe::{ExpertArch, Model, ModelConfig, MoeLayer};
use resmoe::tensor::Matrix;
use resmoe::util::bench::{BenchRunner, Table};
use resmoe::Rng;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast") || std::env::var("RESMOE_FAST").is_ok();
    let iters = if fast { 3 } else { 10 };
    let mut runner = BenchRunner::new();
    let mut rng = Rng::new(0);

    // --- L3 matmul substrate (sizes from the mixtral-mini hot path).
    let a = Matrix::randn(64, 64, 1.0, &mut rng);
    let b = Matrix::randn(64, 224, 1.0, &mut rng);
    runner.run("matmul 64x64 @ 64x224 (expert up-proj)", 3, iters * 10, || {
        std::hint::black_box(a.matmul(&b));
    });
    // §Perf before/after in one run: the serial-dot reference kernel vs the
    // 4-column-blocked matmul_nt used on the expert-forward hot path.
    let wt = Matrix::randn(224, 64, 1.0, &mut rng); // expert W1 [pI, p]
    let xs = Matrix::randn(96, 64, 1.0, &mut rng); // 96-token batch
    runner.run("matmul_nt NAIVE  96x64 @ (224x64)^T", 3, iters * 10, || {
        std::hint::black_box(xs.matmul_nt_naive(&wt));
    });
    runner.run("matmul_nt 4-col  96x64 @ (224x64)^T", 3, iters * 10, || {
        std::hint::black_box(xs.matmul_nt(&wt));
    });
    let big_a = Matrix::randn(512, 256, 1.0, &mut rng);
    let big_b = Matrix::randn(256, 512, 1.0, &mut rng);
    runner.run("matmul 512x256 @ 256x512 (parallel path)", 2, iters, || {
        std::hint::black_box(big_a.matmul(&big_b));
    });

    // --- barycenter: ResMoE joint solve vs OT-Fusion layer-by-layer.
    let layer = MoeLayer::random(ExpertArch::SwiGlu, 64, 224, 8, 2, true, false, &mut rng);
    runner.run("ResMoE(UP) compress one mixtral-mini layer", 1, iters.min(5), || {
        let mut r = Rng::new(1);
        let mut ctx = CompressCtx::new(0.25, &mut r);
        std::hint::black_box(ResMoE::up().compress(&layer, &mut ctx));
    });
    runner.run("OT-Fusion merge one mixtral-mini layer", 1, iters.min(5), || {
        let mut r = Rng::new(1);
        let mut ctx = CompressCtx::new(0.25, &mut r);
        std::hint::black_box(OtFusion.compress(&layer, &mut ctx));
    });

    // --- restoration (Alg. 2 hot path).
    let cl = {
        let mut r = Rng::new(2);
        let mut ctx = CompressCtx::new(0.25, &mut r);
        ResMoE::up().compress(&layer, &mut ctx)
    };
    runner.run("restore one expert (W_w + sparse residual)", 3, iters * 10, || {
        std::hint::black_box(cl.restore_expert(3));
    });
    let cl_svd = {
        let mut r = Rng::new(2);
        let mut ctx = CompressCtx::new(0.25, &mut r);
        ResMoE::svd().compress(&layer, &mut ctx)
    };
    runner.run("restore one expert (W_w + low-rank residual)", 3, iters * 10, || {
        std::hint::black_box(cl_svd.restore_expert(3));
    });

    // --- cache under thrash vs warm.
    let expert_bytes = layer.experts[0].n_params() * 4;
    runner.run("cache get (warm, hit)", 1, iters * 10, || {
        let mut cache = ExpertCache::new(vec![(0, cl.clone())], usize::MAX);
        cache.get(0, 0);
        for _ in 0..100 {
            std::hint::black_box(cache.get(0, 0));
        }
    });
    runner.run("cache get (thrash, budget=1 expert)", 1, iters.min(5), || {
        let mut cache = ExpertCache::new(vec![(0, cl.clone())], expert_bytes);
        for i in 0..20 {
            std::hint::black_box(cache.get(0, i % 8));
        }
    });

    // --- end-to-end engine scoring.
    let cfg = ModelConfig::mixtral_mini();
    let mut mrng = Rng::new(3);
    let model = Model::random(&cfg, &mut mrng);
    let cm = compress_model(&model, &ResMoE::up(), 0.25, 4, None, &mut mrng);
    let engine = Engine::compressed(model.clone(), cm.layers, usize::MAX);
    let tokens: Vec<u32> = (0..96).map(|i| (i * 7 % 256) as u32).collect();
    runner.run("engine score 96 tokens (cached restore path)", 1, iters.min(5), || {
        std::hint::black_box(engine.handle(&Request::Score { tokens: tokens.clone() }));
    });
    let dense_engine = Engine::dense(model);
    runner.run("engine score 96 tokens (dense baseline)", 1, iters.min(5), || {
        std::hint::black_box(dense_engine.handle(&Request::Score { tokens: tokens.clone() }));
    });

    // Summarize as a table for the reports directory.
    let mut t = Table::new("Perf hot-path microbenches", &["bench", "mean (ms)", "p50 (ms)", "p99 (ms)"]);
    for r in &runner.results {
        t.row(vec![
            r.name.clone(),
            format!("{:.3}", r.mean_ms()),
            format!("{:.3}", r.p50_ms()),
            format!("{:.3}", r.p99_ms()),
        ]);
    }
    t.print();
    t.save_json("perf_hotpath");
}
