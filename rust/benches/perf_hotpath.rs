//! §Perf micro-benchmarks over the L3 hot paths: matmul kernels, the
//! barycenter solver (ResMoE's joint solve vs OT-Fusion's layer-by-layer
//! procedure — the paper's §5.5/B.2 ">4 days vs <1 day" claim in relative
//! time), expert restoration vs the fused restore-free forward, the
//! SpMM-vs-dense crossover as a function of residual density, the restore
//! cache, and end-to-end engine scoring at warm/thrashed cache budgets.
//! Results feed EXPERIMENTS.md §Perf and are persisted as `reports/
//! BENCH_*.json` so successive PRs track a trajectory.

use resmoe::baselines::OtFusion;
use resmoe::compress::{compress_model, CompressCtx, Compressor, ResMoE};
use resmoe::coordinator::{Engine, ExpertCache, Request};
use resmoe::moe::model_io::{load_model, save_model_compressed};
use resmoe::moe::{ExpertArch, Model, ModelConfig, MoeLayer};
use resmoe::store::{pack_compressed_model, quantize_layer, ExpertStore};
use resmoe::tensor::kernel::{kernel_kind, kernel_label, matmul_nt_into_with, KernelKind};
use resmoe::tensor::matrix::matmul_nt_into;
use resmoe::tensor::{sparse::IndexWidth, Csr, Matrix, QuantMatrix};
use resmoe::coordinator::Response;
use resmoe::util::bench::{BenchRunner, Table};
use resmoe::util::stats::percentile;
use resmoe::Rng;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast") || std::env::var("RESMOE_FAST").is_ok();
    let iters = if fast { 3 } else { 10 };
    let mut runner = BenchRunner::new();
    let mut rng = Rng::new(0);

    // --- L3 matmul substrate (sizes from the mixtral-mini hot path).
    let a = Matrix::randn(64, 64, 1.0, &mut rng);
    let b = Matrix::randn(64, 224, 1.0, &mut rng);
    runner.run("matmul 64x64 @ 64x224 (expert up-proj)", 3, iters * 10, || {
        std::hint::black_box(a.matmul(&b));
    });
    // §Perf before/after in one run: the scalar twin vs the runtime kernel
    // (AVX2+FMA where the CPU has it) through the forced-kind entry points
    // — both kernels measured in ONE process, unlike the env-pinned
    // dispatch. (The old serial-dot naive reference is #[cfg(test)]-only
    // now; the scalar twin is the production baseline.)
    let wt = Matrix::randn(224, 64, 1.0, &mut rng); // expert W1 [pI, p]
    let xs = Matrix::randn(96, 64, 1.0, &mut rng); // 96-token batch
    let mut nt_out = Matrix::zeros(96, 224);
    runner.run("matmul_nt SCALAR 96x64 @ (224x64)^T", 3, iters * 10, || {
        matmul_nt_into_with(KernelKind::Scalar, &xs, &wt, &mut nt_out, false);
        std::hint::black_box(&nt_out);
    });
    runner.run("matmul_nt ACTIVE 96x64 @ (224x64)^T", 3, iters * 10, || {
        matmul_nt_into_with(kernel_kind(), &xs, &wt, &mut nt_out, false);
        std::hint::black_box(&nt_out);
    });
    let big_a = Matrix::randn(512, 256, 1.0, &mut rng);
    let big_b = Matrix::randn(256, 512, 1.0, &mut rng);
    runner.run("matmul 512x256 @ 256x512 (parallel path)", 2, iters, || {
        std::hint::black_box(big_a.matmul(&big_b));
    });

    // --- barycenter: ResMoE joint solve vs OT-Fusion layer-by-layer.
    let layer = MoeLayer::random(ExpertArch::SwiGlu, 64, 224, 8, 2, true, false, &mut rng);
    runner.run("ResMoE(UP) compress one mixtral-mini layer", 1, iters.min(5), || {
        let mut r = Rng::new(1);
        let mut ctx = CompressCtx::new(0.25, &mut r);
        std::hint::black_box(ResMoE::up().compress(&layer, &mut ctx));
    });
    runner.run("OT-Fusion merge one mixtral-mini layer", 1, iters.min(5), || {
        let mut r = Rng::new(1);
        let mut ctx = CompressCtx::new(0.25, &mut r);
        std::hint::black_box(OtFusion.compress(&layer, &mut ctx));
    });

    // --- restoration (Alg. 2 hot path).
    let cl = {
        let mut r = Rng::new(2);
        let mut ctx = CompressCtx::new(0.25, &mut r);
        ResMoE::up().compress(&layer, &mut ctx)
    };
    runner.run("restore one expert (W_w + sparse residual)", 3, iters * 10, || {
        std::hint::black_box(cl.restore_expert(3));
    });
    let cl_svd = {
        let mut r = Rng::new(2);
        let mut ctx = CompressCtx::new(0.25, &mut r);
        ResMoE::svd().compress(&layer, &mut ctx)
    };
    runner.run("restore one expert (W_w + low-rank residual)", 3, iters * 10, || {
        std::hint::black_box(cl_svd.restore_expert(3));
    });

    // --- fused restore-free forward vs the restore-then-dense miss path.
    // Same work as a cache miss serving a 96-token sub-batch.
    let xs96 = Matrix::randn(96, 64, 1.0, &mut rng);
    runner.run("miss fwd 96 tok: restore+dense (UP)", 2, iters * 5, || {
        let e = cl.restore_expert(3);
        std::hint::black_box(e.forward(&xs96));
    });
    let fused = cl.fused().expect("resmoe layer has a center");
    runner.run("miss fwd 96 tok: fused (UP, incl shared)", 2, iters * 5, || {
        let sh = fused.shared_act(&xs96);
        std::hint::black_box(fused.forward_slot(3, &xs96, &sh));
    });
    // Shared term amortized over all 8 experts of the layer (the per-batch
    // serving shape: one SharedAct, eight corrections).
    runner.run("miss fwd 96 tok x8 experts: restore+dense (UP)", 1, iters.min(5), || {
        for slot in 0..8 {
            let e = cl.restore_expert(slot);
            std::hint::black_box(e.forward(&xs96));
        }
    });
    runner.run("miss fwd 96 tok x8 experts: fused (UP)", 1, iters.min(5), || {
        let sh = fused.shared_act(&xs96);
        for slot in 0..8 {
            std::hint::black_box(fused.forward_slot(slot, &xs96, &sh));
        }
    });
    let fused_svd = cl_svd.fused().expect("resmoe layer has a center");
    runner.run("miss fwd 96 tok: restore+dense (SVD)", 2, iters * 5, || {
        let e = cl_svd.restore_expert(3);
        std::hint::black_box(e.forward(&xs96));
    });
    runner.run("miss fwd 96 tok: fused (SVD, incl shared)", 2, iters * 5, || {
        let sh = fused_svd.shared_act(&xs96);
        std::hint::black_box(fused_svd.forward_slot(3, &xs96, &sh));
    });

    // --- SpMM vs dense sweep over residual density (B=96, Δ1 is 224x64).
    let mut spmm_table = Table::new(
        "SpMM vs dense by residual density (out += x @ D^T, x 96x64, D 224x64)",
        &["density", "dense (ms)", "spmm (ms)", "speedup"],
    );
    for density in [0.05, 0.15, 0.25] {
        let mut drng = Rng::new(42);
        let delta = Matrix::from_fn(224, 64, |_, _| {
            if drng.uniform() < density {
                drng.normal()
            } else {
                0.0
            }
        });
        let csr = Csr::from_dense(&delta, IndexWidth::narrowest_for(delta.cols));
        // Symmetric comparison: both kernels write (non-accumulating) into
        // the same preallocated buffer — neither pays allocation.
        let mut out = Matrix::zeros(96, 224);
        runner.run(&format!("spmm sweep dense d={density}"), 2, iters * 5, || {
            matmul_nt_into(&xs96, &delta, &mut out, false);
            std::hint::black_box(&out);
        });
        let dense_ms = runner.results.last().unwrap().mean_ms();
        runner.run(&format!("spmm sweep csr   d={density}"), 2, iters * 5, || {
            csr.matmul_nt_into(&xs96, &mut out, false);
            std::hint::black_box(&out);
        });
        let spmm_ms = runner.results.last().unwrap().mean_ms();
        spmm_table.row(vec![
            format!("{density:.2}"),
            format!("{dense_ms:.4}"),
            format!("{spmm_ms:.4}"),
            format!("{:.2}x", dense_ms / spmm_ms.max(1e-9)),
        ]);
    }

    // --- cache under thrash vs warm.
    let expert_bytes = layer.experts[0].n_params() * 4;
    runner.run("cache get (warm, hit)", 1, iters * 10, || {
        let cache = ExpertCache::new(vec![(0, cl.clone())], usize::MAX);
        cache.try_get(0, 0).unwrap();
        for _ in 0..100 {
            std::hint::black_box(cache.try_get(0, 0).unwrap());
        }
    });
    runner.run("cache get (thrash, budget=1 expert)", 1, iters.min(5), || {
        let cache = ExpertCache::new(vec![(0, cl.clone())], expert_bytes);
        for i in 0..20 {
            std::hint::black_box(cache.try_get(0, i % 8).unwrap());
        }
    });

    // --- end-to-end engine scoring: warm cache, thrashed cache with the
    // seed's restore-on-every-miss policy, and thrashed cache with the
    // fused restore-free policy (the acceptance comparison).
    let cfg = ModelConfig::mixtral_mini();
    let mut mrng = Rng::new(3);
    let model = Model::random(&cfg, &mut mrng);
    let cm = compress_model(&model, &ResMoE::up(), 0.25, 4, None, &mut mrng);
    let engine = Engine::compressed(model.clone(), cm.layers.clone(), usize::MAX);
    let tokens: Vec<u32> = (0..96).map(|i| (i * 7 % 256) as u32).collect();
    runner.run("engine score 96 tokens (warm cache)", 1, iters.min(5), || {
        std::hint::black_box(engine.handle(&Request::Score { tokens: tokens.clone() }));
    });
    let warm_serve_ms = runner.results.last().unwrap().mean_ms();
    // Thrash: budget below ONE restored expert, so every lookup misses.
    let thrash_budget = expert_bytes / 2;
    let engine_restore = Engine::compressed(model.clone(), cm.layers.clone(), thrash_budget);
    engine_restore.set_fused(false);
    runner.run("engine score 96 tokens (thrashed, restore)", 1, iters.min(5), || {
        std::hint::black_box(engine_restore.handle(&Request::Score { tokens: tokens.clone() }));
    });
    let engine_fused = Engine::compressed(model.clone(), cm.layers.clone(), thrash_budget);
    runner.run("engine score 96 tokens (thrashed, fused)", 1, iters.min(5), || {
        std::hint::black_box(engine_fused.handle(&Request::Score { tokens: tokens.clone() }));
    });
    let thrash_serve_ms = runner.results.last().unwrap().mean_ms();
    if let Some(m) = engine_fused.cache_metrics() {
        eprintln!(
            "  thrashed-fused decisions: {} fused / {} restored ({} misses)",
            m.fused_serves, m.restore_serves, m.misses
        );
    }
    let dense_engine = Engine::dense(model.clone());
    runner.run("engine score 96 tokens (dense baseline)", 1, iters.min(5), || {
        std::hint::black_box(dense_engine.handle(&Request::Score { tokens: tokens.clone() }));
    });

    // --- cold start: monolithic RMWZ load vs demand-paged RMES artifact.
    // Both serve the same compressed model. The monolithic path must read +
    // entropy-decode the WHOLE restored model before the first token; the
    // packed path opens the index, loads backbone + skeletons, and pages in
    // only the expert shards the first request routes to.
    let cold_dir = std::env::temp_dir().join("resmoe-bench-coldstart");
    std::fs::create_dir_all(&cold_dir).ok();
    let rmwz = cold_dir.join("cold.rmwz");
    let rmes = cold_dir.join("cold.rmes");
    save_model_compressed(&cm.model, &rmwz, 3).expect("write rmwz");
    pack_compressed_model(&model, &cm.layers, 0.25, &rmes).expect("pack rmes");
    let first_tokens: Vec<u32> = (0..16).map(|i| (i * 11 % 256) as u32).collect();
    let paged_budget = {
        let store = ExpertStore::open(&rmes).unwrap();
        (store.total_expert_raw_bytes() / 4) as usize // well below full residency
    };
    runner.run("cold start: RMWZ load + dense engine + 16-tok score", 1, iters.min(5), || {
        let m = load_model(&rmwz).expect("load rmwz");
        let e = Engine::dense(m);
        std::hint::black_box(e.handle(&Request::Score { tokens: first_tokens.clone() }));
    });
    let mono_cold_ms = runner.results.last().unwrap().mean_ms();
    runner.run("cold start: RMES open + paged engine + 16-tok score", 1, iters.min(5), || {
        let mut e = Engine::from_store(&rmes, paged_budget).expect("open rmes");
        e.disable_prefetch(); // measure pure demand paging
        std::hint::black_box(e.handle(&Request::Score { tokens: first_tokens.clone() }));
    });
    let paged_cold_ms = runner.results.last().unwrap().mean_ms();
    // Peak resident expert bytes after first token, each path.
    let mono_resident = cm.report.total_bytes_before(); // every dense expert in RAM
    let (paged_resident, paged_read, artifact_bytes) = {
        let mut e = Engine::from_store(&rmes, paged_budget).unwrap();
        e.disable_prefetch();
        e.handle(&Request::Score { tokens: first_tokens.clone() });
        let (skel, dense, paged) = e.resident_breakdown().unwrap();
        let store = e.backing_store().unwrap();
        (skel + dense + paged, store.bytes_read(), store.file_bytes())
    };
    let mut cold_table = Table::new(
        "Cold start: monolithic RMWZ vs demand-paged RMES (mixtral-mini, 4 compressed layers)",
        &["path", "artifact (bytes)", "open+first-score (ms)", "resident expert bytes", "bytes read"],
    );
    cold_table.row(vec![
        "monolithic RMWZ".into(),
        format!("{}", std::fs::metadata(&rmwz).map(|m| m.len()).unwrap_or(0)),
        format!("{mono_cold_ms:.3}"),
        format!("{mono_resident}"),
        "full file".into(),
    ]);
    cold_table.row(vec![
        "demand-paged RMES".into(),
        format!("{artifact_bytes}"),
        format!("{paged_cold_ms:.3}"),
        format!("{paged_resident}"),
        format!("{paged_read}"),
    ]);

    // --- multi-worker serving: p50/p99 serve latency + aggregate tok/s at
    // 1/2/4/8 workers over the demand-paged engine, under a cold-start
    // roomy budget (every miss restores) and a thrash budget (every miss is
    // a paged fused serve) — each against a serialized baseline that wraps
    // every request in one global mutex, i.e. the old "all heavy work under
    // the cache lock" collapse the concurrent cache removes. Every cell
    // opens a FRESH engine so the cold window is actually measured.
    let conc_reqs = if fast { 6 } else { 24 };
    let mut conc_table = Table::new(
        "Concurrent serving: budget x workers, concurrent vs serialized baseline (24-tok scores, cold engine per cell)",
        &["mode", "budget", "workers", "p50 (ms)", "p99 (ms)", "tok/s"],
    );
    let thrash_mode_budget = expert_bytes / 2;
    for &(mode, serialize) in &[("concurrent", false), ("serialized", true)] {
        for &(bname, budget) in &[("cold+roomy", usize::MAX), ("thrash", thrash_mode_budget)] {
            for &workers in &[1usize, 2, 4, 8] {
                let (p50, p99, toks) =
                    concurrent_serve_stats(&rmes, budget, workers, conc_reqs, serialize);
                conc_table.row(vec![
                    mode.into(),
                    bname.into(),
                    format!("{workers}"),
                    format!("{p50:.3}"),
                    format!("{p99:.3}"),
                    format!("{toks:.0}"),
                ]);
            }
        }
    }

    // --- continuous batching: one batched window vs per-request serving
    // at 1/2/4/8 concurrent clients, over a warm monolithic engine and a
    // cold demand-paged engine. Outputs are bit-identical (the
    // differential tests pin that); this measures what the fused window
    // buys — one routing pass, one SharedAct, and per-expert matmuls over
    // the concatenated rows.
    let batch_iters = if fast { 3 } else { 10 };
    let mut batch_table = Table::new(
        "Continuous batching: batched window vs per-request serving (24-tok scores)",
        &["mode", "clients", "unbatched (ms)", "batched (ms)", "speedup", "rows/dispatch"],
    );
    for &clients in &[1usize, 2, 4, 8] {
        let reqs: Vec<Request> = (0..clients)
            .map(|c| Request::Score {
                tokens: (0..24).map(|t| ((t * 7 + c * 13 + 1) % 256) as u32).collect(),
            })
            .collect();
        // Warm monolithic: budget-resident experts, steady-state windows.
        let warm = Engine::compressed(model.clone(), cm.layers.clone(), usize::MAX);
        for r in &reqs {
            warm.handle(r); // warm the cache once
        }
        runner.run(&format!("warm unbatched x{clients}"), 2, batch_iters, || {
            for r in &reqs {
                std::hint::black_box(warm.handle(r));
            }
        });
        let warm_unbatched_ms = runner.results.last().unwrap().mean_ms();
        runner.run(&format!("warm batched   x{clients}"), 2, batch_iters, || {
            std::hint::black_box(warm.handle_batch(&reqs));
        });
        let warm_batched_ms = runner.results.last().unwrap().mean_ms();
        let rows_per_dispatch = warm.batch_metrics().mean_rows_per_dispatch();
        batch_table.row(vec![
            "warm".into(),
            format!("{clients}"),
            format!("{warm_unbatched_ms:.3}"),
            format!("{warm_batched_ms:.3}"),
            format!("{:.2}x", warm_unbatched_ms / warm_batched_ms.max(1e-9)),
            format!("{rows_per_dispatch:.2}"),
        ]);
        // Cold + demand-paged: every iteration opens a fresh engine, so
        // the window also collapses the first-touch materializations.
        runner.run(&format!("cold+paged unbatched x{clients}"), 1, batch_iters.min(5), || {
            let mut e = Engine::from_store(&rmes, paged_budget).expect("open rmes");
            e.disable_prefetch();
            for r in &reqs {
                std::hint::black_box(e.handle(r));
            }
        });
        let cold_unbatched_ms = runner.results.last().unwrap().mean_ms();
        let mut cold_rows = 0.0f64;
        runner.run(&format!("cold+paged batched   x{clients}"), 1, batch_iters.min(5), || {
            let mut e = Engine::from_store(&rmes, paged_budget).expect("open rmes");
            e.disable_prefetch();
            std::hint::black_box(e.handle_batch(&reqs));
            cold_rows = e.batch_metrics().mean_rows_per_dispatch();
        });
        let cold_batched_ms = runner.results.last().unwrap().mean_ms();
        batch_table.row(vec![
            "cold+paged".into(),
            format!("{clients}"),
            format!("{cold_unbatched_ms:.3}"),
            format!("{cold_batched_ms:.3}"),
            format!("{:.2}x", cold_unbatched_ms / cold_batched_ms.max(1e-9)),
            format!("{cold_rows:.2}"),
        ]);
    }

    // --- SIMD kernel sweep → BENCH_simd.json: the scalar twin vs the
    // runtime kernel through the forced-kind entry points, in GFLOP/s, over
    // expert-shaped GEMMs and the CSR SpMM density grid; plus the warm /
    // thrash serve mix in tok/s under the ACTIVE kernel (the engine's kind
    // is env-pinned per process — run the bench again with RESMOE_SIMD=0 to
    // fill in the scalar serve rows; see EXPERIMENTS.md §Kernels).
    let mut simd_table = Table::new(
        &format!("SIMD kernel sweep (runtime kernel: {})", kernel_label()),
        &["bench", "config", "metric", "scalar", "simd", "speedup"],
    );
    let time_best = |f: &mut dyn FnMut()| -> f64 {
        // Best-of-3 wall time for `reps` scaled to the workload inside f.
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    for &(label, m, n, k) in &[
        ("up-proj prefill", 96usize, 224usize, 64usize),
        ("down-proj prefill", 96, 64, 224),
        ("up-proj 8-tok", 8, 224, 64),
        ("decode 1-tok", 1, 224, 64),
        ("lm_head 96-tok", 96, 256, 64),
        ("square 256", 256, 256, 256),
    ] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let bt = Matrix::randn(n, k, 1.0, &mut rng);
        let mut out = Matrix::zeros(m, n);
        let flops = 2.0 * (m * n * k) as f64;
        let reps = ((5e7 / flops) as usize).clamp(1, 2000) * if fast { 1 } else { 4 };
        let mut gflops = |kind: KernelKind| -> f64 {
            let secs = time_best(&mut || {
                for _ in 0..reps {
                    matmul_nt_into_with(kind, &a, &bt, &mut out, false);
                    std::hint::black_box(&out);
                }
            });
            flops * reps as f64 / secs / 1e9
        };
        let scalar = gflops(KernelKind::Scalar);
        let simd = gflops(kernel_kind());
        simd_table.row(vec![
            "gemm_nt".into(),
            format!("{label} {m}x{k}@({n}x{k})^T"),
            "GFLOP/s".into(),
            format!("{scalar:.2}"),
            format!("{simd:.2}"),
            format!("{:.2}x", simd / scalar.max(1e-9)),
        ]);
    }
    for &density in &[0.05f64, 0.25, 0.5] {
        let mut drng = Rng::new(7);
        let delta = Matrix::from_fn(224, 64, |_, _| {
            if drng.uniform() < density {
                drng.normal()
            } else {
                0.0
            }
        });
        let csr = Csr::from_dense(&delta, IndexWidth::narrowest_for(delta.cols));
        let x96 = Matrix::randn(96, 64, 1.0, &mut rng);
        let h96 = Matrix::randn(96, 224, 1.0, &mut rng);
        let flops_nt = 2.0 * (96 * csr.nnz()) as f64;
        let reps = ((5e7 / flops_nt.max(1.0)) as usize).clamp(1, 2000);
        for &(op, is_nt) in &[("spmm_nt", true), ("spmm_acc", false)] {
            let mut out_nt = Matrix::zeros(96, 224);
            let mut out_acc = Matrix::zeros(96, 64);
            let mut gflops = |kind: KernelKind| -> f64 {
                let secs = time_best(&mut || {
                    for _ in 0..reps {
                        if is_nt {
                            csr.matmul_nt_into_with(kind, &x96, &mut out_nt, false);
                            std::hint::black_box(&out_nt);
                        } else {
                            out_acc.data.fill(0.0);
                            csr.matmul_acc_into_with(kind, &h96, &mut out_acc);
                            std::hint::black_box(&out_acc);
                        }
                    }
                });
                flops_nt * reps as f64 / secs / 1e9
            };
            let scalar = gflops(KernelKind::Scalar);
            let simd = gflops(kernel_kind());
            simd_table.row(vec![
                op.to_string(),
                format!("d={density} 96x64 x (224x64 csr)"),
                "GFLOP/s (eff)".into(),
                format!("{scalar:.2}"),
                format!("{simd:.2}"),
                format!("{:.2}x", simd / scalar.max(1e-9)),
            ]);
        }
    }
    // Serve mix under the active kernel (per-process pin): tok/s at a warm
    // budget and under thrash with the fused policy.
    for (cfg_label, ms) in [("warm 96-tok score", warm_serve_ms), ("thrash+fused 96-tok score", thrash_serve_ms)] {
        simd_table.row(vec![
            "serve".into(),
            cfg_label.into(),
            format!("tok/s ({})", kernel_label()),
            "-".into(),
            format!("{:.0}", 96.0 / (ms / 1e3).max(1e-9)),
            "-".into(),
        ]);
    }

    // --- int8 quant sweep → BENCH_quant.json: f32 vs int8
    // dequant-then-GEMM (materialize the f32 matrix each call, then the
    // plain kernel) vs int8 dequant-fused (one kernel pass over codes +
    // scales), under the scalar twin and the runtime kernel. Two rows per
    // shape x kernel: GFLOP/s, and shard GB/s — the bytes actually
    // streamed from the resident representation (f32: n·k·4; int8:
    // n·k + n·4), which is the serving-side win. Then the quantized
    // artifact's warm/thrash 96-tok serve against the f32 artifact
    // (tok/s + resident expert bytes). EXPERIMENTS.md §Quantization.
    let mut quant_table = Table::new(
        &format!("Int8 quant sweep (runtime kernel: {})", kernel_label()),
        &["bench", "config", "metric", "f32", "int8 dq->gemm", "int8 fused", "fused/dq"],
    );
    for &(label, m, n, k) in &[
        ("up-proj prefill", 96usize, 224usize, 64usize),
        ("down-proj prefill", 96, 64, 224),
        ("up-proj 8-tok", 8, 224, 64),
        ("decode 1-tok", 1, 224, 64),
        ("lm_head 96-tok", 96, 256, 64),
        ("square 256", 256, 256, 256),
    ] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let bt = Matrix::randn(n, k, 1.0, &mut rng);
        let q = QuantMatrix::quantize(&bt);
        let dq0 = q.to_dense(); // the f32 baseline serves the SAME values
        let mut out = Matrix::zeros(m, n);
        let flops = 2.0 * (m * n * k) as f64;
        let reps = ((5e7 / flops) as usize).clamp(1, 2000) * if fast { 1 } else { 4 };
        for &(kname, kind) in &[("scalar", KernelKind::Scalar), (kernel_label(), kernel_kind())] {
            let secs_f32 = time_best(&mut || {
                for _ in 0..reps {
                    matmul_nt_into_with(kind, &a, &dq0, &mut out, false);
                    std::hint::black_box(&out);
                }
            });
            let secs_dq = time_best(&mut || {
                for _ in 0..reps {
                    let dq = q.to_dense();
                    matmul_nt_into_with(kind, &a, &dq, &mut out, false);
                    std::hint::black_box(&out);
                }
            });
            let secs_fused = time_best(&mut || {
                for _ in 0..reps {
                    q.matmul_nt_into_with(kind, &a, &mut out, false);
                    std::hint::black_box(&out);
                }
            });
            let gf = |s: f64| flops * reps as f64 / s.max(1e-12) / 1e9;
            quant_table.row(vec![
                "gemm_nt".into(),
                format!("{label} {m}x{k}@({n}x{k})^T {kname}"),
                "GFLOP/s".into(),
                format!("{:.2}", gf(secs_f32)),
                format!("{:.2}", gf(secs_dq)),
                format!("{:.2}", gf(secs_fused)),
                format!("{:.2}x", secs_dq / secs_fused.max(1e-12)),
            ]);
            let gbs = |bytes: usize, s: f64| bytes as f64 * reps as f64 / s.max(1e-12) / 1e9;
            let q_bytes = n * k + n * 4;
            quant_table.row(vec![
                "gemm_nt".into(),
                format!("{label} {m}x{k}@({n}x{k})^T {kname}"),
                "shard GB/s".into(),
                format!("{:.2}", gbs(n * k * 4, secs_f32)),
                format!("{:.2}", gbs(q_bytes, secs_dq)),
                format!("{:.2}", gbs(q_bytes, secs_fused)),
                "-".into(),
            ]);
        }
    }
    // Quantized artifact serve mix: same model packed with int8 residual
    // shards, served warm (roomy budget) and thrashed, vs the f32 RMES.
    // Resident bytes count skeletons + restored dense + paged shards after
    // the first scored batch; the quantized column is the int8 tier's
    // footprint win while the cost model keeps cold shards paged.
    let rmes_q8 = cold_dir.join("cold-q8.rmes");
    let qlayers: Vec<_> = cm.layers.iter().map(|(b, l)| (*b, quantize_layer(l))).collect();
    pack_compressed_model(&model, &qlayers, 0.25, &rmes_q8).expect("pack q8 rmes");
    for &(bname, budget) in &[("warm", usize::MAX), ("thrash", thrash_budget)] {
        let mut cells: Vec<(f64, usize)> = Vec::new();
        for path in [&rmes, &rmes_q8] {
            let mut e = Engine::from_store(path, budget).expect("open rmes");
            e.disable_prefetch();
            e.handle(&Request::Score { tokens: tokens.clone() }); // page in
            let secs = time_best(&mut || {
                std::hint::black_box(e.handle(&Request::Score { tokens: tokens.clone() }));
            });
            let (skel, dense, paged) = e.resident_breakdown().unwrap();
            cells.push((96.0 / secs.max(1e-9), skel + dense + paged));
        }
        quant_table.row(vec![
            "serve".into(),
            format!("{bname} 96-tok score ({})", kernel_label()),
            "tok/s".into(),
            format!("{:.0}", cells[0].0),
            "-".into(),
            format!("{:.0}", cells[1].0),
            "-".into(),
        ]);
        quant_table.row(vec![
            "serve".into(),
            format!("{bname} 96-tok score"),
            "resident expert bytes".into(),
            format!("{}", cells[0].1),
            "-".into(),
            format!("{}", cells[1].1),
            "-".into(),
        ]);
    }

    // --- observability overhead: what the registry + trace layer cost on
    // the serve path. Counter/histogram records are a few relaxed atomic
    // adds and a disabled span is one relaxed load — these rows are the
    // "off is free" claim in numbers; the traced-span row is the price only
    // paid when RESMOE_TRACE is set.
    {
        use resmoe::obs::{trace, Registry};
        let reg = Registry::new();
        let ctr = reg.counter("bench.counter");
        let hist = reg.histogram("bench.hist");
        runner.run("obs: counter inc x1000", 3, iters * 10, || {
            for _ in 0..1000 {
                ctr.inc();
            }
            std::hint::black_box(ctr.get());
        });
        runner.run("obs: histogram record x1000", 3, iters * 10, || {
            for i in 0..1000u64 {
                hist.record(i * 37 % 5000);
            }
            std::hint::black_box(&hist);
        });
        runner.run("obs: span disabled x1000", 3, iters * 10, || {
            for _ in 0..1000 {
                std::hint::black_box(trace::span("bench.stage"));
            }
        });
        {
            let _g = trace::test_serial();
            trace::force_for_tests(Some(true));
            runner.run("obs: span traced x1000 (begin..finish)", 3, iters * 5, || {
                trace::begin();
                for _ in 0..1000 {
                    std::hint::black_box(trace::span("bench.stage"));
                }
                std::hint::black_box(trace::finish());
            });
            trace::drain_test_lines();
            trace::force_for_tests(None);
        }
        runner.run("obs: registry snapshot (2 instruments)", 3, iters * 10, || {
            std::hint::black_box(reg.snapshot());
        });
    }

    // Summarize as tables for the reports directory. The BENCH_* stems are
    // the cross-PR trajectory files (EXPERIMENTS.md §Perf).
    let mut t = Table::new("Perf hot-path microbenches", &["bench", "mean (ms)", "p50 (ms)", "p99 (ms)"]);
    for r in &runner.results {
        t.row(vec![
            r.name.clone(),
            format!("{:.3}", r.mean_ms()),
            format!("{:.3}", r.p50_ms()),
            format!("{:.3}", r.p99_ms()),
        ]);
    }
    t.print();
    t.save_json("perf_hotpath");
    t.save_json("BENCH_perf_hotpath");
    spmm_table.print();
    spmm_table.save_json("BENCH_spmm_density_sweep");
    simd_table.print();
    simd_table.save_json("BENCH_simd");
    quant_table.print();
    quant_table.save_json("BENCH_quant");
    cold_table.print();
    cold_table.save_json("BENCH_coldstart");
    conc_table.print();
    conc_table.save_json("BENCH_concurrency");
    batch_table.print();
    batch_table.save_json("BENCH_batching");
}

/// Drive `workers` client threads, each scoring `reqs` 24-token sequences
/// against a fresh (cold) store-backed engine at `budget`. `serialize`
/// additionally wraps every `handle()` in one global mutex — the
/// collapsed-to-one-worker baseline the concurrent ExpertCache replaces.
/// Returns (p50 ms, p99 ms, aggregate tok/s) over all requests.
fn concurrent_serve_stats(
    artifact: &std::path::Path,
    budget: usize,
    workers: usize,
    reqs: usize,
    serialize: bool,
) -> (f64, f64, f64) {
    use std::time::Instant;
    let mut engine = Engine::from_store(artifact, budget).expect("open artifact");
    engine.disable_prefetch(); // measure pure demand paging under contention
    let gate = std::sync::Mutex::new(());
    let t0 = Instant::now();
    let lats: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let engine = engine.clone();
                let gate = &gate;
                s.spawn(move || {
                    let mut lats = Vec::with_capacity(reqs);
                    for i in 0..reqs {
                        let tokens: Vec<u32> = (0..24)
                            .map(|t| ((t * 7 + i * 13 + w * 5 + 1) % 256) as u32)
                            .collect();
                        let t = Instant::now();
                        let resp = if serialize {
                            let _g = gate.lock().unwrap();
                            engine.handle(&Request::Score { tokens })
                        } else {
                            engine.handle(&Request::Score { tokens })
                        };
                        assert!(matches!(resp, Response::Score(_)), "{resp:?}");
                        lats.push(t.elapsed().as_secs_f64());
                    }
                    lats
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let toks = (workers * reqs * 24) as f64 / wall;
    (percentile(&lats, 50.0) * 1e3, percentile(&lats, 99.0) * 1e3, toks)
}
