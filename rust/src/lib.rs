//! # resmoe
//!
//! Production-oriented reproduction of **ResMoE: Space-efficient Compression
//! of Mixture-of-Experts LLMs via Residual Restoration** (Ai, Wei, Chen et
//! al., KDD 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the compression toolkit (Wasserstein-barycenter
//!   expert extraction, residual compressors, every baseline from the
//!   paper's evaluation), the MoE model substrate it operates on, the eval
//!   harness that regenerates each paper table/figure, and a serving
//!   coordinator whose restored-expert cache turns the paper's Algorithm 2
//!   into a first-class runtime feature.
//! * **L2/L1 (python/, build-time only)** — the JAX MoE block and the Pallas
//!   barycenter-MoE kernel, AOT-lowered to HLO text consumed by
//!   [`runtime`] through the PJRT CPU client. Python never runs on the
//!   request path.
//!
//! Entry points: [`compress`] for the algorithm, [`coordinator`] for
//! serving, `rust/benches/*` for the paper's tables, and
//! `examples/end_to_end.rs` for the full pipeline.

pub mod baselines;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod loadgen;
pub mod moe;
pub mod obs;
pub mod ot;
pub mod runtime;
pub mod store;
pub mod tensor;
pub mod util;

pub use tensor::Matrix;
pub use util::Rng;
