//! Entropic optimal transport via log-domain Sinkhorn iterations.
//!
//! Used (a) as a differentiable/soft alternative to the exact assignment in
//! the barycenter ablations and (b) to cross-check the Hungarian solver:
//! as the regularization `eps → 0`, the Sinkhorn cost approaches the exact
//! OT cost from above.

use crate::tensor::Matrix;

/// Result of a Sinkhorn solve.
#[derive(Debug, Clone)]
pub struct SinkhornPlan {
    /// Transport plan (n×m), rows sum to `a`, columns to `b` (approximately).
    pub plan: Matrix,
    /// `<plan, cost>` — the regularized transport cost (without the entropy
    /// term).
    pub cost: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// Log-domain Sinkhorn for marginals `a` (len n) and `b` (len m) under
/// `cost` (n×m) with entropic regularization `eps`.
pub fn sinkhorn(
    cost: &Matrix,
    a: &[f64],
    b: &[f64],
    eps: f64,
    max_iter: usize,
    tol: f64,
) -> SinkhornPlan {
    let (n, m) = cost.shape();
    assert_eq!(a.len(), n);
    assert_eq!(b.len(), m);
    assert!(eps > 0.0);
    let log_a: Vec<f64> = a.iter().map(|x| x.max(1e-300).ln()).collect();
    let log_b: Vec<f64> = b.iter().map(|x| x.max(1e-300).ln()).collect();
    // Scaled negative cost in f64.
    let mk = |i: usize, j: usize| -(cost.at(i, j) as f64) / eps;
    let mut f = vec![0.0f64; n]; // dual potentials / eps
    let mut g = vec![0.0f64; m];
    let mut iterations = 0;
    let mut converged = false;
    let logsumexp_row = |f_: &[f64], g_: &[f64], i: usize| {
        let mut max = f64::NEG_INFINITY;
        for j in 0..m {
            max = max.max(mk(i, j) + g_[j]);
        }
        if max.is_infinite() {
            return max;
        }
        let s: f64 = (0..m).map(|j| (mk(i, j) + g_[j] - max).exp()).sum();
        let _ = f_;
        max + s.ln()
    };
    let logsumexp_col = |f_: &[f64], j: usize| {
        let mut max = f64::NEG_INFINITY;
        for i in 0..n {
            max = max.max(mk(i, j) + f_[i]);
        }
        if max.is_infinite() {
            return max;
        }
        let s: f64 = (0..n).map(|i| (mk(i, j) + f_[i] - max).exp()).sum();
        max + s.ln()
    };
    for it in 0..max_iter {
        iterations = it + 1;
        // f update: f_i = log a_i - logsumexp_j (M_ij + g_j)
        for i in 0..n {
            f[i] = log_a[i] - logsumexp_row(&f, &g, i);
        }
        // g update.
        let mut max_violation = 0.0f64;
        for j in 0..m {
            let new_g = log_b[j] - logsumexp_col(&f, j);
            max_violation = max_violation.max((new_g - g[j]).abs());
            g[j] = new_g;
        }
        if max_violation < tol {
            converged = true;
            break;
        }
    }
    // Plan P_ij = exp(f_i + g_j + M_ij).
    let mut plan = Matrix::zeros(n, m);
    let mut total_cost = 0.0f64;
    for i in 0..n {
        for j in 0..m {
            let p = (f[i] + g[j] + mk(i, j)).exp();
            *plan.at_mut(i, j) = p as f32;
            total_cost += p * cost.at(i, j) as f64;
        }
    }
    SinkhornPlan { plan, cost: total_cost, iterations, converged }
}

/// Uniform-marginal convenience wrapper.
pub fn sinkhorn_uniform(cost: &Matrix, eps: f64, max_iter: usize, tol: f64) -> SinkhornPlan {
    let (n, m) = cost.shape();
    let a = vec![1.0 / n as f64; n];
    let b = vec![1.0 / m as f64; m];
    sinkhorn(cost, &a, &b, eps, max_iter, tol)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::{cost::sq_euclidean, hungarian};
    use crate::util::Rng;

    #[test]
    fn marginals_are_respected() {
        let mut rng = Rng::new(1);
        let c = Matrix::from_fn(6, 6, |_, _| rng.uniform() as f32);
        // Generous eps: the entropic contraction rate degrades like
        // exp(-osc(C)/eps), so tiny eps converges impractically slowly.
        let sp = sinkhorn_uniform(&c, 0.5, 5000, 1e-9);
        assert!(sp.converged, "no convergence in {} iters", sp.iterations);
        for i in 0..6 {
            let row_sum: f32 = sp.plan.row(i).iter().sum();
            assert!((row_sum - 1.0 / 6.0).abs() < 1e-5, "row {i}: {row_sum}");
        }
        for j in 0..6 {
            let col_sum: f32 = sp.plan.col(j).iter().sum();
            assert!((col_sum - 1.0 / 6.0).abs() < 1e-5, "col {j}: {col_sum}");
        }
    }

    #[test]
    fn approaches_exact_ot_as_eps_shrinks() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(8, 3, 1.0, &mut rng);
        let b = Matrix::randn(8, 3, 1.0, &mut rng);
        let c = sq_euclidean(&a, &b);
        let exact = hungarian::solve(&c).cost / 8.0; // uniform masses 1/8
        let loose = sinkhorn_uniform(&c, 1.0, 3000, 1e-11).cost;
        let tight = sinkhorn_uniform(&c, 0.01, 6000, 1e-11).cost;
        assert!(tight <= loose + 1e-9, "tight={tight} loose={loose}");
        assert!(
            (tight - exact).abs() < 0.05 * exact.max(1e-9) + 1e-3,
            "sinkhorn={tight} exact={exact}"
        );
    }

    #[test]
    fn plan_concentrates_on_cheap_edges() {
        // Two points each, one obviously optimal matching.
        let c = Matrix::from_vec(2, 2, vec![0.0, 10.0, 10.0, 0.0]);
        let sp = sinkhorn_uniform(&c, 0.1, 2000, 1e-10);
        assert!(sp.plan.at(0, 0) > 10.0 * sp.plan.at(0, 1));
        assert!(sp.plan.at(1, 1) > 10.0 * sp.plan.at(1, 0));
    }

    #[test]
    fn rectangular_problem() {
        let mut rng = Rng::new(3);
        let c = Matrix::from_fn(4, 7, |_, _| rng.uniform() as f32);
        let sp = sinkhorn_uniform(&c, 0.1, 2000, 1e-9);
        assert!(sp.converged);
        let total: f32 = sp.plan.data.iter().sum();
        assert!((total - 1.0).abs() < 1e-4);
    }
}
