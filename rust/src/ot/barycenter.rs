//! Free-support Wasserstein barycenter (Cuturi & Doucet 2014) for uniform
//! point clouds of equal support size — the heart of ResMoE §4.2.
//!
//! Because every input distribution and the barycenter are uniform on the
//! same number of points, each OT plan rescales to a permutation
//! (Prop. 4.1), and the Cuturi–Doucet alternating scheme reduces to:
//!
//! 1. **Assignment step** — for each cloud `W_k`, solve a Hungarian problem
//!    between the barycenter rows and `W_k`'s rows (exact `T_k`).
//! 2. **Update step** — each barycenter row becomes the mean of its matched
//!    rows across clouds (the closed-form minimizer of problem (4) for
//!    fixed `T_k`).
//!
//! The objective `1/N Σ_k W2²(μ_k, μ_ω)` is monotonically non-increasing,
//! which the tests assert.

use super::cost::sq_euclidean;
use super::hungarian;
use crate::tensor::{kernel, Matrix};
use crate::util::Rng;

/// Configuration for the alternating barycenter solver.
#[derive(Debug, Clone, Copy)]
pub struct BarycenterConfig {
    pub max_iter: usize,
    /// Stop when the relative objective improvement drops below this.
    pub rel_tol: f64,
    /// Initialization strategy.
    pub init: BarycenterInit,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BarycenterInit {
    /// Start from the first cloud (deterministic).
    FirstCloud,
    /// Start from the element-wise mean of the clouds (no alignment).
    Mean,
    /// Start from a random cloud.
    RandomCloud,
}

impl Default for BarycenterConfig {
    fn default() -> Self {
        BarycenterConfig { max_iter: 25, rel_tol: 1e-6, init: BarycenterInit::FirstCloud }
    }
}

/// Output of the barycenter solve.
#[derive(Debug, Clone)]
pub struct Barycenter {
    /// Barycenter support points (n×d) — the rows of `W_ω`.
    pub support: Matrix,
    /// Per-cloud alignment: `perms[k][i] = j` means barycenter row `i` is
    /// matched to row `j` of cloud `k`, i.e. `(T_k W_k)[i] = W_k[perms[k][i]]`.
    pub perms: Vec<Vec<usize>>,
    /// Final objective `1/N Σ_k W2²(μ_k, μ_ω)` (un-normalized point masses:
    /// mean over rows).
    pub objective: f64,
    /// Objective value after each iteration (for convergence diagnostics).
    pub history: Vec<f64>,
    pub iterations: usize,
}

/// Compute the free-support barycenter of `clouds` (all n×d with the same n).
pub fn free_support_barycenter(
    clouds: &[&Matrix],
    cfg: &BarycenterConfig,
    rng: &mut Rng,
) -> Barycenter {
    assert!(!clouds.is_empty(), "need at least one cloud");
    let n = clouds[0].rows;
    let d = clouds[0].cols;
    for c in clouds {
        assert_eq!(c.shape(), (n, d), "all clouds must share the same shape");
    }
    let mut support = match cfg.init {
        BarycenterInit::FirstCloud => clouds[0].clone(),
        BarycenterInit::Mean => Matrix::mean_of(clouds),
        BarycenterInit::RandomCloud => clouds[rng.below(clouds.len())].clone(),
    };
    let nk = clouds.len();
    let mut perms: Vec<Vec<usize>> = vec![(0..n).collect(); nk];
    let mut history = Vec::new();
    let mut prev_obj = f64::INFINITY;
    let mut iterations = 0;
    for it in 0..cfg.max_iter {
        iterations = it + 1;
        // --- assignment step: barycenter rows -> cloud rows.
        let mut obj = 0.0f64;
        for (k, cloud) in clouds.iter().enumerate() {
            let cost = sq_euclidean(&support, cloud);
            let asg = hungarian::solve(&cost);
            obj += asg.cost / n as f64;
            perms[k] = asg.row_to_col;
        }
        obj /= nk as f64;
        history.push(obj);
        // --- update step: each barycenter row = mean of matched rows.
        let mut new_support = Matrix::zeros(n, d);
        for (k, cloud) in clouds.iter().enumerate() {
            for i in 0..n {
                // Exact elementwise tier: bitwise identical across kernel
                // kinds, so barycenters stay reproducible under SIMD.
                kernel::add_slice(new_support.row_mut(i), cloud.row(perms[k][i]));
            }
        }
        support = new_support.scale(1.0 / nk as f32);
        if prev_obj.is_finite() && (prev_obj - obj).abs() <= cfg.rel_tol * prev_obj.abs().max(1e-12)
        {
            break;
        }
        prev_obj = obj;
    }
    // Final objective/alignments against the updated support.
    let mut obj = 0.0f64;
    for (k, cloud) in clouds.iter().enumerate() {
        let cost = sq_euclidean(&support, cloud);
        let asg = hungarian::solve(&cost);
        obj += asg.cost / n as f64;
        perms[k] = asg.row_to_col;
    }
    obj /= nk as f64;
    history.push(obj);
    Barycenter { support, perms, objective: obj, history, iterations }
}

/// `W2²` between two equal-size uniform clouds (exact, via assignment).
pub fn wasserstein2_sq(a: &Matrix, b: &Matrix) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let cost = sq_euclidean(a, b);
    hungarian::solve(&cost).cost / a.rows as f64
}

/// The aligned objective of problem (4) for a given barycenter + alignments:
/// `1/N Σ_k ||T_k W_k - W_ω||_F²` — used to verify Prop. 4.1 numerically.
pub fn alignment_objective(clouds: &[&Matrix], bc: &Barycenter) -> f64 {
    let n = bc.support.rows;
    let mut total = 0.0f64;
    for (k, cloud) in clouds.iter().enumerate() {
        let aligned = cloud.permute_rows(&bc.perms[k]);
        total += aligned.sq_dist(&bc.support) / n as f64;
    }
    total / clouds.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shifted_clouds(rng: &mut Rng, n: usize, d: usize, n_clouds: usize) -> Vec<Matrix> {
        // A base cloud plus per-cloud row permutation and small shift.
        let base = Matrix::randn(n, d, 1.0, rng);
        (0..n_clouds)
            .map(|k| {
                let perm = rng.permutation(n);
                let shift = (k as f32 - (n_clouds as f32 - 1.0) / 2.0) * 0.01;
                base.permute_rows(&perm).map(|x| x + shift)
            })
            .collect()
    }

    #[test]
    fn single_cloud_is_its_own_barycenter() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(10, 4, 1.0, &mut rng);
        let bc = free_support_barycenter(&[&a], &BarycenterConfig::default(), &mut rng);
        assert!(bc.objective < 1e-10);
        assert!(bc.support.sq_dist(&a) < 1e-10);
    }

    #[test]
    fn recovers_common_structure_under_permutation() {
        // Clouds are the SAME point set under different row permutations →
        // the barycenter must recover that set (objective ≈ per-cloud shift
        // variance only).
        let mut rng = Rng::new(2);
        let clouds = shifted_clouds(&mut rng, 16, 5, 4);
        let refs: Vec<&Matrix> = clouds.iter().collect();
        let bc = free_support_barycenter(&refs, &BarycenterConfig::default(), &mut rng);
        // shift magnitudes 0.015 max per coordinate → tiny residual objective
        assert!(bc.objective < 1e-2, "objective={}", bc.objective);
        // And alignment maps every cloud onto the barycenter almost exactly.
        for (k, cloud) in clouds.iter().enumerate() {
            let aligned = cloud.permute_rows(&bc.perms[k]);
            assert!(aligned.sq_dist(&bc.support) / 16.0 < 1e-2, "cloud {k}");
        }
    }

    #[test]
    fn objective_monotonically_decreases() {
        let mut rng = Rng::new(3);
        let clouds: Vec<Matrix> = (0..5).map(|_| Matrix::randn(12, 6, 1.0, &mut rng)).collect();
        let refs: Vec<&Matrix> = clouds.iter().collect();
        let bc = free_support_barycenter(&refs, &BarycenterConfig::default(), &mut rng);
        for w in bc.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "history not monotone: {:?}", bc.history);
        }
    }

    #[test]
    fn proposition_4_1_objectives_coincide() {
        // The WB objective (5) equals the alignment objective (4) at the
        // solution — the numerical content of Prop 4.1.
        let mut rng = Rng::new(4);
        let clouds: Vec<Matrix> = (0..4).map(|_| Matrix::randn(10, 3, 1.0, &mut rng)).collect();
        let refs: Vec<&Matrix> = clouds.iter().collect();
        let bc = free_support_barycenter(&refs, &BarycenterConfig::default(), &mut rng);
        let align_obj = alignment_objective(&refs, &bc);
        assert!(
            (align_obj - bc.objective).abs() < 1e-6 * bc.objective.max(1e-9),
            "alignment={} wb={}",
            align_obj,
            bc.objective
        );
    }

    #[test]
    fn barycenter_beats_unaligned_mean() {
        // With permuted clouds the naive mean destroys structure; the WB
        // objective must be strictly better than the mean-center objective.
        let mut rng = Rng::new(5);
        let clouds = shifted_clouds(&mut rng, 20, 8, 4);
        let refs: Vec<&Matrix> = clouds.iter().collect();
        let bc = free_support_barycenter(&refs, &BarycenterConfig::default(), &mut rng);
        let mean = Matrix::mean_of(&refs);
        let mean_obj: f64 = refs.iter().map(|c| wasserstein2_sq(&mean, c)).sum::<f64>()
            / refs.len() as f64;
        assert!(
            bc.objective < 0.5 * mean_obj,
            "wb={} mean={}",
            bc.objective,
            mean_obj
        );
    }

    #[test]
    fn perms_are_permutations() {
        let mut rng = Rng::new(6);
        let clouds: Vec<Matrix> = (0..3).map(|_| Matrix::randn(15, 4, 1.0, &mut rng)).collect();
        let refs: Vec<&Matrix> = clouds.iter().collect();
        let bc = free_support_barycenter(&refs, &BarycenterConfig::default(), &mut rng);
        for p in &bc.perms {
            let mut sorted = p.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..15).collect::<Vec<_>>());
        }
    }

    #[test]
    fn mean_init_also_converges() {
        let mut rng = Rng::new(7);
        let clouds = shifted_clouds(&mut rng, 12, 4, 3);
        let refs: Vec<&Matrix> = clouds.iter().collect();
        let cfg = BarycenterConfig { init: BarycenterInit::Mean, ..Default::default() };
        let bc = free_support_barycenter(&refs, &cfg, &mut rng);
        assert!(bc.objective < 1e-2);
    }

    #[test]
    fn w2_of_identical_clouds_is_zero_even_permuted() {
        let mut rng = Rng::new(8);
        let a = Matrix::randn(9, 3, 1.0, &mut rng);
        let p = rng.permutation(9);
        let b = a.permute_rows(&p);
        assert!(wasserstein2_sq(&a, &b) < 1e-10);
    }
}
