//! Exact minimum-cost assignment (Hungarian algorithm, Jonker–Volgenant
//! style shortest-augmenting-path formulation, O(n^3)).
//!
//! This is the workhorse behind Proposition 4.1: for two uniform discrete
//! distributions with equal support size the optimal transport plan is a
//! permutation (Peyré–Cuturi Prop. 2.1), so `W2^2` and the alignment `T_k`
//! reduce to one assignment solve on the squared-Euclidean cost matrix.

use crate::tensor::Matrix;

/// Solution of an assignment problem.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// `row_to_col[i] = j` — row i is matched to column j.
    pub row_to_col: Vec<usize>,
    /// Total cost of the matching.
    pub cost: f64,
}

impl Assignment {
    /// Inverse mapping: `col_to_row[j] = i`.
    pub fn col_to_row(&self) -> Vec<usize> {
        let mut inv = vec![usize::MAX; self.row_to_col.len()];
        for (i, &j) in self.row_to_col.iter().enumerate() {
            inv[j] = i;
        }
        inv
    }
}

/// Solve the square min-cost assignment problem for `cost` (n×n).
///
/// Classic potentials formulation (e-maxx / KACTL): for each row, grow an
/// alternating tree of tight edges via Dijkstra-like scans until an
/// unmatched column is reached, then augment.
pub fn solve(cost: &Matrix) -> Assignment {
    assert_eq!(cost.rows, cost.cols, "assignment requires a square cost matrix");
    let n = cost.rows;
    if n == 0 {
        return Assignment { row_to_col: vec![], cost: 0.0 };
    }
    const INF: f64 = f64::INFINITY;
    // 1-based arrays; p[j] = row matched to column j (0 = none).
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1];
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            let row = cost.row(i0 - 1);
            for j in 1..=n {
                if !used[j] {
                    let cur = row[j - 1] as f64 - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        // Augment along the path.
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut row_to_col = vec![usize::MAX; n];
    for j in 1..=n {
        if p[j] > 0 {
            row_to_col[p[j] - 1] = j - 1;
        }
    }
    let total: f64 = row_to_col
        .iter()
        .enumerate()
        .map(|(i, &j)| cost.at(i, j) as f64)
        .sum();
    Assignment { row_to_col, cost: total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Brute-force minimum over all permutations (for n <= 8).
    fn brute_force(cost: &Matrix) -> f64 {
        fn rec(cost: &Matrix, row: usize, used: &mut Vec<bool>, acc: f64, best: &mut f64) {
            if row == cost.rows {
                *best = best.min(acc);
                return;
            }
            if acc >= *best {
                return;
            }
            for j in 0..cost.cols {
                if !used[j] {
                    used[j] = true;
                    rec(cost, row + 1, used, acc + cost.at(row, j) as f64, best);
                    used[j] = false;
                }
            }
        }
        let mut best = f64::INFINITY;
        rec(cost, 0, &mut vec![false; cost.cols], 0.0, &mut best);
        best
    }

    #[test]
    fn trivial_identity() {
        // Diagonal is clearly optimal.
        let c = Matrix::from_fn(4, 4, |i, j| if i == j { 0.0 } else { 10.0 });
        let a = solve(&c);
        assert_eq!(a.row_to_col, vec![0, 1, 2, 3]);
        assert_eq!(a.cost, 0.0);
    }

    #[test]
    fn known_small_case() {
        let c = Matrix::from_vec(3, 3, vec![4.0, 1.0, 3.0, 2.0, 0.0, 5.0, 3.0, 2.0, 2.0]);
        let a = solve(&c);
        assert!((a.cost - 5.0).abs() < 1e-9, "cost={}", a.cost);
    }

    #[test]
    fn matches_brute_force_randomly() {
        let mut rng = Rng::new(42);
        for trial in 0..30 {
            let n = 2 + rng.below(6);
            let c = Matrix::from_fn(n, n, |_, _| rng.uniform() as f32 * 10.0);
            let a = solve(&c);
            let bf = brute_force(&c);
            assert!(
                (a.cost - bf).abs() < 1e-4,
                "trial {trial}: hungarian={} brute={}",
                a.cost,
                bf
            );
        }
    }

    #[test]
    fn output_is_permutation() {
        let mut rng = Rng::new(7);
        let c = Matrix::from_fn(50, 50, |_, _| rng.uniform() as f32);
        let a = solve(&c);
        let mut seen = vec![false; 50];
        for &j in &a.row_to_col {
            assert!(!seen[j], "column {j} assigned twice");
            seen[j] = true;
        }
    }

    #[test]
    fn recovers_planted_permutation() {
        // Cost = 0 on a planted permutation, positive elsewhere.
        let mut rng = Rng::new(9);
        let n = 32;
        let planted = rng.permutation(n);
        let c = Matrix::from_fn(n, n, |i, j| {
            if planted[i] == j {
                0.0
            } else {
                0.1 + rng.uniform() as f32
            }
        });
        let a = solve(&c);
        assert_eq!(a.row_to_col, planted);
    }

    #[test]
    fn handles_negative_costs() {
        let c = Matrix::from_vec(2, 2, vec![-5.0, 1.0, 1.0, -5.0]);
        let a = solve(&c);
        assert!((a.cost + 10.0).abs() < 1e-9);
    }

    #[test]
    fn col_to_row_inverse() {
        let mut rng = Rng::new(11);
        let c = Matrix::from_fn(10, 10, |_, _| rng.uniform() as f32);
        let a = solve(&c);
        let inv = a.col_to_row();
        for (i, &j) in a.row_to_col.iter().enumerate() {
            assert_eq!(inv[j], i);
        }
    }

    #[test]
    fn empty_problem() {
        let a = solve(&Matrix::zeros(0, 0));
        assert!(a.row_to_col.is_empty());
        assert_eq!(a.cost, 0.0);
    }
}
