//! Optimal transport: cost matrices, exact assignment (Hungarian/JV),
//! entropic Sinkhorn, and the free-support Wasserstein barycenter used to
//! extract the ResMoE barycenter expert (§3.2, §4.2, Prop. 4.1).

pub mod barycenter;
pub mod cost;
pub mod hungarian;
pub mod sinkhorn;

pub use barycenter::{
    alignment_objective, free_support_barycenter, wasserstein2_sq, Barycenter, BarycenterConfig,
    BarycenterInit,
};
pub use hungarian::Assignment;
