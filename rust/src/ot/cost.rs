//! Cost matrices for optimal transport between point clouds.

use crate::tensor::{kernel, Matrix};

/// Pairwise squared Euclidean distances between the rows of `a` (n×d) and
/// the rows of `b` (m×d): `C[i,j] = ||a_i - b_j||^2`.
///
/// Computed as `||a_i||^2 + ||b_j||^2 - 2 a_i·b_j` with a single matmul so
/// the dominant term vectorizes; negatives from float cancellation are
/// clamped to zero.
pub fn sq_euclidean(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "point dims differ");
    let a_sq: Vec<f32> = (0..a.rows).map(|i| kernel::dot(a.row(i), a.row(i))).collect();
    let b_sq: Vec<f32> = (0..b.rows).map(|j| kernel::dot(b.row(j), b.row(j))).collect();
    let ab = a.matmul_nt(b); // n×m of dot products
    Matrix::from_fn(a.rows, b.rows, |i, j| {
        (a_sq[i] + b_sq[j] - 2.0 * ab.at(i, j)).max(0.0)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matches_naive() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(7, 5, 1.0, &mut rng);
        let b = Matrix::randn(9, 5, 1.0, &mut rng);
        let c = sq_euclidean(&a, &b);
        for i in 0..7 {
            for j in 0..9 {
                let naive: f32 = a
                    .row(i)
                    .iter()
                    .zip(b.row(j))
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                assert!((c.at(i, j) - naive).abs() < 1e-4, "({i},{j})");
            }
        }
    }

    #[test]
    fn self_distance_zero_diagonal() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(6, 4, 1.0, &mut rng);
        let c = sq_euclidean(&a, &a);
        for i in 0..6 {
            assert!(c.at(i, i).abs() < 1e-4);
        }
    }

    #[test]
    fn nonnegative() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(20, 3, 10.0, &mut rng);
        let b = Matrix::randn(20, 3, 10.0, &mut rng);
        let c = sq_euclidean(&a, &b);
        assert!(c.data.iter().all(|&x| x >= 0.0));
    }
}
