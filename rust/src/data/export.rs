//! Dataset export for the build-time JAX pretrainer.
//!
//! Rust owns the data generators (single source of truth); `resmoe datagen`
//! dumps the corpus and task datasets as JSON under `artifacts/data/`, and
//! `python/compile/pretrain.py` consumes them. This guarantees the python
//! training distribution and the rust evaluation distribution are
//! bit-identical.

use super::corpus::Corpus;
use super::tasks::{self, Example, NLU_TASKS};
use crate::util::json::Json;
use crate::util::Rng;
use anyhow::{Context, Result};
use std::path::Path;

fn tokens_json(tokens: &[u32]) -> Json {
    Json::Arr(tokens.iter().map(|&t| Json::num(t as f64)).collect())
}

fn examples_json(examples: &[Example]) -> Json {
    Json::Arr(
        examples
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("tokens", tokens_json(&e.tokens)),
                    ("label", Json::num(e.label as f64)),
                ])
            })
            .collect(),
    )
}

/// Export the corpus + NLU training sets for one model family.
pub fn export_datasets(dir: &Path, vocab_size: usize, max_len: usize, seed: u64) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("mkdir {}", dir.display()))?;
    let corpus = Corpus::generate(vocab_size, 300_000, 20_000, seed);
    let write = |name: &str, j: Json| -> Result<()> {
        std::fs::write(dir.join(name), j.to_string())
            .with_context(|| format!("write {name}"))?;
        Ok(())
    };
    write(
        "corpus.json",
        Json::obj(vec![
            ("vocab_size", Json::num(vocab_size as f64)),
            ("seed", Json::num(seed as f64)),
            ("train", tokens_json(&corpus.train)),
            ("valid", tokens_json(&corpus.valid)),
        ]),
    )?;
    let mut rng = Rng::new(seed ^ 0x7A5C5);
    for task in NLU_TASKS {
        let train = tasks::gen_nlu(task, &corpus.language, 2000, max_len, &mut rng);
        let test = tasks::gen_nlu(task, &corpus.language, 400, max_len, &mut rng);
        write(
            &format!("{task}.json"),
            Json::obj(vec![
                ("task", Json::str(task)),
                ("n_classes", Json::num(tasks::n_classes(task) as f64)),
                ("train", examples_json(&train)),
                ("test", examples_json(&test)),
            ]),
        )?;
    }
    Ok(())
}

/// Load exported classification examples back (used by the eval harness so
/// heads trained in python are evaluated on the *identical* test split).
pub fn load_examples(path: &Path, split: &str) -> Result<Vec<Example>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let arr = j
        .get(split)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow::anyhow!("missing split {split}"))?;
    arr.iter()
        .map(|e| {
            let tokens = e
                .get("tokens")
                .and_then(|t| t.as_arr())
                .ok_or_else(|| anyhow::anyhow!("bad tokens"))?
                .iter()
                .map(|v| v.as_usize().map(|u| u as u32))
                .collect::<Option<Vec<u32>>>()
                .ok_or_else(|| anyhow::anyhow!("bad token value"))?;
            let label = e
                .get("label")
                .and_then(|l| l.as_usize())
                .ok_or_else(|| anyhow::anyhow!("bad label"))?;
            Ok(Example { tokens, label })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn export_and_reload_roundtrip() {
        let dir = std::env::temp_dir().join("resmoe-export-test");
        let _ = std::fs::remove_dir_all(&dir);
        export_datasets(&dir, 64, 96, 11).unwrap();
        assert!(dir.join("corpus.json").exists());
        for task in NLU_TASKS {
            let train = load_examples(&dir.join(format!("{task}.json")), "train").unwrap();
            let test = load_examples(&dir.join(format!("{task}.json")), "test").unwrap();
            assert_eq!(train.len(), 2000);
            assert_eq!(test.len(), 400);
            assert!(test.iter().all(|e| e.tokens.iter().all(|&t| t < 64)));
        }
    }
}
