//! Synthetic evaluation tasks — analogs of the paper's benchmarks over the
//! synthetic language (substitution table in DESIGN.md §2).
//!
//! NLU (classification, heads trained at build time in JAX):
//! * `sst2`  — sentiment: does the sequence carry more positive than
//!   negative marker words? (binary)
//! * `mrpc`  — paraphrase: is the second segment a shuffled copy of the
//!   first? (binary)
//! * `cola`  — acceptability: lexicon words vs corrupted token soup (binary)
//! * `mnli`  — entailment: hypothesis ⊂ premise / contradiction marker /
//!   disjoint (3-way)
//!
//! Zero-shot NLG (LM-scored, no heads):
//! * `lambada`    — predict a word's final token from the passage
//! * `piqa`       — 2-choice: true word completion vs corrupted
//! * `winogrande` — 2-choice: consistent vs inconsistent continuation in
//!   context

use super::corpus::{Language, SEP};
use crate::util::Rng;

/// One classification example.
#[derive(Debug, Clone)]
pub struct Example {
    pub tokens: Vec<u32>,
    pub label: usize,
}

/// A 2-choice LM-scored example: `prefix + choices[label]` is correct.
#[derive(Debug, Clone)]
pub struct ChoiceExample {
    pub prefix: Vec<u32>,
    pub choices: [Vec<u32>; 2],
    pub label: usize,
}

/// A last-token-prediction example (LAMBADA analog).
#[derive(Debug, Clone)]
pub struct LambadaExample {
    pub context: Vec<u32>,
    pub target: u32,
}

pub const NLU_TASKS: [&str; 4] = ["sst2", "mrpc", "cola", "mnli"];

pub fn n_classes(task: &str) -> usize {
    match task {
        "mnli" => 3,
        _ => 2,
    }
}

fn cap(mut tokens: Vec<u32>, max_len: usize) -> Vec<u32> {
    if tokens.len() > max_len {
        tokens.drain(..tokens.len() - max_len);
    }
    tokens
}

/// Marker words for sentiment: the first `n_markers` lexicon words are
/// "positive", the next `n_markers` are "negative".
const N_MARKERS: usize = 8;

pub fn gen_sst2(lang: &Language, n: usize, max_len: usize, rng: &mut Rng) -> Vec<Example> {
    (0..n)
        .map(|_| {
            let label = rng.below(2);
            let (mut tokens, _) = lang.generate_words(4, rng);
            let strong = 2 + rng.below(2);
            let weak = rng.below(2);
            let (majority, minority) =
                if label == 1 { (0, N_MARKERS) } else { (N_MARKERS, 0) };
            for _ in 0..strong {
                let w = majority + rng.below(N_MARKERS);
                tokens.extend_from_slice(&lang.words[w]);
                tokens.push(SEP);
            }
            for _ in 0..weak {
                let w = minority + rng.below(N_MARKERS);
                tokens.extend_from_slice(&lang.words[w]);
                tokens.push(SEP);
            }
            Example { tokens: cap(tokens, max_len), label }
        })
        .collect()
}

pub fn gen_mrpc(lang: &Language, n: usize, max_len: usize, rng: &mut Rng) -> Vec<Example> {
    (0..n)
        .map(|_| {
            let label = rng.below(2);
            let (_, ids_a) = lang.generate_words(4, rng);
            let mut a_tokens = Vec::new();
            for &w in &ids_a {
                a_tokens.extend_from_slice(&lang.words[w]);
                a_tokens.push(SEP);
            }
            let b_tokens = if label == 1 {
                // Paraphrase: the same words, shuffled.
                let mut ids = ids_a.clone();
                rng.shuffle(&mut ids);
                let mut t = Vec::new();
                for &w in &ids {
                    t.extend_from_slice(&lang.words[w]);
                    t.push(SEP);
                }
                t
            } else {
                let (t, _) = lang.generate_words(4, rng);
                t
            };
            let mut tokens = a_tokens;
            tokens.push(SEP); // segment boundary = double separator
            tokens.extend(b_tokens);
            Example { tokens: cap(tokens, max_len), label }
        })
        .collect()
}

pub fn gen_cola(lang: &Language, n: usize, max_len: usize, rng: &mut Rng) -> Vec<Example> {
    (0..n)
        .map(|_| {
            let label = rng.below(2);
            let tokens = if label == 1 {
                lang.generate_words(6, rng).0
            } else {
                // Unacceptable: uniform token soup with misplaced separators.
                let len = 12 + rng.below(12);
                (0..len)
                    .map(|_| {
                        if rng.uniform() < 0.08 {
                            SEP
                        } else {
                            1 + rng.below(lang.vocab_size - 1) as u32
                        }
                    })
                    .collect()
            };
            Example { tokens: cap(tokens, max_len), label }
        })
        .collect()
}

/// Contradiction marker word id (a reserved mid-frequency lexicon word).
const NEG_MARKER: usize = 2 * N_MARKERS;

pub fn gen_mnli(lang: &Language, n: usize, max_len: usize, rng: &mut Rng) -> Vec<Example> {
    (0..n)
        .map(|_| {
            let label = rng.below(3);
            let (_, premise_ids) = lang.generate_words(5, rng);
            let mut tokens = Vec::new();
            for &w in &premise_ids {
                tokens.extend_from_slice(&lang.words[w]);
                tokens.push(SEP);
            }
            tokens.push(SEP);
            let hyp_ids: Vec<usize> = match label {
                0 => {
                    // Entailment: subset of the premise.
                    let k = 2 + rng.below(2);
                    rng.choose_k(premise_ids.len(), k.min(premise_ids.len()))
                        .into_iter()
                        .map(|i| premise_ids[i])
                        .collect()
                }
                1 => {
                    // Contradiction: premise overlap + negation marker.
                    let mut ids =
                        vec![premise_ids[rng.below(premise_ids.len())], NEG_MARKER];
                    ids.push(premise_ids[rng.below(premise_ids.len())]);
                    ids
                }
                _ => {
                    // Neutral: fresh words (likely disjoint).
                    lang.generate_words(3, rng).1
                }
            };
            for &w in &hyp_ids {
                tokens.extend_from_slice(&lang.words[w]);
                tokens.push(SEP);
            }
            Example { tokens: cap(tokens, max_len), label }
        })
        .collect()
}

pub fn gen_nlu(task: &str, lang: &Language, n: usize, max_len: usize, rng: &mut Rng) -> Vec<Example> {
    match task {
        "sst2" => gen_sst2(lang, n, max_len, rng),
        "mrpc" => gen_mrpc(lang, n, max_len, rng),
        "cola" => gen_cola(lang, n, max_len, rng),
        "mnli" => gen_mnli(lang, n, max_len, rng),
        other => panic!("unknown NLU task {other}"),
    }
}

// ---------------------------------------------------------------- zero-shot

pub fn gen_lambada(lang: &Language, n: usize, max_len: usize, rng: &mut Rng) -> Vec<LambadaExample> {
    (0..n)
        .map(|_| {
            loop {
                let (tokens, ids) = lang.generate_words(8, rng);
                let last_word = &lang.words[*ids.last().unwrap()];
                if last_word.len() >= 2 {
                    // Cut right before the final token of the last word
                    // (tokens end with [..., last_word..., SEP]).
                    let cut = tokens.len() - 2;
                    let target = tokens[cut];
                    let context = cap(tokens[..cut].to_vec(), max_len);
                    return LambadaExample { context, target };
                }
            }
        })
        .collect()
}

pub fn gen_piqa(lang: &Language, n: usize, max_len: usize, rng: &mut Rng) -> Vec<ChoiceExample> {
    (0..n)
        .map(|_| {
            let (prefix, ids) = lang.generate_words(5, rng);
            let label = rng.below(2);
            // True continuation: a plausible successor word; corrupted:
            // random tokens of the same length.
            let succ = lang.next_word_public(*ids.last().unwrap(), rng);
            let good: Vec<u32> = lang.words[succ].clone();
            let bad: Vec<u32> = (0..good.len())
                .map(|_| 1 + rng.below(lang.vocab_size - 1) as u32)
                .collect();
            let choices = if label == 0 { [good, bad] } else { [bad, good] };
            ChoiceExample { prefix: cap(prefix, max_len), choices, label }
        })
        .collect()
}

pub fn gen_winogrande(lang: &Language, n: usize, max_len: usize, rng: &mut Rng) -> Vec<ChoiceExample> {
    (0..n)
        .map(|_| {
            // Longer context; the consistent choice repeats an earlier word
            // (coreference-ish), the inconsistent one is fresh.
            let (prefix, ids) = lang.generate_words(10, rng);
            let label = rng.below(2);
            let referent = ids[rng.below(ids.len())];
            let mut good = lang.words[referent].clone();
            good.push(SEP);
            let fresh = lang.next_word_public(referent, rng);
            let mut bad = lang.words[(fresh + 97) % lang.words.len()].clone();
            bad.push(SEP);
            let choices = if label == 0 { [good, bad] } else { [bad, good] };
            ChoiceExample { prefix: cap(prefix, max_len), choices, label }
        })
        .collect()
}

impl Language {
    /// Public successor sampler for task generators.
    pub fn next_word_public(&self, prev: usize, rng: &mut Rng) -> usize {
        let choices = &self.trans[prev];
        let ws: Vec<f32> = choices.iter().map(|&(_, w)| w).collect();
        choices[rng.categorical(&ws)].0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lang() -> Language {
        Language::new(64, 100, 42)
    }

    #[test]
    fn all_nlu_tasks_generate() {
        let l = lang();
        let mut rng = Rng::new(1);
        for task in NLU_TASKS {
            let ex = gen_nlu(task, &l, 50, 96, &mut rng);
            assert_eq!(ex.len(), 50);
            for e in &ex {
                assert!(e.label < n_classes(task));
                assert!(!e.tokens.is_empty() && e.tokens.len() <= 96);
                assert!(e.tokens.iter().all(|&t| (t as usize) < 64));
            }
            // Both/all classes present.
            let classes: std::collections::HashSet<usize> =
                ex.iter().map(|e| e.label).collect();
            assert_eq!(classes.len(), n_classes(task), "task {task}");
        }
    }

    #[test]
    fn sst2_markers_separate_classes() {
        let l = lang();
        let mut rng = Rng::new(2);
        let ex = gen_sst2(&l, 200, 96, &mut rng);
        // Count positive-marker tokens per class: label-1 examples should
        // contain far more of them.
        let marker_tokens: std::collections::HashSet<u32> = (0..N_MARKERS)
            .flat_map(|w| l.words[w].clone())
            .collect();
        let score = |e: &Example| {
            e.tokens.iter().filter(|t| marker_tokens.contains(t)).count() as f64
        };
        let pos: f64 = ex.iter().filter(|e| e.label == 1).map(score).sum();
        let neg: f64 = ex.iter().filter(|e| e.label == 0).map(score).sum();
        assert!(pos > 1.5 * neg, "pos={pos} neg={neg}");
    }

    #[test]
    fn lambada_targets_are_predictable_in_principle() {
        let l = lang();
        let mut rng = Rng::new(3);
        let ex = gen_lambada(&l, 100, 120, &mut rng);
        for e in &ex {
            assert!(!e.context.is_empty());
            assert!((e.target as usize) < 64);
            assert_ne!(e.target, SEP);
        }
    }

    #[test]
    fn choice_tasks_balanced() {
        let l = lang();
        let mut rng = Rng::new(4);
        let piqa = gen_piqa(&l, 200, 96, &mut rng);
        let ones = piqa.iter().filter(|e| e.label == 1).count();
        assert!(ones > 60 && ones < 140, "ones={ones}");
        let wino = gen_winogrande(&l, 100, 120, &mut rng);
        for e in &wino {
            assert_ne!(e.choices[0], e.choices[1]);
        }
    }

    #[test]
    fn deterministic_generation() {
        let l = lang();
        let a = gen_mnli(&l, 20, 96, &mut Rng::new(9));
        let b = gen_mnli(&l, 20, 96, &mut Rng::new(9));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.label, y.label);
        }
    }
}
