//! Synthetic data: the corpus (WikiText/C4 stand-in), the NLU/NLG task
//! generators (GLUE / LAMBADA / PIQA / WinoGrande analogs), and the JSON
//! export consumed by the JAX pretrainer.

pub mod corpus;
pub mod export;
pub mod tasks;

pub use corpus::{Corpus, Language, SEP};
pub use tasks::{ChoiceExample, Example, LambadaExample};
