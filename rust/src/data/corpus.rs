//! Synthetic corpus — the stand-in for WikiText/C4 (DESIGN.md §2).
//!
//! A "language" is a Zipf-weighted lexicon of multi-token words over a byte
//! vocabulary, emitted with first-order word-level Markov structure and a
//! separator token. The corpus is learnable by a small LM (so PPL deltas
//! under compression are meaningful), deterministic from a seed, and admits
//! a natural LAMBADA analog (predict a word's final token from its prefix).
//!
//! Token map: 0 = separator, 1..vocab-1 = content tokens.

use crate::util::Rng;

pub const SEP: u32 = 0;

/// A synthetic language: lexicon + Markov transitions.
#[derive(Debug, Clone)]
pub struct Language {
    pub vocab_size: usize,
    /// Lexicon words (each 2..=5 tokens, no separator inside).
    pub words: Vec<Vec<u32>>,
    /// Unnormalized Zipf weights per word.
    pub weights: Vec<f32>,
    /// Markov transition weights between words: trans[i] lists (j, w).
    pub trans: Vec<Vec<(usize, f32)>>,
}

impl Language {
    /// Build a language with `n_words` lexicon entries.
    pub fn new(vocab_size: usize, n_words: usize, seed: u64) -> Language {
        let mut rng = Rng::new(seed);
        let mut words = Vec::with_capacity(n_words);
        let mut seen = std::collections::HashSet::new();
        while words.len() < n_words {
            let len = 2 + rng.below(4);
            let w: Vec<u32> = (0..len)
                .map(|_| 1 + rng.below(vocab_size - 1) as u32)
                .collect();
            if seen.insert(w.clone()) {
                words.push(w);
            }
        }
        // Zipf weights: w_i ∝ 1 / rank.
        let weights: Vec<f32> = (0..n_words).map(|i| 1.0 / (i + 1) as f32).collect();
        // Sparse Markov structure: each word links to ~8 successors.
        let fanout = 8.min(n_words);
        let trans = (0..n_words)
            .map(|_| {
                let succ = rng.choose_k(n_words, fanout);
                succ.into_iter()
                    .map(|j| (j, rng.uniform_in(0.2, 1.0) * weights[j]))
                    .collect()
            })
            .collect();
        Language { vocab_size, words, weights, trans }
    }

    /// Sample a word index following `prev` (or from the unigram prior).
    fn next_word(&self, prev: Option<usize>, rng: &mut Rng) -> usize {
        match prev {
            Some(p) => {
                let choices = &self.trans[p];
                let ws: Vec<f32> = choices.iter().map(|&(_, w)| w).collect();
                choices[rng.categorical(&ws)].0
            }
            None => rng.categorical(&self.weights),
        }
    }

    /// Generate a token stream of (at least) `n_tokens` tokens.
    pub fn generate(&self, n_tokens: usize, rng: &mut Rng) -> Vec<u32> {
        let mut out = Vec::with_capacity(n_tokens + 8);
        let mut prev = None;
        while out.len() < n_tokens {
            let w = self.next_word(prev, rng);
            out.extend_from_slice(&self.words[w]);
            out.push(SEP);
            prev = Some(w);
        }
        out.truncate(n_tokens);
        out
    }

    /// Generate a sequence of exactly `n_words` words (with separators).
    pub fn generate_words(&self, n_words: usize, rng: &mut Rng) -> (Vec<u32>, Vec<usize>) {
        let mut tokens = Vec::new();
        let mut word_ids = Vec::with_capacity(n_words);
        let mut prev = None;
        for _ in 0..n_words {
            let w = self.next_word(prev, rng);
            tokens.extend_from_slice(&self.words[w]);
            tokens.push(SEP);
            word_ids.push(w);
            prev = Some(w);
        }
        (tokens, word_ids)
    }
}

/// Train/validation corpus with fixed-length windows.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub language: Language,
    pub train: Vec<u32>,
    pub valid: Vec<u32>,
}

impl Corpus {
    pub fn generate(vocab_size: usize, train_tokens: usize, valid_tokens: usize, seed: u64) -> Corpus {
        let language = Language::new(vocab_size, 200, seed);
        let mut rng = Rng::new(seed ^ 0xDA7A);
        let train = language.generate(train_tokens, &mut rng);
        let valid = language.generate(valid_tokens, &mut rng);
        Corpus { language, train, valid }
    }

    /// Non-overlapping windows of length `len` from a stream.
    pub fn windows(stream: &[u32], len: usize) -> Vec<&[u32]> {
        stream.chunks_exact(len).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let a = Corpus::generate(64, 1000, 200, 7);
        let b = Corpus::generate(64, 1000, 200, 7);
        assert_eq!(a.train, b.train);
        assert_eq!(a.valid, b.valid);
        let c = Corpus::generate(64, 1000, 200, 8);
        assert_ne!(a.train, c.train);
    }

    #[test]
    fn tokens_in_range() {
        let c = Corpus::generate(64, 2000, 100, 1);
        assert_eq!(c.train.len(), 2000);
        assert!(c.train.iter().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn zipf_head_words_dominate() {
        let lang = Language::new(64, 100, 2);
        let mut rng = Rng::new(3);
        let (_, word_ids) = lang.generate_words(5000, &mut rng);
        let head = word_ids.iter().filter(|&&w| w < 10).count();
        let tail = word_ids.iter().filter(|&&w| w >= 90).count();
        assert!(head > 5 * tail.max(1), "head={head} tail={tail}");
    }

    #[test]
    fn corpus_is_learnable_structure() {
        // Bigram statistics must be far from uniform (so an LM can learn).
        let c = Corpus::generate(32, 20_000, 100, 4);
        let mut counts = vec![vec![0u32; 32]; 32];
        for w in c.train.windows(2) {
            counts[w[0] as usize][w[1] as usize] += 1;
        }
        // For several contexts the max successor should dominate uniform.
        let mut peaked = 0;
        for row in &counts {
            let total: u32 = row.iter().sum();
            if total > 50 {
                let max = *row.iter().max().unwrap();
                if max as f64 > 4.0 * total as f64 / 32.0 {
                    peaked += 1;
                }
            }
        }
        assert!(peaked > 5, "peaked={peaked}");
    }

    #[test]
    fn windows_are_exact() {
        let c = Corpus::generate(32, 1000, 512, 5);
        let w = Corpus::windows(&c.valid, 128);
        assert_eq!(w.len(), 4);
        assert!(w.iter().all(|x| x.len() == 128));
    }
}
