//! Binary checkpoint format shared between the JAX pretrainer
//! (`python/compile/checkpoint.py`) and the Rust runtime.
//!
//! Layout:
//! ```text
//! magic  b"RMW1"
//! u32 LE header length
//! JSON header {"config": {...}, "tensors": [{name, rows, cols, offset}]}
//! f32 LE tensor blob (offsets are element offsets into the blob)
//! ```
//! Vectors are stored as 1×n tensors. Tensor names follow the module path,
//! e.g. `blocks.3.ffn.experts.5.w1` — both writers must agree, which the
//! python tests assert by round-tripping through this loader.

use super::attention::Attention;
use super::config::{ExpertArch, ModelConfig};
use super::expert::ExpertWeights;
use super::layer::MoeLayer;
use super::router::Router;
use super::transformer::{Block, Ffn, Model};
use crate::tensor::Matrix;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"RMW1";
/// Magic of the zstd-wrapped variant: an RMW1 payload inside one zstd frame.
const MAGIC_Z: &[u8; 4] = b"RMWZ";

/// A parsed checkpoint: config + named tensors.
pub struct Checkpoint {
    pub config: ModelConfig,
    pub tensors: BTreeMap<String, Matrix>,
}

// ---------------------------------------------------------------- writing

fn collect_tensors(model: &Model) -> Vec<(String, Matrix)> {
    let vec_mat = |v: &[f32]| Matrix::from_vec(1, v.len(), v.to_vec());
    let mut out: Vec<(String, Matrix)> = vec![
        ("embed".into(), model.embed.clone()),
        ("pos".into(), model.pos.clone()),
        ("lm_head".into(), model.lm_head.clone()),
        ("final_norm".into(), vec_mat(&model.final_norm)),
    ];
    let push_expert = |out: &mut Vec<(String, Matrix)>, prefix: &str, e: &ExpertWeights| {
        out.push((format!("{prefix}.w1"), e.w1.clone()));
        out.push((format!("{prefix}.b1"), vec_mat(&e.b1)));
        if let Some(w3) = &e.w3 {
            out.push((format!("{prefix}.w3"), w3.clone()));
        }
        if let Some(b3) = &e.b3 {
            out.push((format!("{prefix}.b3"), vec_mat(b3)));
        }
        out.push((format!("{prefix}.w2"), e.w2.clone()));
        out.push((format!("{prefix}.b2"), vec_mat(&e.b2)));
    };
    for (i, b) in model.blocks.iter().enumerate() {
        let p = format!("blocks.{i}");
        out.push((format!("{p}.norm1"), vec_mat(&b.norm1)));
        out.push((format!("{p}.norm2"), vec_mat(&b.norm2)));
        out.push((format!("{p}.attn.wq"), b.attn.wq.clone()));
        out.push((format!("{p}.attn.wk"), b.attn.wk.clone()));
        out.push((format!("{p}.attn.wv"), b.attn.wv.clone()));
        out.push((format!("{p}.attn.wo"), b.attn.wo.clone()));
        match &b.ffn {
            Ffn::Dense(e) => push_expert(&mut out, &format!("{p}.ffn.dense"), e),
            Ffn::Moe(l) => {
                out.push((format!("{p}.ffn.router.w_g"), l.router.w_g.clone()));
                for (k, e) in l.experts.iter().enumerate() {
                    push_expert(&mut out, &format!("{p}.ffn.experts.{k}"), e);
                }
                if let Some(se) = &l.shared_expert {
                    push_expert(&mut out, &format!("{p}.ffn.shared"), se);
                }
            }
        }
    }
    for (name, head) in &model.heads {
        out.push((format!("head.{name}"), head.clone()));
    }
    out
}

/// Serialize a model to an in-memory RMW1 byte buffer — the unit the
/// sharded artifact store embeds as its backbone shard.
pub fn model_to_bytes(model: &Model) -> Vec<u8> {
    let tensors = collect_tensors(model);
    let mut dir = Vec::new();
    let mut offset = 0usize;
    for (name, m) in &tensors {
        dir.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("rows", Json::num(m.rows as f64)),
            ("cols", Json::num(m.cols as f64)),
            ("offset", Json::num(offset as f64)),
        ]));
        offset += m.n_params();
    }
    let header = Json::obj(vec![
        ("config", model.cfg.to_json()),
        ("tensors", Json::Arr(dir)),
    ])
    .to_string();
    let mut out = Vec::with_capacity(8 + header.len() + offset * 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for (_, m) in &tensors {
        for v in &m.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Serialize a model to the RMW1 format.
pub fn save_model(model: &Model, path: &Path) -> Result<()> {
    let bytes = model_to_bytes(model);
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    f.write_all(&bytes)?;
    f.flush()?;
    Ok(())
}

/// Serialize a model zstd-compressed (`RMWZ`): the space-efficient on-disk
/// companion of the in-memory compression — trained MoE weights typically
/// shrink a further ~10–25 % losslessly. `load_checkpoint` reads both
/// formats transparently.
pub fn save_model_compressed(model: &Model, path: &Path, level: i32) -> Result<()> {
    let raw = model_to_bytes(model);
    let compressed = zstd::encode_all(&raw[..], level).context("zstd encode")?;
    let mut out = Vec::with_capacity(compressed.len() + 4);
    out.extend_from_slice(MAGIC_Z);
    out.extend_from_slice(&compressed);
    std::fs::write(path, out).with_context(|| format!("write {}", path.display()))?;
    Ok(())
}

// ---------------------------------------------------------------- reading

/// Parse an RMW1 (or zstd-wrapped RMWZ) checkpoint file.
pub fn load_checkpoint(path: &Path) -> Result<Checkpoint> {
    let head = {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        magic
    };
    if &head == MAGIC_Z {
        let raw = std::fs::read(path)?;
        let inner = zstd::decode_all(&raw[4..]).context("zstd decode")?;
        return load_checkpoint_bytes(&inner, path);
    }
    let bytes = std::fs::read(path)?;
    load_checkpoint_bytes(&bytes, path)
}

fn load_checkpoint_bytes(bytes: &[u8], path: &Path) -> Result<Checkpoint> {
    let mut f = std::io::BufReader::new(bytes);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad magic (not an RMW1 checkpoint)", path.display());
    }
    let mut len_buf = [0u8; 4];
    f.read_exact(&mut len_buf)?;
    let header_len = u32::from_le_bytes(len_buf) as usize;
    let mut header_bytes = vec![0u8; header_len];
    f.read_exact(&mut header_bytes)?;
    let header = Json::parse(std::str::from_utf8(&header_bytes)?)
        .map_err(|e| anyhow!("bad header json: {e}"))?;
    let config = ModelConfig::from_json(
        header.get("config").ok_or_else(|| anyhow!("header missing config"))?,
    )?;
    let mut blob = Vec::new();
    f.read_to_end(&mut blob)?;
    if blob.len() % 4 != 0 {
        bail!("tensor blob not a multiple of 4 bytes");
    }
    let floats: Vec<f32> = blob
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let mut tensors = BTreeMap::new();
    for t in header
        .get("tensors")
        .and_then(|t| t.as_arr())
        .ok_or_else(|| anyhow!("header missing tensors"))?
    {
        let name = t.get("name").and_then(|v| v.as_str()).ok_or_else(|| anyhow!("tensor name"))?;
        let rows = t.get("rows").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("rows"))?;
        let cols = t.get("cols").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("cols"))?;
        let offset = t.get("offset").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("offset"))?;
        let n = rows * cols;
        if offset + n > floats.len() {
            bail!("tensor {name} out of range ({offset}+{n} > {})", floats.len());
        }
        tensors.insert(
            name.to_string(),
            Matrix::from_vec(rows, cols, floats[offset..offset + n].to_vec()),
        );
    }
    Ok(Checkpoint { config, tensors })
}

fn take_mat(t: &mut BTreeMap<String, Matrix>, name: &str) -> Result<Matrix> {
    t.remove(name).ok_or_else(|| anyhow!("checkpoint missing tensor '{name}'"))
}

fn take_vec(t: &mut BTreeMap<String, Matrix>, name: &str) -> Result<Vec<f32>> {
    Ok(take_mat(t, name)?.data.into_vec())
}

fn take_expert(
    t: &mut BTreeMap<String, Matrix>,
    prefix: &str,
    arch: ExpertArch,
) -> Result<ExpertWeights> {
    Ok(ExpertWeights {
        arch,
        w1: take_mat(t, &format!("{prefix}.w1"))?,
        b1: take_vec(t, &format!("{prefix}.b1"))?,
        w3: match arch {
            ExpertArch::SwiGlu => Some(take_mat(t, &format!("{prefix}.w3"))?),
            ExpertArch::Relu => None,
        },
        b3: match arch {
            ExpertArch::SwiGlu => Some(take_vec(t, &format!("{prefix}.b3"))?),
            ExpertArch::Relu => None,
        },
        w2: take_mat(t, &format!("{prefix}.w2"))?,
        b2: take_vec(t, &format!("{prefix}.b2"))?,
    })
}

/// Materialize a [`Model`] from a checkpoint.
pub fn load_model(path: &Path) -> Result<Model> {
    model_from_checkpoint(load_checkpoint(path)?)
}

/// Materialize a [`Model`] from in-memory RMW1 bytes (the store's backbone
/// shard after decompression).
pub fn model_from_bytes(bytes: &[u8]) -> Result<Model> {
    model_from_checkpoint(load_checkpoint_bytes(bytes, Path::new("<memory>"))?)
}

fn model_from_checkpoint(ckpt: Checkpoint) -> Result<Model> {
    let Checkpoint { config: cfg, mut tensors } = ckpt;
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let p = format!("blocks.{i}");
        let ffn = if cfg.is_moe_layer(i) {
            let router = Router {
                w_g: take_mat(&mut tensors, &format!("{p}.ffn.router.w_g"))?,
                top_k: cfg.top_k,
            };
            let experts = (0..cfg.n_experts)
                .map(|k| take_expert(&mut tensors, &format!("{p}.ffn.experts.{k}"), cfg.arch))
                .collect::<Result<Vec<_>>>()?;
            let shared_expert = if cfg.shared_expert {
                Some(take_expert(&mut tensors, &format!("{p}.ffn.shared"), cfg.arch)?)
            } else {
                None
            };
            Ffn::Moe(MoeLayer { router, experts, shared_expert })
        } else {
            Ffn::Dense(take_expert(&mut tensors, &format!("{p}.ffn.dense"), cfg.arch)?)
        };
        blocks.push(Block {
            norm1: take_vec(&mut tensors, &format!("{p}.norm1"))?,
            attn: Attention {
                wq: take_mat(&mut tensors, &format!("{p}.attn.wq"))?,
                wk: take_mat(&mut tensors, &format!("{p}.attn.wk"))?,
                wv: take_mat(&mut tensors, &format!("{p}.attn.wv"))?,
                wo: take_mat(&mut tensors, &format!("{p}.attn.wo"))?,
                n_heads: cfg.n_heads,
            },
            norm2: take_vec(&mut tensors, &format!("{p}.norm2"))?,
            ffn,
        });
    }
    let embed = take_mat(&mut tensors, "embed")?;
    let pos = take_mat(&mut tensors, "pos")?;
    let lm_head = take_mat(&mut tensors, "lm_head")?;
    let final_norm = take_vec(&mut tensors, "final_norm")?;
    // Remaining `head.*` tensors become classification heads.
    let heads: Vec<(String, Matrix)> = tensors
        .iter()
        .filter(|(k, _)| k.starts_with("head."))
        .map(|(k, v)| (k.trim_start_matches("head.").to_string(), v.clone()))
        .collect();
    Ok(Model { cfg, embed, pos, blocks, final_norm, lm_head, heads })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("resmoe-test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn models_equal(a: &Model, b: &Model) -> bool {
        if a.n_params() != b.n_params() {
            return false;
        }
        let fa = a.forward(&[1, 5, 3, 2]);
        let fb = b.forward(&[1, 5, 3, 2]);
        fa.sq_dist(&fb) < 1e-10
    }

    #[test]
    fn roundtrip_switch_style() {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        let mut rng = Rng::new(1);
        let m = Model::random(&cfg, &mut rng);
        let path = tmp("roundtrip_switch.bin");
        save_model(&m, &path).unwrap();
        let m2 = load_model(&path).unwrap();
        assert!(models_equal(&m, &m2));
        assert_eq!(m2.cfg.name, cfg.name);
    }

    #[test]
    fn roundtrip_swiglu_with_shared_expert_and_heads() {
        let mut cfg = ModelConfig::deepseek_mini();
        cfg.d_model = 16;
        cfg.d_inner = 11;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        cfg.n_experts = 4;
        cfg.top_k = 2;
        let mut rng = Rng::new(2);
        let mut m = Model::random(&cfg, &mut rng);
        m.heads.push(("sst2".into(), Matrix::randn(2, 16, 0.1, &mut rng)));
        m.heads.push(("nli".into(), Matrix::randn(3, 16, 0.1, &mut rng)));
        let path = tmp("roundtrip_ds.bin");
        save_model(&m, &path).unwrap();
        let m2 = load_model(&path).unwrap();
        assert!(models_equal(&m, &m2));
        assert_eq!(m2.heads.len(), 2);
        assert!(m2.head("sst2").is_some());
        let h1 = m.head("nli").unwrap();
        let h2 = m2.head("nli").unwrap();
        assert!(h1.sq_dist(h2) < 1e-12);
    }

    #[test]
    fn zstd_roundtrip_and_shrinks() {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        let mut rng = Rng::new(9);
        let m = Model::random(&cfg, &mut rng);
        let plain = tmp("zstd_plain.rmw");
        let packed = tmp("zstd_packed.rmwz");
        save_model(&m, &plain).unwrap();
        save_model_compressed(&m, &packed, 3).unwrap();
        let m2 = load_model(&packed).unwrap();
        assert!(models_equal(&m, &m2));
        let plain_len = std::fs::metadata(&plain).unwrap().len();
        let packed_len = std::fs::metadata(&packed).unwrap().len();
        assert!(
            packed_len < plain_len,
            "compressed {packed_len} should be below plain {plain_len}"
        );
    }

    #[test]
    fn bytes_roundtrip_without_disk() {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        let mut rng = Rng::new(17);
        let m = Model::random(&cfg, &mut rng);
        let m2 = model_from_bytes(&model_to_bytes(&m)).unwrap();
        assert!(models_equal(&m, &m2));
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad_magic.bin");
        std::fs::write(&path, b"NOPE----").unwrap();
        assert!(load_model(&path).is_err());
    }

    #[test]
    fn rejects_truncated_blob() {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        let mut rng = Rng::new(3);
        let m = Model::random(&cfg, &mut rng);
        let path = tmp("truncated.bin");
        save_model(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 64]).unwrap();
        assert!(load_model(&path).is_err());
    }

    #[test]
    fn missing_tensor_reports_name() {
        // Handcraft a header that references a tensor not covered by blob.
        let path = tmp("missing.bin");
        let cfg = ModelConfig::switch_mini(4);
        let header = Json::obj(vec![
            ("config", cfg.to_json()),
            (
                "tensors",
                Json::Arr(vec![Json::obj(vec![
                    ("name", Json::str("embed")),
                    ("rows", Json::num(4.0)),
                    ("cols", Json::num(4.0)),
                    ("offset", Json::num(0.0)),
                ])]),
            ),
        ])
        .to_string();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&[0u8; 16 * 4]); // enough for embed only
        std::fs::write(&path, bytes).unwrap();
        let err = load_model(&path).unwrap_err().to_string();
        assert!(err.contains("missing tensor"), "err: {err}");
    }
}
