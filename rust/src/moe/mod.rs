//! The MoE transformer substrate being compressed: expert MLPs with their
//! design-matrix (distributional) view, top-k routing, attention, the full
//! decoder-only LM, and the checkpoint format shared with the JAX
//! pretrainer.

pub mod attention;
pub mod config;
pub mod expert;
pub mod layer;
pub mod model_io;
pub mod router;
pub mod transformer;

pub use attention::{
    kv_lease_bytes, kv_token_bytes, KvCache, KvLease, KvPagePool, KV_PAGE_TOKENS,
};
pub use config::{ExpertArch, ExpertInit, ModelConfig};
pub use expert::{ExpertForward, ExpertWeights};
pub use layer::{
    combine_slot_output, gather_rows, group_parts, route_dispatch_combine, route_groups,
    MoeLayer,
};
pub use router::{Route, Router, RouterStats};
pub use transformer::{Block, Ffn, FfnHook, Model, NoHook};
