//! Top-k softmax router (paper §3.1: `G(x) = Softmax(TopK(W_g x))`).

use crate::tensor::Matrix;
use crate::util::stats::{softmax, top_k_indices};
use crate::util::Rng;

/// Router gate network for one MoE layer.
#[derive(Debug, Clone, PartialEq)]
pub struct Router {
    /// `N × p` logit projection.
    pub w_g: Matrix,
    pub top_k: usize,
}

/// Routing decision for a single token.
#[derive(Debug, Clone, PartialEq)]
pub struct Route {
    /// Selected expert indices (length top_k, descending by logit).
    pub experts: Vec<usize>,
    /// Softmax-normalized weights over the selected experts (sums to 1).
    pub weights: Vec<f32>,
}

impl Router {
    pub fn random(n_experts: usize, p: usize, top_k: usize, rng: &mut Rng) -> Router {
        Router {
            w_g: Matrix::randn(n_experts, p, 1.0 / (p as f32).sqrt(), rng),
            top_k,
        }
    }

    pub fn n_experts(&self) -> usize {
        self.w_g.rows
    }

    /// Route one token.
    pub fn route(&self, x: &[f32]) -> Route {
        let logits = self.w_g.matvec(x);
        self.route_logits(&logits)
    }

    /// Route from precomputed logits (used by the batched layer forward).
    pub fn route_logits(&self, logits: &[f32]) -> Route {
        let experts = top_k_indices(logits, self.top_k);
        let selected: Vec<f32> = experts.iter().map(|&e| logits[e]).collect();
        let weights = softmax(&selected);
        Route { experts, weights }
    }

    /// Batched logits for `x` (B × p) → (B × N).
    pub fn logits(&self, x: &Matrix) -> Matrix {
        x.matmul_nt(&self.w_g)
    }
}

/// Accumulated router statistics — activation frequency and mean gate score
/// per expert. Drives the usage-based baselines (expert pruning, M-SMoE
/// grouping) and the coordinator's prefetch policy.
#[derive(Debug, Clone, Default)]
pub struct RouterStats {
    pub activations: Vec<u64>,
    pub weight_sums: Vec<f64>,
    pub tokens: u64,
}

impl RouterStats {
    pub fn new(n_experts: usize) -> RouterStats {
        RouterStats {
            activations: vec![0; n_experts],
            weight_sums: vec![0.0; n_experts],
            tokens: 0,
        }
    }

    pub fn record(&mut self, route: &Route) {
        self.tokens += 1;
        for (e, w) in route.experts.iter().zip(&route.weights) {
            self.activations[*e] += 1;
            self.weight_sums[*e] += *w as f64;
        }
    }

    /// Activation frequency per expert (fraction of tokens that used it).
    pub fn frequency(&self) -> Vec<f64> {
        self.activations
            .iter()
            .map(|&a| a as f64 / self.tokens.max(1) as f64)
            .collect()
    }

    /// Experts sorted by ascending usage (first = least used).
    pub fn by_ascending_usage(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.activations.len()).collect();
        idx.sort_by(|&a, &b| {
            self.weight_sums[a]
                .partial_cmp(&self.weight_sums[b])
                .unwrap()
                .then(a.cmp(&b))
        });
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_selects_top_logits() {
        let mut rng = Rng::new(1);
        let r = Router::random(8, 16, 2, &mut rng);
        let x = rng.normal_vec(16, 1.0);
        let logits = r.w_g.matvec(&x);
        let route = r.route(&x);
        assert_eq!(route.experts.len(), 2);
        // The two selected logits dominate all others.
        let min_sel = route.experts.iter().map(|&e| logits[e]).fold(f32::INFINITY, f32::min);
        for (i, &l) in logits.iter().enumerate() {
            if !route.experts.contains(&i) {
                assert!(l <= min_sel + 1e-6);
            }
        }
    }

    #[test]
    fn weights_sum_to_one_and_ordered() {
        let mut rng = Rng::new(2);
        let r = Router::random(8, 16, 3, &mut rng);
        for _ in 0..20 {
            let x = rng.normal_vec(16, 1.0);
            let route = r.route(&x);
            let s: f32 = route.weights.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            for w in route.weights.windows(2) {
                assert!(w[0] >= w[1] - 1e-6);
            }
        }
    }

    #[test]
    fn top1_routing_weight_is_one() {
        let mut rng = Rng::new(3);
        let r = Router::random(4, 8, 1, &mut rng);
        let x = rng.normal_vec(8, 1.0);
        let route = r.route(&x);
        assert_eq!(route.experts.len(), 1);
        assert!((route.weights[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn batched_logits_match_single() {
        let mut rng = Rng::new(4);
        let r = Router::random(6, 10, 2, &mut rng);
        let x = Matrix::randn(5, 10, 1.0, &mut rng);
        let logits = r.logits(&x);
        for b in 0..5 {
            let single = r.w_g.matvec(x.row(b));
            for e in 0..6 {
                assert!((logits.at(b, e) - single[e]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn stats_accumulate() {
        let mut stats = RouterStats::new(4);
        stats.record(&Route { experts: vec![1, 2], weights: vec![0.7, 0.3] });
        stats.record(&Route { experts: vec![1, 0], weights: vec![0.6, 0.4] });
        assert_eq!(stats.tokens, 2);
        assert_eq!(stats.activations, vec![1, 2, 1, 0]);
        assert!((stats.frequency()[1] - 1.0).abs() < 1e-12);
        assert_eq!(stats.by_ascending_usage()[0], 3);
    }
}
