//! Expert MLPs and their design-matrix (distributional) view.
//!
//! The paper's key representational move (§4.2, Eq. 3) is that an MLP is an
//! order-invariant ensemble of bottleneck-1 sub-MLPs: row `i` of `W1` (and
//! `b1`, and `W3/b3` for gated experts) together with column `i` of `W2`
//! form one sub-MLP. Concatenating them row-wise gives the *design matrix*
//! `W_k = [W1, b1, (W3, b3,) W2^T] ∈ R^{pI×D}`; permuting its rows leaves
//! the expert's function unchanged. The barycenter is computed over these
//! design matrices.

use super::config::ExpertArch;
use crate::tensor::kernel;
use crate::tensor::Matrix;
use crate::util::Rng;

// The activation/bias tier lives in the runtime-dispatched kernel layer
// since PR 5 (scalar twin or AVX2 per `RESMOE_SIMD`); re-exported here so
// the historical `moe::expert::{silu, add_bias_rows}` paths keep working.
pub use crate::tensor::kernel::{add_bias_rows, silu};

/// The one interface every expert representation serves tokens through —
/// dense restored weights ([`ExpertWeights`]) and the restore-free fused
/// path (`compress::formats::FusedSlot`) both implement it, so the MoE
/// layer and the serving hook dispatch without knowing which backing a
/// slot has.
pub trait ExpertForward {
    /// Forward a token batch `x` (B × p) → (B × p).
    fn expert_forward(&self, x: &Matrix) -> Matrix;
}

impl ExpertForward for ExpertWeights {
    fn expert_forward(&self, x: &Matrix) -> Matrix {
        self.forward(x)
    }
}

/// Weights of one expert MLP.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpertWeights {
    pub arch: ExpertArch,
    /// `pI × p` — rows are sub-MLP input weights.
    pub w1: Matrix,
    /// `pI`.
    pub b1: Vec<f32>,
    /// Gated path (`SwiGlu` only): `pI × p` / `pI`.
    pub w3: Option<Matrix>,
    pub b3: Option<Vec<f32>>,
    /// `p × pI` — columns are sub-MLP output weights.
    pub w2: Matrix,
    /// `p`.
    pub b2: Vec<f32>,
}

impl ExpertWeights {
    pub fn d_model(&self) -> usize {
        self.w1.cols
    }

    pub fn d_inner(&self) -> usize {
        self.w1.rows
    }

    /// Random expert; std follows 1/sqrt(fan_in).
    pub fn random(arch: ExpertArch, p: usize, pi: usize, rng: &mut Rng) -> ExpertWeights {
        let s1 = 1.0 / (p as f32).sqrt();
        let s2 = 1.0 / (pi as f32).sqrt();
        ExpertWeights {
            arch,
            w1: Matrix::randn(pi, p, s1, rng),
            b1: vec![0.0; pi],
            w3: (arch == ExpertArch::SwiGlu).then(|| Matrix::randn(pi, p, s1, rng)),
            b3: (arch == ExpertArch::SwiGlu).then(|| vec![0.0; pi]),
            w2: Matrix::randn(p, pi, s2, rng),
            b2: vec![0.0; p],
        }
    }

    /// Clone with i.i.d. Gaussian noise added — models Mixtral's upcycled
    /// ("copy-and-paste then diverge") expert initialization.
    pub fn perturbed(&self, noise_std: f32, rng: &mut Rng) -> ExpertWeights {
        let jitter = |m: &Matrix, rng: &mut Rng| {
            let mut out = m.clone();
            for v in out.data.iter_mut() {
                *v += rng.normal_scaled(noise_std);
            }
            out
        };
        let jitter_vec = |v: &[f32], rng: &mut Rng| -> Vec<f32> {
            v.iter().map(|x| x + rng.normal_scaled(noise_std)).collect()
        };
        ExpertWeights {
            arch: self.arch,
            w1: jitter(&self.w1, rng),
            b1: jitter_vec(&self.b1, rng),
            w3: self.w3.as_ref().map(|m| jitter(m, rng)),
            b3: self.b3.as_ref().map(|v| jitter_vec(v, rng)),
            w2: jitter(&self.w2, rng),
            b2: jitter_vec(&self.b2, rng),
        }
    }

    /// Forward pass over a batch `x` (B × p) → (B × p).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let mut h = x.matmul_nt(&self.w1); // B × pI
        add_bias_rows(&mut h, &self.b1);
        match self.arch {
            ExpertArch::Relu => kernel::relu_inplace(&mut h),
            ExpertArch::SwiGlu => {
                let w3 = self.w3.as_ref().expect("SwiGlu expert missing w3");
                let b3 = self.b3.as_ref().expect("SwiGlu expert missing b3");
                let mut g = x.matmul_nt(w3);
                add_bias_rows(&mut g, b3);
                kernel::silu_mul(&mut h, &g);
            }
        }
        let mut out = h.matmul_nt(&self.w2); // B × p
        add_bias_rows(&mut out, &self.b2);
        out
    }

    /// Number of parameters (incl. biases).
    pub fn n_params(&self) -> usize {
        let mut n = self.w1.n_params() + self.b1.len() + self.w2.n_params() + self.b2.len();
        if let Some(w3) = &self.w3 {
            n += w3.n_params();
        }
        if let Some(b3) = &self.b3 {
            n += b3.len();
        }
        n
    }

    // -------------------------------------------------- design-matrix view
    /// Column width of the design matrix: p+1 (+p+1 gated) + p.
    pub fn design_cols(arch: ExpertArch, p: usize) -> usize {
        match arch {
            ExpertArch::Relu => 2 * p + 1,
            ExpertArch::SwiGlu => 3 * p + 2,
        }
    }

    /// `W_k = [W1, b1, (W3, b3,) W2^T]` — the row-permutable representation
    /// (paper §4.2 and App. B.3). `b2` is excluded (it is not part of the
    /// row-sum, Eq. 3) and carried alongside.
    pub fn design_matrix(&self) -> Matrix {
        let pi = self.d_inner();
        let b1 = Matrix::from_vec(pi, 1, self.b1.clone());
        let mut dm = self.w1.hcat(&b1);
        if let (Some(w3), Some(b3)) = (&self.w3, &self.b3) {
            let b3m = Matrix::from_vec(pi, 1, b3.clone());
            dm = dm.hcat(w3).hcat(&b3m);
        }
        dm.hcat(&self.w2.transpose())
    }

    /// Rebuild an expert from a design matrix (inverse of
    /// [`Self::design_matrix`]); `b2` is supplied separately.
    pub fn from_design_matrix(
        arch: ExpertArch,
        p: usize,
        dm: &Matrix,
        b2: Vec<f32>,
    ) -> ExpertWeights {
        assert_eq!(dm.cols, Self::design_cols(arch, p), "design matrix width");
        let pi = dm.rows;
        let w1 = dm.slice_cols(0, p);
        // col_into: this runs once per restore-cache miss; the strided
        // in-place copy avoids the per-call Vec the old col() allocated.
        let mut b1 = vec![0.0f32; pi];
        dm.col_into(p, &mut b1);
        let (w3, b3, w2t_off) = match arch {
            ExpertArch::Relu => (None, None, p + 1),
            ExpertArch::SwiGlu => {
                let w3 = dm.slice_cols(p + 1, 2 * p + 1);
                let mut b3 = vec![0.0f32; pi];
                dm.col_into(2 * p + 1, &mut b3);
                (Some(w3), Some(b3), 2 * p + 2)
            }
        };
        let w2 = dm.slice_cols(w2t_off, dm.cols).transpose();
        ExpertWeights { arch, w1, b1, w3, b3, w2, b2 }
    }

    /// Apply a sub-MLP permutation: `perm[i] = j` moves sub-MLP `j` to slot
    /// `i` (rows of W1/b1/W3/b3, columns of W2). Function-preserving.
    pub fn permuted(&self, perm: &[usize]) -> ExpertWeights {
        let inv_col_perm = perm; // permute_cols uses out[:, i] = in[:, perm[i]]
        ExpertWeights {
            arch: self.arch,
            w1: self.w1.permute_rows(perm),
            b1: perm.iter().map(|&j| self.b1[j]).collect(),
            w3: self.w3.as_ref().map(|m| m.permute_rows(perm)),
            b3: self.b3.as_ref().map(|v| perm.iter().map(|&j| v[j]).collect()),
            w2: self.w2.permute_cols(inv_col_perm),
            b2: self.b2.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(arch: ExpertArch, seed: u64) -> (ExpertWeights, Rng) {
        let mut rng = Rng::new(seed);
        let e = ExpertWeights::random(arch, 8, 12, &mut rng);
        (e, rng)
    }

    #[test]
    fn forward_shapes() {
        for arch in [ExpertArch::Relu, ExpertArch::SwiGlu] {
            let (e, mut rng) = mk(arch, 1);
            let x = Matrix::randn(5, 8, 1.0, &mut rng);
            let y = e.forward(&x);
            assert_eq!(y.shape(), (5, 8));
            assert!(y.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn relu_forward_matches_manual() {
        let (e, mut rng) = mk(ExpertArch::Relu, 2);
        let x = Matrix::randn(1, 8, 1.0, &mut rng);
        let y = e.forward(&x);
        // Manual single-vector computation.
        let h: Vec<f32> = (0..12)
            .map(|i| {
                let dot: f32 = e.w1.row(i).iter().zip(x.row(0)).map(|(a, b)| a * b).sum();
                (dot + e.b1[i]).max(0.0)
            })
            .collect();
        for o in 0..8 {
            let manual: f32 =
                (0..12).map(|i| e.w2.at(o, i) * h[i]).sum::<f32>() + e.b2[o];
            assert!((y.at(0, o) - manual).abs() < 1e-5);
        }
    }

    #[test]
    fn swiglu_uses_gate() {
        let (mut e, mut rng) = mk(ExpertArch::SwiGlu, 3);
        let x = Matrix::randn(3, 8, 1.0, &mut rng);
        let y1 = e.forward(&x);
        // Zeroing the gate path must change the output to b2 only.
        e.w3 = Some(Matrix::zeros(12, 8));
        e.b3 = Some(vec![0.0; 12]);
        let y2 = e.forward(&x);
        for r in 0..3 {
            for c in 0..8 {
                assert!((y2.at(r, c) - e.b2[c]).abs() < 1e-6);
            }
        }
        assert!(y1.sq_dist(&y2) > 1e-4);
    }

    #[test]
    fn design_matrix_roundtrip() {
        for arch in [ExpertArch::Relu, ExpertArch::SwiGlu] {
            let (e, _) = mk(arch, 4);
            let dm = e.design_matrix();
            assert_eq!(dm.shape(), (12, ExpertWeights::design_cols(arch, 8)));
            let back = ExpertWeights::from_design_matrix(arch, 8, &dm, e.b2.clone());
            assert_eq!(back, e);
        }
    }

    #[test]
    fn permutation_preserves_function() {
        // The distributional claim of Eq. (3): permuting sub-MLPs leaves the
        // expert's output bit-identical up to float addition order.
        for arch in [ExpertArch::Relu, ExpertArch::SwiGlu] {
            let (e, mut rng) = mk(arch, 5);
            let perm = rng.permutation(12);
            let ep = e.permuted(&perm);
            let x = Matrix::randn(6, 8, 1.0, &mut rng);
            let y0 = e.forward(&x);
            let y1 = ep.forward(&x);
            assert!(y0.sq_dist(&y1) < 1e-8, "arch {arch:?}: {}", y0.sq_dist(&y1));
        }
    }

    #[test]
    fn permutation_commutes_with_design_matrix() {
        let (e, mut rng) = mk(ExpertArch::SwiGlu, 6);
        let perm = rng.permutation(12);
        let a = e.permuted(&perm).design_matrix();
        let b = e.design_matrix().permute_rows(&perm);
        assert!(a.sq_dist(&b) < 1e-12);
    }

    #[test]
    fn n_params_matches_config_formula() {
        use crate::moe::config::ModelConfig;
        let cfg = ModelConfig::mixtral_mini();
        let mut rng = Rng::new(7);
        let e = ExpertWeights::random(cfg.arch, cfg.d_model, cfg.d_inner, &mut rng);
        assert_eq!(e.n_params(), cfg.params_per_expert());
    }

    #[test]
    fn perturbed_is_close_but_different() {
        let (e, mut rng) = mk(ExpertArch::Relu, 8);
        let e2 = e.perturbed(0.01, &mut rng);
        let d = e.design_matrix().sq_dist(&e2.design_matrix());
        assert!(d > 0.0);
        assert!(d < 0.1 * e.design_matrix().frob_norm_sq());
    }
}
