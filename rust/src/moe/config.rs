//! Model configurations mirroring the paper's three backbones at laptop
//! scale (the substitution table in DESIGN.md §2).

use crate::util::json::Json;

/// Expert MLP architecture (paper §3.1 and App. B.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpertArch {
    /// `W2 · relu(W1 x + b1) + b2` — Switch Transformer style.
    Relu,
    /// `W2 · (silu(W1 x + b1) ⊙ (W3 x + b3)) + b2` — Llama/Mixtral/DeepSeek
    /// gated style.
    SwiGlu,
}

impl ExpertArch {
    pub fn name(self) -> &'static str {
        match self {
            ExpertArch::Relu => "relu",
            ExpertArch::SwiGlu => "swiglu",
        }
    }

    pub fn from_name(s: &str) -> Option<ExpertArch> {
        match s {
            "relu" => Some(ExpertArch::Relu),
            "swiglu" => Some(ExpertArch::SwiGlu),
            _ => None,
        }
    }
}

/// Expert initialization style — the paper attributes merge-method behaviour
/// differences to this (§5.4: Mixtral experts are "copy-and-paste"
/// upcycled → near-uniform weights; Switch experts are independent).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpertInit {
    /// Independent Gaussian experts (Switch Transformer style).
    Independent,
    /// One base expert cloned with small noise then drifted (Mixtral-style
    /// sparse upcycling).
    Upcycled,
}

/// Full model configuration.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,      // p in the paper
    pub d_inner: usize,      // p_I in the paper
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub n_experts: usize,    // N
    pub top_k: usize,
    pub arch: ExpertArch,
    pub expert_init: ExpertInit,
    /// Every `moe_every`-th FFN is a sparse MoE layer (Switch uses 2).
    pub moe_every: usize,
    /// DeepSeekMoE's always-on shared expert (App. A.2) — excluded from
    /// compression.
    pub shared_expert: bool,
}

impl ModelConfig {
    /// switch-base-8 analog: ReLU experts, top-1 routing, pI = 4p, MoE every
    /// other layer, independent expert init.
    pub fn switch_mini(n_experts: usize) -> ModelConfig {
        ModelConfig {
            name: format!("switch-mini-{n_experts}"),
            vocab_size: 256,
            d_model: 64,
            d_inner: 256,
            n_layers: 6,
            n_heads: 4,
            max_seq: 128,
            n_experts,
            top_k: 1,
            arch: ExpertArch::Relu,
            expert_init: ExpertInit::Independent,
            moe_every: 2,
            shared_expert: false,
        }
    }

    /// Mixtral analog: SwiGLU experts, 8 experts top-2, pI = 3.5p, every FFN
    /// sparse, upcycled init.
    pub fn mixtral_mini() -> ModelConfig {
        ModelConfig {
            name: "mixtral-mini".into(),
            vocab_size: 256,
            d_model: 64,
            d_inner: 224,
            n_layers: 6,
            n_heads: 4,
            max_seq: 128,
            n_experts: 8,
            top_k: 2,
            arch: ExpertArch::SwiGlu,
            expert_init: ExpertInit::Upcycled,
            moe_every: 1,
            shared_expert: false,
        }
    }

    /// DeepSeekMoE analog: 64 fine-grained SwiGLU experts (pI = 11/16·p),
    /// top-6 plus a shared expert.
    pub fn deepseek_mini() -> ModelConfig {
        ModelConfig {
            name: "deepseek-mini".into(),
            vocab_size: 256,
            d_model: 64,
            d_inner: 44,
            n_layers: 4,
            n_heads: 4,
            max_seq: 128,
            n_experts: 64,
            top_k: 6,
            arch: ExpertArch::SwiGlu,
            expert_init: ExpertInit::Upcycled,
            moe_every: 1,
            shared_expert: true,
        }
    }

    pub fn by_name(name: &str) -> Option<ModelConfig> {
        match name {
            "switch-mini-8" => Some(ModelConfig::switch_mini(8)),
            "switch-mini-16" => Some(ModelConfig::switch_mini(16)),
            "mixtral-mini" => Some(ModelConfig::mixtral_mini()),
            "deepseek-mini" => Some(ModelConfig::deepseek_mini()),
            _ => None,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Whether FFN layer `layer` is a sparse MoE layer.
    pub fn is_moe_layer(&self, layer: usize) -> bool {
        (layer + 1) % self.moe_every == 0
    }

    pub fn moe_layer_indices(&self) -> Vec<usize> {
        (0..self.n_layers).filter(|&l| self.is_moe_layer(l)).collect()
    }

    /// Parameters of one expert (paper: Mixtral expert = 176.2 M at scale).
    pub fn params_per_expert(&self) -> usize {
        let (p, pi) = (self.d_model, self.d_inner);
        let gates = if self.arch == ExpertArch::SwiGlu { 2 } else { 1 };
        gates * (pi * p + pi) + p * pi + p
    }

    // ------------------------------------------------------------- JSON io
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("vocab_size", Json::num(self.vocab_size as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("d_inner", Json::num(self.d_inner as f64)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
            ("n_experts", Json::num(self.n_experts as f64)),
            ("top_k", Json::num(self.top_k as f64)),
            ("arch", Json::str(self.arch.name())),
            (
                "expert_init",
                Json::str(match self.expert_init {
                    ExpertInit::Independent => "independent",
                    ExpertInit::Upcycled => "upcycled",
                }),
            ),
            ("moe_every", Json::num(self.moe_every as f64)),
            ("shared_expert", Json::Bool(self.shared_expert)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ModelConfig> {
        let field = |k: &str| {
            j.get(k)
                .ok_or_else(|| anyhow::anyhow!("config missing field '{k}'"))
        };
        let num = |k: &str| -> anyhow::Result<usize> {
            field(k)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("config field '{k}' not a usize"))
        };
        Ok(ModelConfig {
            name: field("name")?.as_str().unwrap_or("unnamed").to_string(),
            vocab_size: num("vocab_size")?,
            d_model: num("d_model")?,
            d_inner: num("d_inner")?,
            n_layers: num("n_layers")?,
            n_heads: num("n_heads")?,
            max_seq: num("max_seq")?,
            n_experts: num("n_experts")?,
            top_k: num("top_k")?,
            arch: ExpertArch::from_name(field("arch")?.as_str().unwrap_or(""))
                .ok_or_else(|| anyhow::anyhow!("bad arch"))?,
            expert_init: match field("expert_init")?.as_str() {
                Some("upcycled") => ExpertInit::Upcycled,
                _ => ExpertInit::Independent,
            },
            moe_every: num("moe_every")?,
            shared_expert: field("shared_expert")?.as_bool().unwrap_or(false),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_follow_paper_ratios() {
        let sw = ModelConfig::switch_mini(8);
        assert_eq!(sw.d_inner, 4 * sw.d_model); // Switch: pI = 4p
        let mx = ModelConfig::mixtral_mini();
        assert_eq!(mx.d_inner * 2, 7 * mx.d_model); // Mixtral: pI = 3.5p
        let ds = ModelConfig::deepseek_mini();
        assert_eq!(ds.d_inner * 16, 11 * ds.d_model); // DeepSeek: pI = 11/16 p
        assert!(ds.shared_expert);
        assert_eq!(ds.n_experts, 64);
    }

    #[test]
    fn moe_layer_placement() {
        let sw = ModelConfig::switch_mini(8);
        assert_eq!(sw.moe_layer_indices(), vec![1, 3, 5]);
        let mx = ModelConfig::mixtral_mini();
        assert_eq!(mx.moe_layer_indices().len(), mx.n_layers);
    }

    #[test]
    fn params_per_expert_counts() {
        let sw = ModelConfig::switch_mini(8);
        // relu: W1 (256x64) + b1 (256) + W2 (64x256) + b2 (64)
        assert_eq!(sw.params_per_expert(), 256 * 64 + 256 + 64 * 256 + 64);
        let mx = ModelConfig::mixtral_mini();
        // swiglu adds W3/b3
        assert_eq!(
            mx.params_per_expert(),
            2 * (224 * 64 + 224) + 64 * 224 + 64
        );
    }

    #[test]
    fn json_roundtrip() {
        for cfg in [
            ModelConfig::switch_mini(16),
            ModelConfig::mixtral_mini(),
            ModelConfig::deepseek_mini(),
        ] {
            let j = cfg.to_json();
            let back = ModelConfig::from_json(&j).unwrap();
            assert_eq!(back.name, cfg.name);
            assert_eq!(back.d_inner, cfg.d_inner);
            assert_eq!(back.arch, cfg.arch);
            assert_eq!(back.shared_expert, cfg.shared_expert);
            assert_eq!(back.top_k, cfg.top_k);
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(ModelConfig::by_name("mixtral-mini").is_some());
        assert!(ModelConfig::by_name("switch-mini-16").unwrap().n_experts == 16);
        assert!(ModelConfig::by_name("nope").is_none());
    }
}
