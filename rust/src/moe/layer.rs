//! Sparse MoE FFN layer: router + N experts (+ optional shared expert).
//!
//! The forward pass groups tokens by activated expert so each expert runs
//! one batched matmul over its assigned tokens (the standard dispatch/
//! combine formulation) — this is also the layout the Pallas kernel mirrors.

use super::config::ExpertArch;
use super::expert::{ExpertForward, ExpertWeights};
use super::router::{Router, RouterStats};
use crate::tensor::Matrix;
use crate::util::Rng;

/// Route every row of `x` and group `(row, gate-weight)` pairs by activated
/// expert slot — the planning half of [`route_dispatch_combine`]. Rows
/// within each group are ascending (token order), which for a
/// row-concatenated multi-request batch means grouped by request in
/// admission order with per-request row order preserved. Exposed so the
/// serving coordinator's continuous-batching hook can replay per-request
/// cache decisions in serial (request-major) order BEFORE dispatching each
/// slot's combined rows once.
pub fn route_groups(
    router: &Router,
    x: &Matrix,
    mut stats: Option<&mut RouterStats>,
) -> Vec<Vec<(usize, f32)>> {
    let n = router.n_experts();
    let logits = router.logits(x);
    let mut groups: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n];
    for t in 0..x.rows {
        let route = router.route_logits(logits.row(t));
        if let Some(s) = stats.as_deref_mut() {
            s.record(&route);
        }
        for (e, w) in route.experts.iter().zip(&route.weights) {
            groups[*e].push((t, *w));
        }
    }
    groups
}

/// Gather the given rows of `x` into a dense sub-batch (the dispatch
/// layout handed to an expert forward).
pub fn gather_rows(x: &Matrix, rows: &[usize]) -> Matrix {
    let mut sub = Matrix::zeros(rows.len(), x.cols);
    for (i, &t) in rows.iter().enumerate() {
        sub.row_mut(i).copy_from_slice(x.row(t));
    }
    sub
}

/// Weighted scatter-accumulate of one slot's expert output back into the
/// combined output: `out[row] += w * y[i]` for each `(row, w)` of `group`.
/// Every row belongs to exactly one `(slot, group-position)`, so calling
/// this per dispatch segment accumulates each row's expert contributions
/// in ascending-slot order — the same order the serial forward uses.
pub fn combine_slot_output(out: &mut Matrix, group: &[(usize, f32)], y: &Matrix) {
    debug_assert_eq!(y.rows, group.len());
    for (i, &(t, w)) in group.iter().enumerate() {
        // Exact axpy (non-fused on both kernels): the weighted combine is
        // bitwise identical whichever kernel RESMOE_SIMD resolves.
        crate::tensor::kernel::axpy(out.row_mut(t), w, y.row(i));
    }
}

/// The dispatch/combine plumbing shared by the plain layer forward and the
/// serving coordinator's cache hook: route every token, group token indices
/// by activated expert slot, run `forward_slot(slot, sub_batch, token_rows)`
/// once per non-empty group, and weighted-combine into the output (on top
/// of the always-on shared expert when present). `token_rows` carries each
/// sub-batch row's original row index in `x` so callers can gather
/// batch-level precomputations (the fused path's shared activations).
///
/// Composed from [`route_groups`] + [`gather_rows`] + [`combine_slot_output`]
/// — the continuous-batching hook uses those pieces directly so it can
/// interleave per-request cache decisions between planning and dispatch.
pub fn route_dispatch_combine(
    router: &Router,
    x: &Matrix,
    stats: Option<&mut RouterStats>,
    shared_expert: Option<&ExpertWeights>,
    mut forward_slot: impl FnMut(usize, &Matrix, &[usize]) -> Matrix,
) -> Matrix {
    let groups = route_groups(router, x, stats);
    let mut out = match shared_expert {
        Some(se) => se.forward(x),
        None => Matrix::zeros(x.rows, x.cols),
    };
    for (slot, group) in groups.iter().enumerate() {
        if group.is_empty() {
            continue;
        }
        let rows: Vec<usize> = group.iter().map(|&(t, _)| t).collect();
        let sub = gather_rows(x, &rows);
        let y = forward_slot(slot, &sub, &rows);
        debug_assert_eq!(y.shape(), sub.shape());
        combine_slot_output(&mut out, group, &y);
    }
    out
}

/// Split one slot's group (rows ascending over a row-concatenated
/// multi-request batch) into per-request runs: returns `(part, len)` pairs
/// in admission order, where `part` indexes the request whose row span in
/// the concatenated matrix is `offsets[part]..offsets[part + 1]`. The runs
/// tile the group contiguously, so segment `k` covers group positions
/// `[sum(len[..k]), sum(len[..k+1]))` — both the cache-decision replay and
/// the fused dispatch of the batched serving hook walk this tiling.
pub fn group_parts(group: &[(usize, f32)], offsets: &[usize]) -> Vec<(usize, usize)> {
    let mut parts: Vec<(usize, usize)> = Vec::new();
    let mut part = 0usize;
    for &(row, _) in group {
        debug_assert!(row < *offsets.last().unwrap());
        while row >= offsets[part + 1] {
            part += 1;
        }
        match parts.last_mut() {
            Some((p, len)) if *p == part => *len += 1,
            _ => parts.push((part, 1)),
        }
    }
    parts
}

#[derive(Debug, Clone, PartialEq)]
pub struct MoeLayer {
    pub router: Router,
    pub experts: Vec<ExpertWeights>,
    /// DeepSeekMoE-style always-on shared expert (not routed, excluded from
    /// compression per App. A.2).
    pub shared_expert: Option<ExpertWeights>,
}

impl MoeLayer {
    pub fn random(
        arch: ExpertArch,
        p: usize,
        pi: usize,
        n_experts: usize,
        top_k: usize,
        upcycled: bool,
        shared: bool,
        rng: &mut Rng,
    ) -> MoeLayer {
        let experts: Vec<ExpertWeights> = if upcycled {
            // Mixtral-style: one base expert, cloned with noise.
            let base = ExpertWeights::random(arch, p, pi, rng);
            (0..n_experts).map(|_| base.perturbed(0.02, rng)).collect()
        } else {
            (0..n_experts)
                .map(|_| ExpertWeights::random(arch, p, pi, rng))
                .collect()
        };
        MoeLayer {
            router: Router::random(n_experts, p, top_k, rng),
            experts,
            shared_expert: shared.then(|| ExpertWeights::random(arch, p, pi, rng)),
        }
    }

    pub fn n_experts(&self) -> usize {
        self.experts.len()
    }

    /// Forward over a batch of token activations `x` (B × p), optionally
    /// recording router statistics.
    pub fn forward(&self, x: &Matrix, stats: Option<&mut RouterStats>) -> Matrix {
        route_dispatch_combine(
            &self.router,
            x,
            stats,
            self.shared_expert.as_ref(),
            |slot, sub, _rows| self.experts[slot].expert_forward(sub),
        )
    }

    /// Total parameters in the routed experts (what compression targets).
    pub fn expert_params(&self) -> usize {
        self.experts.iter().map(|e| e.n_params()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(seed: u64, top_k: usize) -> (MoeLayer, Rng) {
        let mut rng = Rng::new(seed);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, top_k, false, false, &mut rng);
        (l, rng)
    }

    #[test]
    fn forward_shape_and_finiteness() {
        let (l, mut rng) = layer(1, 2);
        let x = Matrix::randn(10, 8, 1.0, &mut rng);
        let y = l.forward(&x, None);
        assert_eq!(y.shape(), (10, 8));
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn matches_naive_per_token_computation() {
        let (l, mut rng) = layer(2, 2);
        let x = Matrix::randn(7, 8, 1.0, &mut rng);
        let y = l.forward(&x, None);
        for t in 0..7 {
            let xt = x.slice_rows(t, t + 1);
            let route = l.router.route(x.row(t));
            let mut want = vec![0.0f32; 8];
            for (e, w) in route.experts.iter().zip(&route.weights) {
                let ye = l.experts[*e].forward(&xt);
                for (o, &v) in want.iter_mut().zip(ye.row(0)) {
                    *o += w * v;
                }
            }
            for c in 0..8 {
                assert!((y.at(t, c) - want[c]).abs() < 1e-4, "token {t} col {c}");
            }
        }
    }

    #[test]
    fn stats_cover_all_tokens() {
        let (l, mut rng) = layer(3, 2);
        let x = Matrix::randn(32, 8, 1.0, &mut rng);
        let mut stats = RouterStats::new(4);
        l.forward(&x, Some(&mut stats));
        assert_eq!(stats.tokens, 32);
        assert_eq!(stats.activations.iter().sum::<u64>(), 64); // top-2
    }

    #[test]
    fn shared_expert_always_contributes() {
        let mut rng = Rng::new(4);
        let mut l =
            MoeLayer::random(ExpertArch::SwiGlu, 8, 12, 4, 1, true, true, &mut rng);
        let x = Matrix::randn(5, 8, 1.0, &mut rng);
        let y_with = l.forward(&x, None);
        let se = l.shared_expert.take().unwrap();
        let y_without = l.forward(&x, None);
        let se_out = se.forward(&x);
        assert!(y_with.sq_dist(&y_without.add(&se_out)) < 1e-6);
    }

    #[test]
    fn upcycled_experts_are_similar() {
        let mut rng = Rng::new(5);
        let up = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 1, true, false, &mut rng);
        let ind = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 1, false, false, &mut rng);
        let spread = |l: &MoeLayer| -> f64 {
            let dms: Vec<Matrix> = l.experts.iter().map(|e| e.design_matrix()).collect();
            let mean = Matrix::mean_of(&dms.iter().collect::<Vec<_>>());
            dms.iter().map(|d| d.sq_dist(&mean)).sum::<f64>() / dms.len() as f64
        };
        assert!(spread(&up) * 10.0 < spread(&ind), "up={} ind={}", spread(&up), spread(&ind));
    }

    #[test]
    fn expert_params_sum() {
        let (l, _) = layer(6, 1);
        assert_eq!(l.expert_params(), 4 * l.experts[0].n_params());
    }

    #[test]
    fn route_groups_rows_are_ascending_and_cover_topk() {
        let (l, mut rng) = layer(7, 2);
        let x = Matrix::randn(12, 8, 1.0, &mut rng);
        let groups = route_groups(&l.router, &x, None);
        let total: usize = groups.iter().map(|g| g.len()).sum();
        assert_eq!(total, 12 * 2, "top-2 routing assigns every token twice");
        for g in &groups {
            for w in g.windows(2) {
                assert!(w[0].0 < w[1].0, "rows ascending within a group");
            }
        }
    }

    #[test]
    fn group_parts_segments_concatenated_requests_in_admission_order() {
        // Three requests of 3/2/4 rows concatenated: offsets [0,3,5,9].
        let offsets = [0usize, 3, 5, 9];
        let group: Vec<(usize, f32)> =
            [0usize, 2, 3, 5, 6, 8].iter().map(|&r| (r, 1.0)).collect();
        let parts = group_parts(&group, &offsets);
        assert_eq!(parts, vec![(0, 2), (1, 1), (2, 3)]);
        // A request with no rows in the group is simply absent.
        let group2: Vec<(usize, f32)> = [(0usize, 1.0), (7usize, 1.0)].to_vec();
        assert_eq!(group_parts(&group2, &offsets), vec![(0, 1), (2, 1)]);
        assert_eq!(group_parts(&[], &offsets), vec![]);
    }

    #[test]
    fn concatenated_dispatch_is_bit_identical_to_per_request_forwards() {
        // The row-independence fact continuous batching rests on: a layer
        // forward over vertically concatenated requests equals each
        // request's own forward EXACTLY (same bits), because routing,
        // expert matmuls, and the combine are all per-row.
        let mut rng = Rng::new(9);
        let l = MoeLayer::random(ExpertArch::SwiGlu, 8, 12, 4, 2, true, true, &mut rng);
        let a = Matrix::randn(5, 8, 1.0, &mut rng);
        let b = Matrix::randn(3, 8, 1.0, &mut rng);
        let c = Matrix::randn(7, 8, 1.0, &mut rng);
        let cat = a.vcat(&b).vcat(&c);
        let y_cat = l.forward(&cat, None);
        let (ya, yb, yc) = (l.forward(&a, None), l.forward(&b, None), l.forward(&c, None));
        let want = ya.vcat(&yb).vcat(&yc);
        assert_eq!(y_cat.data, want.data, "batched rows must match per-request bits");
    }
}
