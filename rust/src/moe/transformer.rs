//! Decoder-only MoE transformer used as the evaluation substrate.
//!
//! Architecture (mirrored exactly by `python/compile/model.py`, which trains
//! the weights we load at eval time):
//!
//! ```text
//! h = embed[token] + pos[position]
//! for each block: h += attn(rmsnorm(h)); h += ffn(rmsnorm(h))
//! logits = rmsnorm(h) @ lm_head^T
//! ```
//!
//! FFNs alternate dense/sparse per `ModelConfig::moe_every`.

use super::attention::{Attention, KvCache};
use super::config::{ExpertInit, ModelConfig};
use super::expert::ExpertWeights;
use super::layer::MoeLayer;
use super::router::RouterStats;
use crate::tensor::Matrix;
use crate::util::Rng;

/// RMS normalization with learned gain — dispatched through the kernel
/// layer (scalar twin reproduces the historical arithmetic; AVX2 uses a
/// lane-split sum of squares). Row-local, so batched hidden states stay
/// bit-identical to per-request ones under either kernel.
pub fn rmsnorm(x: &[f32], gain: &[f32], out: &mut [f32]) {
    crate::tensor::kernel::rmsnorm(x, gain, out);
}

fn rmsnorm_mat(x: &Matrix, gain: &[f32]) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        rmsnorm(x.row(r), gain, out.row_mut(r));
    }
    out
}

/// Override point for FFN computation — the serving coordinator routes MoE
/// blocks through its restored-expert cache by returning `Some(output)`;
/// `None` falls through to the block's own weights.
pub trait FfnHook {
    fn ffn_forward(&self, block: usize, x: &Matrix) -> Option<Matrix>;

    /// Cross-request batched override ([`Model::hidden_states_batch_hooked`]):
    /// `x` is the row-concatenation of several requests' activations, with
    /// request `r` owning rows `part_offsets[r]..part_offsets[r + 1]`.
    /// Return `None` to fall through to the block's own weights over the
    /// combined rows — bit-identical to per-request forwards, because every
    /// FFN kernel (dense MLP, routing, expert matmuls, combine) is
    /// row-independent.
    fn ffn_forward_batch(
        &self,
        block: usize,
        x: &Matrix,
        part_offsets: &[usize],
    ) -> Option<Matrix> {
        let _ = (block, x, part_offsets);
        None
    }
}

/// No-op hook (the default offline path).
pub struct NoHook;

impl FfnHook for NoHook {
    fn ffn_forward(&self, _block: usize, _x: &Matrix) -> Option<Matrix> {
        None
    }
}

/// FFN sub-layer: dense MLP or sparse MoE.
#[derive(Debug, Clone, PartialEq)]
pub enum Ffn {
    Dense(ExpertWeights),
    Moe(MoeLayer),
}

impl Ffn {
    pub fn forward(&self, x: &Matrix, stats: Option<&mut RouterStats>) -> Matrix {
        match self {
            Ffn::Dense(e) => e.forward(x),
            Ffn::Moe(l) => l.forward(x, stats),
        }
    }

    pub fn n_params(&self) -> usize {
        match self {
            Ffn::Dense(e) => e.n_params(),
            Ffn::Moe(l) => {
                l.expert_params()
                    + l.router.w_g.n_params()
                    + l.shared_expert.as_ref().map(|e| e.n_params()).unwrap_or(0)
            }
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Block {
    pub norm1: Vec<f32>,
    pub attn: Attention,
    pub norm2: Vec<f32>,
    pub ffn: Ffn,
}

/// The full decoder-only LM.
#[derive(Debug, Clone)]
pub struct Model {
    pub cfg: ModelConfig,
    /// `vocab × d` token embeddings.
    pub embed: Matrix,
    /// `max_seq × d` learned positional embeddings.
    pub pos: Matrix,
    pub blocks: Vec<Block>,
    pub final_norm: Vec<f32>,
    /// `vocab × d` output projection (untied).
    pub lm_head: Matrix,
    /// Optional classification heads (`n_classes × d`), keyed by task name;
    /// applied to the last position's hidden state.
    pub heads: Vec<(String, Matrix)>,
}

impl Model {
    pub fn random(cfg: &ModelConfig, rng: &mut Rng) -> Model {
        let d = cfg.d_model;
        let s = 0.02;
        let blocks = (0..cfg.n_layers)
            .map(|l| {
                let ffn = if cfg.is_moe_layer(l) {
                    Ffn::Moe(MoeLayer::random(
                        cfg.arch,
                        d,
                        cfg.d_inner,
                        cfg.n_experts,
                        cfg.top_k,
                        cfg.expert_init == ExpertInit::Upcycled,
                        cfg.shared_expert,
                        rng,
                    ))
                } else {
                    Ffn::Dense(ExpertWeights::random(cfg.arch, d, cfg.d_inner, rng))
                };
                Block {
                    norm1: vec![1.0; d],
                    attn: Attention::random(d, cfg.n_heads, rng),
                    norm2: vec![1.0; d],
                    ffn,
                }
            })
            .collect();
        Model {
            cfg: cfg.clone(),
            embed: Matrix::randn(cfg.vocab_size, d, s, rng),
            pos: Matrix::randn(cfg.max_seq, d, s, rng),
            blocks,
            final_norm: vec![1.0; d],
            lm_head: Matrix::randn(cfg.vocab_size, d, s, rng),
            heads: Vec::new(),
        }
    }

    /// Drop the dense experts of the given MoE blocks (router and shared
    /// expert stay) — the resident "backbone" of a compressed serving
    /// deployment; experts come back through the restore cache or the
    /// artifact store.
    pub fn strip_experts(mut self, blocks: &[usize]) -> Model {
        for &bi in blocks {
            if let Ffn::Moe(layer) = &mut self.blocks[bi].ffn {
                layer.experts = Vec::new();
            }
        }
        self
    }

    /// Indices of MoE blocks.
    pub fn moe_blocks(&self) -> Vec<usize> {
        self.blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| matches!(b.ffn, Ffn::Moe(_)))
            .map(|(i, _)| i)
            .collect()
    }

    /// Hidden states after the final norm for a token sequence (T × d).
    pub fn hidden_states(&self, tokens: &[u32], stats: Option<&mut Vec<RouterStats>>) -> Matrix {
        self.hidden_states_hooked(tokens, stats, &NoHook)
    }

    /// [`Self::hidden_states`] with an FFN override hook.
    pub fn hidden_states_hooked(
        &self,
        tokens: &[u32],
        stats: Option<&mut Vec<RouterStats>>,
        hook: &dyn FfnHook,
    ) -> Matrix {
        let t = tokens.len();
        assert!(t <= self.cfg.max_seq, "sequence longer than max_seq");
        let d = self.cfg.d_model;
        let mut h = Matrix::zeros(t, d);
        for (i, &tok) in tokens.iter().enumerate() {
            let e = self.embed.row(tok as usize);
            let p = self.pos.row(i);
            for (o, (&ev, &pv)) in h.row_mut(i).iter_mut().zip(e.iter().zip(p)) {
                *o = ev + pv;
            }
        }
        let mut stats = stats;
        for (bi, block) in self.blocks.iter().enumerate() {
            let normed = rmsnorm_mat(&h, &block.norm1);
            let attn_out = block.attn.forward_full(&normed);
            h.add_assign(&attn_out);
            let normed = rmsnorm_mat(&h, &block.norm2);
            let ffn_out = match hook.ffn_forward(bi, &normed) {
                Some(out) => out,
                None => {
                    let block_stats = stats.as_deref_mut().map(|v| &mut v[bi]);
                    block.ffn.forward(&normed, block_stats)
                }
            };
            h.add_assign(&ffn_out);
        }
        rmsnorm_mat(&h, &self.final_norm)
    }

    /// Hidden states for several independent sequences at once — the
    /// continuous-batching prefill path. The returned matrix stacks the
    /// sequences' token rows in admission order (sequence `r` owns rows
    /// `offsets[r]..offsets[r + 1]` of the second return value); positions
    /// restart at 0 per sequence. Causal attention runs per sequence over
    /// its own row span (sequences never attend to each other) while every
    /// row-wise stage — embeddings, norms, and the FFN/MoE dispatch via
    /// [`FfnHook::ffn_forward_batch`] — runs once over the combined
    /// matrix. Because all those kernels are row-independent, each
    /// sequence's rows are **bit-identical** to running it alone through
    /// [`Model::hidden_states_hooked`] (given a hook that preserves the
    /// same property; the serving coordinator's differential tests pin
    /// this end to end).
    pub fn hidden_states_batch_hooked(
        &self,
        seqs: &[&[u32]],
        hook: &dyn FfnHook,
    ) -> (Matrix, Vec<usize>) {
        let mut offsets = Vec::with_capacity(seqs.len() + 1);
        offsets.push(0usize);
        for s in seqs {
            assert!(s.len() <= self.cfg.max_seq, "sequence longer than max_seq");
            offsets.push(offsets.last().unwrap() + s.len());
        }
        let total = *offsets.last().unwrap();
        let d = self.cfg.d_model;
        let mut h = Matrix::zeros(total, d);
        for (r, s) in seqs.iter().enumerate() {
            for (i, &tok) in s.iter().enumerate() {
                let e = self.embed.row(tok as usize);
                let p = self.pos.row(i);
                for (o, (&ev, &pv)) in
                    h.row_mut(offsets[r] + i).iter_mut().zip(e.iter().zip(p))
                {
                    *o = ev + pv;
                }
            }
        }
        for (bi, block) in self.blocks.iter().enumerate() {
            let normed = rmsnorm_mat(&h, &block.norm1);
            for r in 0..seqs.len() {
                let (lo, hi) = (offsets[r], offsets[r + 1]);
                if lo == hi {
                    continue;
                }
                let attn_out = block.attn.forward_full(&normed.slice_rows(lo, hi));
                for (i, row) in (lo..hi).enumerate() {
                    for (o, &v) in h.row_mut(row).iter_mut().zip(attn_out.row(i)) {
                        *o += v;
                    }
                }
            }
            let normed = rmsnorm_mat(&h, &block.norm2);
            let ffn_out = match hook.ffn_forward_batch(bi, &normed, &offsets) {
                Some(out) => out,
                None => block.ffn.forward(&normed, None),
            };
            h.add_assign(&ffn_out);
        }
        (rmsnorm_mat(&h, &self.final_norm), offsets)
    }

    /// Next-token logits for every position (T × vocab).
    pub fn forward(&self, tokens: &[u32]) -> Matrix {
        self.hidden_states(tokens, None).matmul_nt(&self.lm_head)
    }

    /// Per-block FFN *input* activations (post-norm2) for a calibration
    /// sequence — feeds Wanda's `|W|·||x||` metric and M-SMoE's
    /// activation-aware grouping.
    pub fn collect_ffn_inputs(&self, tokens: &[u32]) -> Vec<Matrix> {
        let t = tokens.len();
        let d = self.cfg.d_model;
        let mut h = Matrix::zeros(t, d);
        for (i, &tok) in tokens.iter().enumerate() {
            let e = self.embed.row(tok as usize);
            let p = self.pos.row(i);
            for (o, (&ev, &pv)) in h.row_mut(i).iter_mut().zip(e.iter().zip(p)) {
                *o = ev + pv;
            }
        }
        let mut out = Vec::with_capacity(self.blocks.len());
        for block in &self.blocks {
            let normed = rmsnorm_mat(&h, &block.norm1);
            let attn_out = block.attn.forward_full(&normed);
            h.add_assign(&attn_out);
            let normed = rmsnorm_mat(&h, &block.norm2);
            out.push(normed.clone());
            let ffn_out = block.ffn.forward(&normed, None);
            h.add_assign(&ffn_out);
        }
        out
    }

    /// Fresh router stats (one per block; dense blocks get zero-sized stats).
    pub fn fresh_stats(&self) -> Vec<RouterStats> {
        self.blocks
            .iter()
            .map(|b| match &b.ffn {
                Ffn::Moe(l) => RouterStats::new(l.n_experts()),
                Ffn::Dense(_) => RouterStats::new(0),
            })
            .collect()
    }

    /// Classification logits from a task head applied to the final position.
    pub fn classify(&self, tokens: &[u32], head: &Matrix) -> Vec<f32> {
        let h = self.hidden_states(tokens, None);
        head.matvec(h.row(h.rows - 1))
    }

    pub fn head(&self, task: &str) -> Option<&Matrix> {
        self.heads.iter().find(|(n, _)| n == task).map(|(_, m)| m)
    }

    // ------------------------------------------------------- decode path
    pub fn fresh_caches(&self) -> Vec<KvCache> {
        self.blocks
            .iter()
            .map(|_| KvCache::new(self.cfg.max_seq, self.cfg.d_model))
            .collect()
    }

    /// Single-token decode step (position = caches[0].len). Returns
    /// next-token logits (vocab).
    pub fn decode_step(&self, token: u32, caches: &mut [KvCache]) -> Vec<f32> {
        self.decode_step_hooked(token, caches, &NoHook)
    }

    /// [`Self::decode_step`] with an FFN override hook.
    pub fn decode_step_hooked(
        &self,
        token: u32,
        caches: &mut [KvCache],
        hook: &dyn FfnHook,
    ) -> Vec<f32> {
        let d = self.cfg.d_model;
        let posn = caches[0].len;
        assert!(posn < self.cfg.max_seq);
        let mut h = Matrix::zeros(1, d);
        for ((o, &e), &p) in h
            .row_mut(0)
            .iter_mut()
            .zip(self.embed.row(token as usize))
            .zip(self.pos.row(posn))
        {
            *o = e + p;
        }
        for (bi, (block, cache)) in self.blocks.iter().zip(caches.iter_mut()).enumerate() {
            let normed = rmsnorm_mat(&h, &block.norm1);
            let attn_out = block.attn.forward_step(&normed, cache);
            h.add_assign(&attn_out);
            let normed = rmsnorm_mat(&h, &block.norm2);
            let ffn_out = match hook.ffn_forward(bi, &normed) {
                Some(out) => out,
                None => block.ffn.forward(&normed, None),
            };
            h.add_assign(&ffn_out);
        }
        let final_h = rmsnorm_mat(&h, &self.final_norm);
        self.lm_head.matvec(final_h.row(0))
    }

    /// One decode step for several independent sequences at once — the
    /// iteration-level continuous-batching path. `tokens[s]` is the token
    /// fed to sequence `s` this step and `caches[s]` its per-block KV
    /// stack; positions may differ per sequence (`caches[s][0].len`).
    /// Returns per-sequence next-token logits.
    ///
    /// Layer-major like the prefill batch path: embeddings/norms run once
    /// over the stacked `S × d` activations, attention runs per sequence
    /// against its own cache (row-local, so each row is bit-identical to a
    /// solo [`Self::decode_step_hooked`]), and the FFN goes through
    /// [`FfnHook::ffn_forward_batch`] with one row per part — so the MoE
    /// block routes ONCE per layer for the whole decode batch and the
    /// serving hook's `try_serve_batch` sees all S sequences' expert wants
    /// in a single window. Logits use the same per-row `matvec` as the
    /// solo path. With a row-independent hook (or none) the batch is
    /// bit-identical to S solo steps; under the serving engine's stateful
    /// cost model only the *decisions* may differ, which is exactly what
    /// the relaxed-parity harness bounds.
    pub fn decode_step_batch_hooked(
        &self,
        tokens: &[u32],
        caches: &mut [Vec<KvCache>],
        hook: &dyn FfnHook,
    ) -> Vec<Vec<f32>> {
        let n = tokens.len();
        assert_eq!(caches.len(), n, "one cache stack per sequence");
        let d = self.cfg.d_model;
        let mut h = Matrix::zeros(n, d);
        for (s, &tok) in tokens.iter().enumerate() {
            let posn = caches[s][0].len;
            assert!(posn < self.cfg.max_seq);
            for ((o, &e), &p) in h
                .row_mut(s)
                .iter_mut()
                .zip(self.embed.row(tok as usize))
                .zip(self.pos.row(posn))
            {
                *o = e + p;
            }
        }
        // One row per part: sequence s owns row s.
        let offsets: Vec<usize> = (0..=n).collect();
        for (bi, block) in self.blocks.iter().enumerate() {
            let normed = rmsnorm_mat(&h, &block.norm1);
            for s in 0..n {
                let attn_out =
                    block.attn.forward_step(&normed.slice_rows(s, s + 1), &mut caches[s][bi]);
                for (o, &v) in h.row_mut(s).iter_mut().zip(attn_out.row(0)) {
                    *o += v;
                }
            }
            let normed = rmsnorm_mat(&h, &block.norm2);
            let ffn_out = match hook.ffn_forward_batch(bi, &normed, &offsets) {
                Some(out) => out,
                None => block.ffn.forward(&normed, None),
            };
            h.add_assign(&ffn_out);
        }
        let final_h = rmsnorm_mat(&h, &self.final_norm);
        (0..n).map(|s| self.lm_head.matvec(final_h.row(s))).collect()
    }

    /// Greedy generation from a prompt.
    pub fn generate(&self, prompt: &[u32], max_new: usize) -> Vec<u32> {
        let mut caches = self.fresh_caches();
        let mut logits = vec![0.0f32; self.cfg.vocab_size];
        for &t in prompt {
            logits = self.decode_step(t, &mut caches);
        }
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            if caches[0].len >= self.cfg.max_seq {
                break;
            }
            let next = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as u32)
                .unwrap();
            out.push(next);
            logits = self.decode_step(next, &mut caches);
        }
        out
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        let mut n = self.embed.n_params() + self.pos.n_params() + self.lm_head.n_params();
        n += self.final_norm.len();
        for b in &self.blocks {
            n += b.norm1.len() + b.norm2.len() + b.attn.n_params() + b.ffn.n_params();
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.max_seq = 24;
        cfg.vocab_size = 32;
        cfg
    }

    #[test]
    fn forward_shapes() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        let m = Model::random(&cfg, &mut rng);
        let tokens: Vec<u32> = (0..10).map(|i| i % 32).collect();
        let logits = m.forward(&tokens);
        assert_eq!(logits.shape(), (10, 32));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn moe_placement_matches_config() {
        let cfg = tiny_cfg(); // moe_every = 2, n_layers = 2 → block 1 is MoE
        let mut rng = Rng::new(2);
        let m = Model::random(&cfg, &mut rng);
        assert_eq!(m.moe_blocks(), vec![1]);
    }

    #[test]
    fn decode_matches_full_forward() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(3);
        let m = Model::random(&cfg, &mut rng);
        let tokens: Vec<u32> = vec![3, 7, 1, 30, 12, 8];
        let full = m.forward(&tokens);
        let mut caches = m.fresh_caches();
        for (i, &t) in tokens.iter().enumerate() {
            let logits = m.decode_step(t, &mut caches);
            for v in 0..32 {
                assert!(
                    (full.at(i, v) - logits[v]).abs() < 1e-3,
                    "pos {i} vocab {v}: {} vs {}",
                    full.at(i, v),
                    logits[v]
                );
            }
        }
    }

    #[test]
    fn generate_is_deterministic_and_bounded() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(4);
        let m = Model::random(&cfg, &mut rng);
        let a = m.generate(&[1, 2, 3], 8);
        let b = m.generate(&[1, 2, 3], 8);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|&t| (t as usize) < cfg.vocab_size));
    }

    #[test]
    fn generate_respects_max_seq() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(5);
        let m = Model::random(&cfg, &mut rng);
        let prompt: Vec<u32> = (0..20).map(|i| i % 32).collect();
        let out = m.generate(&prompt, 100);
        assert_eq!(out.len(), cfg.max_seq - prompt.len());
    }

    #[test]
    fn router_stats_collected_per_moe_block() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(6);
        let m = Model::random(&cfg, &mut rng);
        let mut stats = m.fresh_stats();
        let tokens: Vec<u32> = (0..12).map(|i| i % 32).collect();
        m.hidden_states(&tokens, Some(&mut stats));
        assert_eq!(stats[1].tokens, 12);
        assert_eq!(stats[0].tokens, 0); // dense block untouched
    }

    #[test]
    fn classify_shape() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(7);
        let mut m = Model::random(&cfg, &mut rng);
        let head = Matrix::randn(3, cfg.d_model, 0.1, &mut rng);
        m.heads.push(("nli".into(), head.clone()));
        let logits = m.classify(&[1, 2, 3, 4], &head);
        assert_eq!(logits.len(), 3);
        assert!(m.head("nli").is_some());
        assert!(m.head("other").is_none());
    }

    #[test]
    fn batch_hidden_states_are_bit_identical_to_per_sequence() {
        // The continuous-batching substrate: stacking sequences through one
        // forward must reproduce each sequence's solo hidden states
        // EXACTLY (same f32 bits) — attention is per-span, everything else
        // row-independent.
        let cfg = tiny_cfg();
        let mut rng = Rng::new(8);
        let m = Model::random(&cfg, &mut rng);
        let s1: Vec<u32> = vec![3, 7, 1, 30];
        let s2: Vec<u32> = vec![12, 8];
        let s3: Vec<u32> = (0..10).map(|i| (i * 3) % 32).collect();
        let seqs: Vec<&[u32]> = vec![s1.as_slice(), s2.as_slice(), s3.as_slice()];
        let (h, offsets) = m.hidden_states_batch_hooked(&seqs, &NoHook);
        assert_eq!(offsets, vec![0, 4, 6, 16]);
        assert_eq!(h.rows, 16);
        for (r, s) in seqs.iter().enumerate() {
            let solo = m.hidden_states(s, None);
            let span = h.slice_rows(offsets[r], offsets[r + 1]);
            assert_eq!(span.data, solo.data, "sequence {r} must match bitwise");
        }
    }

    #[test]
    fn batched_decode_step_is_bit_identical_to_solo_steps() {
        // The decode analogue of the prefill theorem: one batched step
        // over S sequences at DIFFERENT positions must reproduce each
        // sequence's solo decode_step bit-for-bit (attention is per-cache,
        // everything else row-independent, logits per-row matvec).
        let cfg = tiny_cfg();
        let mut rng = Rng::new(9);
        let m = Model::random(&cfg, &mut rng);
        let prompts: [&[u32]; 3] = [&[3, 7, 1, 30], &[12, 8], &[0, 5, 9, 2, 31, 4]];

        // Solo reference: run each sequence alone, recording every logit
        // vector.
        let mut solo_logits: Vec<Vec<Vec<f32>>> = Vec::new();
        for p in prompts {
            let mut caches = m.fresh_caches();
            let mut per_step = Vec::new();
            for &t in p {
                per_step.push(m.decode_step(t, &mut caches));
            }
            // two greedy continuation steps
            for _ in 0..2 {
                let next = argmax(per_step.last().unwrap());
                per_step.push(m.decode_step(next, &mut caches));
            }
            solo_logits.push(per_step);
        }

        // Batched: drive all three through decode_step_batch_hooked. The
        // sequences have different lengths, so later steps feed a batch
        // whose positions differ per sequence.
        let mut caches: Vec<Vec<KvCache>> =
            (0..3).map(|_| m.fresh_caches()).collect();
        let steps = prompts.iter().map(|p| p.len()).max().unwrap() + 2;
        let mut last: Vec<Option<Vec<f32>>> = vec![None; 3];
        for step in 0..steps {
            let mut idxs = Vec::new();
            let mut toks = Vec::new();
            for (s, p) in prompts.iter().enumerate() {
                let fed = caches[s][0].len;
                if fed >= p.len() + 2 {
                    continue; // retired
                }
                let tok = if fed < p.len() {
                    p[fed]
                } else {
                    argmax(last[s].as_ref().unwrap())
                };
                idxs.push(s);
                toks.push(tok);
            }
            if idxs.is_empty() {
                break;
            }
            // Pull out the active sequences' cache stacks in order.
            let mut active: Vec<Vec<KvCache>> =
                idxs.iter().map(|&s| std::mem::take(&mut caches[s])).collect();
            let logits = m.decode_step_batch_hooked(&toks, &mut active, &NoHook);
            for (k, &s) in idxs.iter().enumerate() {
                caches[s] = std::mem::take(&mut active[k]);
                let pos = caches[s][0].len - 1;
                assert_eq!(
                    logits[k], solo_logits[s][pos],
                    "seq {s} step {step}: batched logits must equal solo bitwise"
                );
                last[s] = Some(logits[k].clone());
            }
        }
        for (s, c) in caches.iter().enumerate() {
            assert_eq!(c[0].len, prompts[s].len() + 2, "seq {s} ran to completion");
        }
    }

    fn argmax(v: &[f32]) -> u32 {
        v.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as u32)
            .unwrap()
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32, -4.0];
        let gain = vec![1.0f32, 1.0];
        let mut out = vec![0.0f32; 2];
        rmsnorm(&x, &gain, &mut out);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-4);
        assert!((out[1] + 4.0 / rms).abs() < 1e-4);
    }
}
