//! Multi-head causal self-attention with an optional KV cache, used by the
//! decoder-only evaluation models.
//!
//! The KV cache is **block-paged** (vLLM-style): instead of one
//! `max_seq × d` slab per layer reserved up front, K and V grow in
//! fixed-size pages of [`KV_PAGE_TOKENS`] rows allocated lazily as decode
//! advances. Sequence admission charges a worst-case page reservation
//! against a shared [`KvPagePool`] so a decode step can never fail
//! mid-sequence on memory: either the lease is granted at admission and
//! every page the sequence can touch is covered, or the request is refused
//! before any state exists. Pages live in the cache itself (the pool is
//! byte accounting, not an allocator), so releasing a lease never has to
//! claw memory back from a live sequence.
//!
//! Paging changes only *where* a K/V row lives, never its contents or the
//! attention arithmetic — `forward_step` reads the same rows in the same
//! order as the old contiguous layout, so decode outputs are bit-identical
//! to the pre-paging cache.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::Arc;

use crate::tensor::kernel;
use crate::tensor::Matrix;
use crate::util::stats::softmax;
use crate::util::Rng;

/// Rows (tokens) per KV page. Small enough that a 4-token Generate
/// request wastes at most one page per layer, large enough that page
/// arithmetic stays off the profile.
pub const KV_PAGE_TOKENS: usize = 16;

/// Bytes one cached token occupies in one layer: a K row and a V row of
/// `d` f32 lanes each.
pub fn kv_token_bytes(d: usize) -> usize {
    2 * d * std::mem::size_of::<f32>()
}

/// Worst-case lease for a sequence that may reach `tokens` positions
/// across `n_layers` layers, rounded up to whole pages — the amount a
/// [`KvPagePool`] admission must cover so mid-decode allocation can never
/// exceed it.
pub fn kv_lease_bytes(tokens: usize, d: usize, n_layers: usize) -> usize {
    let pages = tokens.div_ceil(KV_PAGE_TOKENS);
    n_layers * pages * KV_PAGE_TOKENS * kv_token_bytes(d)
}

/// Shared byte budget for KV pages across all concurrently-decoding
/// sequences. Pure accounting: `lease` reserves worst-case bytes at
/// admission, dropping the returned [`KvLease`] releases them. The pool
/// never revokes a live lease — refusal happens only at admission, so a
/// sequence that got in always finishes (the eviction-refusal contract
/// the page-pool tests pin).
///
/// One over-budget sequence is admitted when the pool is otherwise empty,
/// mirroring the expert cache's single-over-budget-entry precedent:
/// a budget smaller than one sequence must degrade to serial decode, not
/// deadlock.
#[derive(Debug)]
pub struct KvPagePool {
    max_bytes: usize,
    used: AtomicUsize,
    live: AtomicUsize,
    leases_granted: AtomicU64,
    leases_released: AtomicU64,
    refusals: AtomicU64,
    peak_bytes: AtomicUsize,
}

impl KvPagePool {
    pub fn new(max_bytes: usize) -> KvPagePool {
        KvPagePool {
            max_bytes,
            used: AtomicUsize::new(0),
            live: AtomicUsize::new(0),
            leases_granted: AtomicU64::new(0),
            leases_released: AtomicU64::new(0),
            refusals: AtomicU64::new(0),
            peak_bytes: AtomicUsize::new(0),
        }
    }

    pub fn max_bytes(&self) -> usize {
        self.max_bytes
    }

    pub fn used_bytes(&self) -> usize {
        self.used.load(Relaxed)
    }

    pub fn live_leases(&self) -> usize {
        self.live.load(Relaxed)
    }

    pub fn leases_granted(&self) -> u64 {
        self.leases_granted.load(Relaxed)
    }

    pub fn leases_released(&self) -> u64 {
        self.leases_released.load(Relaxed)
    }

    pub fn refusals(&self) -> u64 {
        self.refusals.load(Relaxed)
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes.load(Relaxed)
    }

    /// Try to reserve `bytes` for one sequence. `None` means the caller
    /// must not admit the sequence now (retry after a live sequence
    /// retires). Single-over-budget exception: an empty pool grants any
    /// size.
    pub fn lease(self: &Arc<Self>, bytes: usize) -> Option<KvLease> {
        // CAS loop: admission decisions race across workers, and a
        // check-then-add pair would let two over-budget sequences through
        // one budget slot.
        let mut cur = self.used.load(Relaxed);
        loop {
            let fits = cur + bytes <= self.max_bytes || cur == 0;
            if !fits {
                self.refusals.fetch_add(1, Relaxed);
                return None;
            }
            match self.used.compare_exchange_weak(cur, cur + bytes, Relaxed, Relaxed) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
        self.live.fetch_add(1, Relaxed);
        self.leases_granted.fetch_add(1, Relaxed);
        self.peak_bytes.fetch_max(cur + bytes, Relaxed);
        Some(KvLease { pool: Arc::clone(self), bytes })
    }
}

/// RAII reservation of KV pool bytes for one sequence's lifetime.
/// Dropping it returns the bytes; the pool cannot take them back earlier.
#[derive(Debug)]
pub struct KvLease {
    pool: Arc<KvPagePool>,
    bytes: usize,
}

impl KvLease {
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

impl Drop for KvLease {
    fn drop(&mut self) {
        self.pool.used.fetch_sub(self.bytes, Relaxed);
        self.pool.live.fetch_sub(1, Relaxed);
        self.pool.leases_released.fetch_add(1, Relaxed);
    }
}

/// Attention projection weights; all `d × d`, stored `[out, in]` so
/// application is `x.matmul_nt(w)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Attention {
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub n_heads: usize,
}

/// Per-layer block-paged KV cache for incremental decoding. Pages of
/// [`KV_PAGE_TOKENS`] K rows and V rows are allocated lazily as `len`
/// crosses page boundaries; `max_seq` stays the hard capacity (the
/// "KV cache overflow" panic the decode loop relies on).
#[derive(Debug, Clone)]
pub struct KvCache {
    pages_k: Vec<Matrix>,
    pages_v: Vec<Matrix>,
    pub len: usize,
    max_seq: usize,
    d: usize,
}

impl KvCache {
    /// An empty cache able to hold `max_seq` positions of width `d`.
    /// No pages are allocated until the first append — a cache built for
    /// a long context but used for a short one pays only for the pages it
    /// touches.
    pub fn new(max_seq: usize, d: usize) -> KvCache {
        KvCache { pages_k: Vec::new(), pages_v: Vec::new(), len: 0, max_seq, d }
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Pages currently materialized (K and V pages count as one: they
    /// always allocate together).
    pub fn pages_allocated(&self) -> usize {
        self.pages_k.len()
    }

    /// Bytes of K/V page storage currently materialized.
    pub fn allocated_bytes(&self) -> usize {
        self.pages_k.len() * KV_PAGE_TOKENS * kv_token_bytes(self.d)
    }

    /// Reset to empty. Pages are kept for reuse — `clear` is the
    /// same-request reset path, where the next decode refills them.
    pub fn clear(&mut self) {
        self.len = 0;
    }

    #[inline]
    fn k_row(&self, j: usize) -> &[f32] {
        self.pages_k[j / KV_PAGE_TOKENS].row(j % KV_PAGE_TOKENS)
    }

    #[inline]
    fn v_row(&self, j: usize) -> &[f32] {
        self.pages_v[j / KV_PAGE_TOKENS].row(j % KV_PAGE_TOKENS)
    }

    /// Append one K/V row pair at position `len`, allocating the page it
    /// lands on if this is the first visit. Panics with "KV cache
    /// overflow" past `max_seq` — same contract as the contiguous layout.
    fn append(&mut self, k: &[f32], v: &[f32]) {
        let pos = self.len;
        assert!(pos < self.max_seq, "KV cache overflow");
        let page = pos / KV_PAGE_TOKENS;
        if page == self.pages_k.len() {
            self.pages_k.push(Matrix::zeros(KV_PAGE_TOKENS, self.d));
            self.pages_v.push(Matrix::zeros(KV_PAGE_TOKENS, self.d));
        }
        let slot = pos % KV_PAGE_TOKENS;
        self.pages_k[page].row_mut(slot).copy_from_slice(k);
        self.pages_v[page].row_mut(slot).copy_from_slice(v);
        self.len += 1;
    }
}

impl Attention {
    pub fn random(d: usize, n_heads: usize, rng: &mut Rng) -> Attention {
        assert_eq!(d % n_heads, 0);
        let s = 1.0 / (d as f32).sqrt();
        Attention {
            wq: Matrix::randn(d, d, s, rng),
            wk: Matrix::randn(d, d, s, rng),
            wv: Matrix::randn(d, d, s, rng),
            wo: Matrix::randn(d, d, s, rng),
            n_heads,
        }
    }

    pub fn d_model(&self) -> usize {
        self.wq.rows
    }

    pub fn n_params(&self) -> usize {
        4 * self.wq.n_params()
    }

    /// Full-sequence causal attention: `x` (T × d) → (T × d).
    pub fn forward_full(&self, x: &Matrix) -> Matrix {
        let t = x.rows;
        let d = self.d_model();
        let hd = d / self.n_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let q = x.matmul_nt(&self.wq);
        let k = x.matmul_nt(&self.wk);
        let v = x.matmul_nt(&self.wv);
        let mut ctx = Matrix::zeros(t, d);
        // §Perf: one reusable score buffer + in-place softmax instead of a
        // fresh Vec per (head, position) — the T² small allocations
        // dominated the profile at decode-context lengths. The dot /
        // softmax / weighted-V tiers dispatch through `tensor::kernel`
        // (vectorized under AVX2; the scalar twin reproduces the historical
        // arithmetic bit-for-bit). Each position's softmax spans only its
        // own causal prefix, so outputs stay position-local.
        let mut scores: Vec<f32> = Vec::with_capacity(t);
        for h in 0..self.n_heads {
            let lo = h * hd;
            let hi = lo + hd;
            for i in 0..t {
                // scores over j <= i
                let qi = &q.row(i)[lo..hi];
                scores.clear();
                for j in 0..=i {
                    scores.push(kernel::dot(qi, &k.row(j)[lo..hi]) * scale);
                }
                kernel::softmax_inplace(&mut scores);
                let dst = &mut ctx.row_mut(i)[lo..hi];
                for (j, &p) in scores.iter().enumerate() {
                    kernel::axpy(dst, p, &v.row(j)[lo..hi]);
                }
            }
        }
        ctx.matmul_nt(&self.wo)
    }

    /// Single-token decode step against a KV cache. `x` is (1 × d); the new
    /// K/V rows are appended to the cache.
    pub fn forward_step(&self, x: &Matrix, cache: &mut KvCache) -> Matrix {
        assert_eq!(x.rows, 1);
        let d = self.d_model();
        let hd = d / self.n_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let q = x.matmul_nt(&self.wq);
        let k_new = x.matmul_nt(&self.wk);
        let v_new = x.matmul_nt(&self.wv);
        cache.append(k_new.row(0), v_new.row(0));
        let mut ctx = Matrix::zeros(1, d);
        for h in 0..self.n_heads {
            let lo = h * hd;
            let hi = lo + hd;
            let qh = &q.row(0)[lo..hi];
            let scores: Vec<f32> = (0..cache.len)
                .map(|j| kernel::dot(qh, &cache.k_row(j)[lo..hi]) * scale)
                .collect();
            let probs = softmax(&scores);
            let dst = &mut ctx.row_mut(0)[lo..hi];
            for (j, &p) in probs.iter().enumerate() {
                kernel::axpy(dst, p, &cache.v_row(j)[lo..hi]);
            }
        }
        ctx.matmul_nt(&self.wo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_forward_shape() {
        let mut rng = Rng::new(1);
        let a = Attention::random(16, 4, &mut rng);
        let x = Matrix::randn(10, 16, 1.0, &mut rng);
        let y = a.forward_full(&x);
        assert_eq!(y.shape(), (10, 16));
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_prefix_invariance() {
        // Output at position i must not depend on tokens after i.
        let mut rng = Rng::new(2);
        let a = Attention::random(16, 4, &mut rng);
        let x_full = Matrix::randn(8, 16, 1.0, &mut rng);
        let y_full = a.forward_full(&x_full);
        let x_prefix = x_full.slice_rows(0, 5);
        let y_prefix = a.forward_full(&x_prefix);
        for i in 0..5 {
            for c in 0..16 {
                assert!(
                    (y_full.at(i, c) - y_prefix.at(i, c)).abs() < 1e-5,
                    "pos {i} col {c}"
                );
            }
        }
    }

    #[test]
    fn kv_cache_decode_matches_full_forward() {
        let mut rng = Rng::new(3);
        let a = Attention::random(12, 3, &mut rng);
        let x = Matrix::randn(6, 12, 1.0, &mut rng);
        let y_full = a.forward_full(&x);
        let mut cache = KvCache::new(16, 12);
        for i in 0..6 {
            let xi = x.slice_rows(i, i + 1);
            let yi = a.forward_step(&xi, &mut cache);
            for c in 0..12 {
                assert!(
                    (y_full.at(i, c) - yi.at(0, c)).abs() < 1e-4,
                    "pos {i} col {c}: {} vs {}",
                    y_full.at(i, c),
                    yi.at(0, c)
                );
            }
        }
    }

    #[test]
    fn cache_clear_resets() {
        let mut rng = Rng::new(4);
        let a = Attention::random(8, 2, &mut rng);
        let x = Matrix::randn(1, 8, 1.0, &mut rng);
        let mut cache = KvCache::new(4, 8);
        let y1 = a.forward_step(&x, &mut cache);
        cache.clear();
        let y2 = a.forward_step(&x, &mut cache);
        assert!(y1.sq_dist(&y2) < 1e-12);
        assert_eq!(cache.len, 1);
    }

    #[test]
    #[should_panic(expected = "KV cache overflow")]
    fn cache_overflow_panics() {
        let mut rng = Rng::new(5);
        let a = Attention::random(8, 2, &mut rng);
        let x = Matrix::randn(1, 8, 1.0, &mut rng);
        let mut cache = KvCache::new(1, 8);
        a.forward_step(&x, &mut cache);
        a.forward_step(&x, &mut cache);
    }

    // ------------------------------------------------------ paged layout

    #[test]
    fn pages_allocate_lazily_and_only_when_crossed() {
        let mut rng = Rng::new(6);
        let a = Attention::random(8, 2, &mut rng);
        let mut cache = KvCache::new(3 * KV_PAGE_TOKENS, 8);
        assert_eq!(cache.pages_allocated(), 0, "no pages before first token");
        assert_eq!(cache.allocated_bytes(), 0);
        for i in 0..(2 * KV_PAGE_TOKENS + 1) {
            let x = Matrix::randn(1, 8, 1.0, &mut rng);
            a.forward_step(&x, &mut cache);
            let want = (i / KV_PAGE_TOKENS) + 1;
            assert_eq!(cache.pages_allocated(), want, "token {i}");
        }
        assert_eq!(cache.allocated_bytes(), 3 * KV_PAGE_TOKENS * kv_token_bytes(8));
        // clear keeps pages (reuse) but resets the write head.
        cache.clear();
        assert_eq!(cache.len, 0);
        assert_eq!(cache.pages_allocated(), 3, "pages retained for reuse");
    }

    #[test]
    fn paged_decode_is_identical_across_page_boundaries() {
        // The paged layout must reproduce full-forward attention exactly
        // even when the causal prefix spans multiple pages.
        let mut rng = Rng::new(7);
        let a = Attention::random(12, 3, &mut rng);
        let t = 2 * KV_PAGE_TOKENS + 3;
        let x = Matrix::randn(t, 12, 1.0, &mut rng);
        let y_full = a.forward_full(&x);
        let mut cache = KvCache::new(t, 12);
        for i in 0..t {
            let xi = x.slice_rows(i, i + 1);
            let yi = a.forward_step(&xi, &mut cache);
            for c in 0..12 {
                assert!(
                    (y_full.at(i, c) - yi.at(0, c)).abs() < 1e-4,
                    "pos {i} col {c}"
                );
            }
        }
    }

    // -------------------------------------------------------- page pool

    #[test]
    fn pool_lease_release_conserves_bytes() {
        let pool = Arc::new(KvPagePool::new(10_000));
        let b = kv_lease_bytes(20, 8, 2);
        let l1 = pool.lease(b).expect("fits");
        let l2 = pool.lease(b).expect("fits");
        assert_eq!(pool.used_bytes(), 2 * b);
        assert_eq!(pool.live_leases(), 2);
        drop(l1);
        assert_eq!(pool.used_bytes(), b);
        drop(l2);
        assert_eq!(pool.used_bytes(), 0);
        assert_eq!(pool.leases_granted(), 2);
        assert_eq!(pool.leases_released(), 2);
        assert_eq!(pool.peak_bytes(), 2 * b);
    }

    #[test]
    fn pool_refuses_when_full_but_never_revokes() {
        let b = kv_lease_bytes(KV_PAGE_TOKENS, 4, 1);
        let pool = Arc::new(KvPagePool::new(2 * b));
        let l1 = pool.lease(b).expect("first fits");
        let l2 = pool.lease(b).expect("second fits exactly");
        assert!(pool.lease(b).is_none(), "third must be refused");
        assert_eq!(pool.refusals(), 1);
        // The live leases are untouched by the refusal.
        assert_eq!(pool.live_leases(), 2);
        assert_eq!(pool.used_bytes(), 2 * b);
        drop(l2);
        let l3 = pool.lease(b).expect("slot freed by release");
        drop(l1);
        drop(l3);
        assert_eq!(pool.used_bytes(), 0);
    }

    #[test]
    fn pool_admits_single_over_budget_sequence() {
        // A pool smaller than one sequence degrades to serial decode
        // instead of deadlocking: the empty pool grants anything, but a
        // second over-budget sequence waits.
        let pool = Arc::new(KvPagePool::new(16));
        let big = pool.lease(1_000_000).expect("empty pool grants any size");
        assert!(pool.lease(1).is_none(), "pool no longer empty");
        drop(big);
        let ok = pool.lease(8).expect("empty again");
        drop(ok);
    }

    #[test]
    fn lease_bytes_round_to_whole_pages() {
        let d = 8;
        let one_page = KV_PAGE_TOKENS * kv_token_bytes(d);
        assert_eq!(kv_lease_bytes(1, d, 1), one_page);
        assert_eq!(kv_lease_bytes(KV_PAGE_TOKENS, d, 1), one_page);
        assert_eq!(kv_lease_bytes(KV_PAGE_TOKENS + 1, d, 1), 2 * one_page);
        assert_eq!(kv_lease_bytes(KV_PAGE_TOKENS, d, 3), 3 * one_page);
        assert_eq!(kv_lease_bytes(0, d, 1), 0);
    }
}
