//! Multi-head causal self-attention with an optional KV cache, used by the
//! decoder-only evaluation models.

use crate::tensor::kernel;
use crate::tensor::Matrix;
use crate::util::stats::softmax;
use crate::util::Rng;

/// Attention projection weights; all `d × d`, stored `[out, in]` so
/// application is `x.matmul_nt(w)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Attention {
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub n_heads: usize,
}

/// Per-layer KV cache for incremental decoding.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub k: Matrix,
    pub v: Matrix,
    pub len: usize,
}

impl KvCache {
    pub fn new(max_seq: usize, d: usize) -> KvCache {
        KvCache { k: Matrix::zeros(max_seq, d), v: Matrix::zeros(max_seq, d), len: 0 }
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }
}

impl Attention {
    pub fn random(d: usize, n_heads: usize, rng: &mut Rng) -> Attention {
        assert_eq!(d % n_heads, 0);
        let s = 1.0 / (d as f32).sqrt();
        Attention {
            wq: Matrix::randn(d, d, s, rng),
            wk: Matrix::randn(d, d, s, rng),
            wv: Matrix::randn(d, d, s, rng),
            wo: Matrix::randn(d, d, s, rng),
            n_heads,
        }
    }

    pub fn d_model(&self) -> usize {
        self.wq.rows
    }

    pub fn n_params(&self) -> usize {
        4 * self.wq.n_params()
    }

    /// Full-sequence causal attention: `x` (T × d) → (T × d).
    pub fn forward_full(&self, x: &Matrix) -> Matrix {
        let t = x.rows;
        let d = self.d_model();
        let hd = d / self.n_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let q = x.matmul_nt(&self.wq);
        let k = x.matmul_nt(&self.wk);
        let v = x.matmul_nt(&self.wv);
        let mut ctx = Matrix::zeros(t, d);
        // §Perf: one reusable score buffer + in-place softmax instead of a
        // fresh Vec per (head, position) — the T² small allocations
        // dominated the profile at decode-context lengths. The dot /
        // softmax / weighted-V tiers dispatch through `tensor::kernel`
        // (vectorized under AVX2; the scalar twin reproduces the historical
        // arithmetic bit-for-bit). Each position's softmax spans only its
        // own causal prefix, so outputs stay position-local.
        let mut scores: Vec<f32> = Vec::with_capacity(t);
        for h in 0..self.n_heads {
            let lo = h * hd;
            let hi = lo + hd;
            for i in 0..t {
                // scores over j <= i
                let qi = &q.row(i)[lo..hi];
                scores.clear();
                for j in 0..=i {
                    scores.push(kernel::dot(qi, &k.row(j)[lo..hi]) * scale);
                }
                kernel::softmax_inplace(&mut scores);
                let dst = &mut ctx.row_mut(i)[lo..hi];
                for (j, &p) in scores.iter().enumerate() {
                    kernel::axpy(dst, p, &v.row(j)[lo..hi]);
                }
            }
        }
        ctx.matmul_nt(&self.wo)
    }

    /// Single-token decode step against a KV cache. `x` is (1 × d); the new
    /// K/V rows are appended to the cache.
    pub fn forward_step(&self, x: &Matrix, cache: &mut KvCache) -> Matrix {
        assert_eq!(x.rows, 1);
        let d = self.d_model();
        let hd = d / self.n_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let q = x.matmul_nt(&self.wq);
        let k_new = x.matmul_nt(&self.wk);
        let v_new = x.matmul_nt(&self.wv);
        let pos = cache.len;
        assert!(pos < cache.k.rows, "KV cache overflow");
        cache.k.row_mut(pos).copy_from_slice(k_new.row(0));
        cache.v.row_mut(pos).copy_from_slice(v_new.row(0));
        cache.len += 1;
        let mut ctx = Matrix::zeros(1, d);
        for h in 0..self.n_heads {
            let lo = h * hd;
            let hi = lo + hd;
            let qh = &q.row(0)[lo..hi];
            let scores: Vec<f32> = (0..cache.len)
                .map(|j| kernel::dot(qh, &cache.k.row(j)[lo..hi]) * scale)
                .collect();
            let probs = softmax(&scores);
            let dst = &mut ctx.row_mut(0)[lo..hi];
            for (j, &p) in probs.iter().enumerate() {
                kernel::axpy(dst, p, &cache.v.row(j)[lo..hi]);
            }
        }
        ctx.matmul_nt(&self.wo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_forward_shape() {
        let mut rng = Rng::new(1);
        let a = Attention::random(16, 4, &mut rng);
        let x = Matrix::randn(10, 16, 1.0, &mut rng);
        let y = a.forward_full(&x);
        assert_eq!(y.shape(), (10, 16));
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_prefix_invariance() {
        // Output at position i must not depend on tokens after i.
        let mut rng = Rng::new(2);
        let a = Attention::random(16, 4, &mut rng);
        let x_full = Matrix::randn(8, 16, 1.0, &mut rng);
        let y_full = a.forward_full(&x_full);
        let x_prefix = x_full.slice_rows(0, 5);
        let y_prefix = a.forward_full(&x_prefix);
        for i in 0..5 {
            for c in 0..16 {
                assert!(
                    (y_full.at(i, c) - y_prefix.at(i, c)).abs() < 1e-5,
                    "pos {i} col {c}"
                );
            }
        }
    }

    #[test]
    fn kv_cache_decode_matches_full_forward() {
        let mut rng = Rng::new(3);
        let a = Attention::random(12, 3, &mut rng);
        let x = Matrix::randn(6, 12, 1.0, &mut rng);
        let y_full = a.forward_full(&x);
        let mut cache = KvCache::new(16, 12);
        for i in 0..6 {
            let xi = x.slice_rows(i, i + 1);
            let yi = a.forward_step(&xi, &mut cache);
            for c in 0..12 {
                assert!(
                    (y_full.at(i, c) - yi.at(0, c)).abs() < 1e-4,
                    "pos {i} col {c}: {} vs {}",
                    y_full.at(i, c),
                    yi.at(0, c)
                );
            }
        }
    }

    #[test]
    fn cache_clear_resets() {
        let mut rng = Rng::new(4);
        let a = Attention::random(8, 2, &mut rng);
        let x = Matrix::randn(1, 8, 1.0, &mut rng);
        let mut cache = KvCache::new(4, 8);
        let y1 = a.forward_step(&x, &mut cache);
        cache.clear();
        let y2 = a.forward_step(&x, &mut cache);
        assert!(y1.sq_dist(&y2) < 1e-12);
        assert_eq!(cache.len, 1);
    }

    #[test]
    #[should_panic(expected = "KV cache overflow")]
    fn cache_overflow_panics() {
        let mut rng = Rng::new(5);
        let a = Attention::random(8, 2, &mut rng);
        let x = Matrix::randn(1, 8, 1.0, &mut rng);
        let mut cache = KvCache::new(1, 8);
        a.forward_step(&x, &mut cache);
        a.forward_step(&x, &mut cache);
    }
}
