//! Evaluation: LM metrics (PPL, LAMBADA/PIQA/WinoGrande analogs),
//! classification accuracy, FLOPs and memory accounting, and the method
//! registry/harness the table benches are built on.

pub mod flops;
pub mod harness;
pub mod memory;
pub mod perplexity;
pub mod tasks;

pub mod tablegen;
pub use harness::{method_by_name, Assets, ALL_METHODS, DATA_SEED};
pub use perplexity::{choice_accuracy, continuation_score, lambada_accuracy, perplexity};
pub use tasks::{classification_accuracy, task_accuracy};
