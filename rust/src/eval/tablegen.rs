//! Table/figure generators — one function per paper table, shared by the
//! `rust/benches/table*.rs` harnesses and the CLI.
//!
//! Each function loads the pretrained checkpoint when `make artifacts` has
//! run (falling back to the deterministic random model otherwise — the
//! printed header says which), applies every method at the paper's
//! protocol, and prints the same rows the paper reports. Absolute numbers
//! differ from the paper (mini models, synthetic tasks); EXPERIMENTS.md
//! tracks the shape claims.

use super::harness::{method_by_name, Assets};
use super::{choice_accuracy, lambada_accuracy, perplexity, task_accuracy};
use crate::compress::{compress_model, CompressedModel};
use crate::eval::{flops, memory};
use crate::moe::{Model, ModelConfig};
use crate::util::bench::Table;
use crate::util::format_bytes;
use crate::util::stats::Summary;
use crate::Rng;

/// Scale knob: `RESMOE_BENCH_N` caps eval-set sizes, `RESMOE_BENCH_SEEDS`
/// the seed count (paper uses 3).
pub fn bench_n(default: usize) -> usize {
    std::env::var("RESMOE_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

pub fn bench_seeds() -> u64 {
    std::env::var("RESMOE_BENCH_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2)
}

fn top_layers(cfg: &ModelConfig) -> usize {
    (cfg.moe_layer_indices().len() * 3).div_ceil(4)
}

/// Compress `assets.model` with `method` at `rate` (paper protocol).
pub fn compress_with(
    assets: &Assets,
    method: &str,
    rate: f64,
    seed: u64,
) -> CompressedModel {
    let comp = method_by_name(method).unwrap_or_else(|| panic!("unknown method {method}"));
    let calib = assets.calibration_tokens(assets.model.cfg.max_seq);
    let mut rng = Rng::new(seed);
    compress_model(
        &assets.model,
        comp.as_ref(),
        rate,
        top_layers(&assets.model.cfg),
        Some(&calib),
        &mut rng,
    )
}

fn provenance(assets: &Assets) -> &'static str {
    if assets.pretrained {
        "pretrained checkpoint"
    } else {
        "RANDOM fallback — run `make artifacts` for trained weights"
    }
}

// ================================================================= Table 1

pub const T1_METHODS: [&str; 9] = [
    "up-concat", "wanda", "sp-concat", "svd-concat", "m-smoe", "meo", "mlp-fusion",
    "resmoe-up", "resmoe-svd",
];

/// Table 1: layer approximation error (×pI-normalized) on both backbones.
pub fn table1() -> Table {
    let seeds = bench_seeds();
    let sw = Assets::load(&ModelConfig::switch_mini(8));
    let mx = Assets::load(&ModelConfig::mixtral_mini());
    let mut t = Table::new(
        &format!(
            "Table 1 — Approximation error (switch: {}; mixtral: {})",
            provenance(&sw),
            provenance(&mx)
        ),
        &["method", "Switch Transformer", "Mixtral"],
    );
    for method in T1_METHODS {
        let cell = |assets: &Assets| {
            let errs: Vec<f64> = (0..seeds)
                .map(|s| compress_with(assets, method, 0.25, s).report.mean_approx_error())
                .collect();
            Summary::of(&errs).cell(4)
        };
        t.row(vec![method.to_string(), cell(&sw), cell(&mx)]);
    }
    t
}

// ================================================================= Table 2

pub const T2_METHODS: [&str; 12] = [
    "up-concat", "up-sep", "wanda", "sp-concat", "sp-sep", "svd-concat", "svd-sep",
    "m-smoe", "git-re-basin", "meo", "mlp-fusion", "resmoe-up",
];

/// Table 2: NLU accuracy of switch-mini-8 across the four GLUE analogs.
pub fn table2() -> Table {
    let n = bench_n(150);
    let seeds = bench_seeds();
    let assets = Assets::load(&ModelConfig::switch_mini(8));
    let tasks = ["sst2", "mrpc", "cola", "mnli"];
    let mut t = Table::new(
        &format!("Table 2 — Switch Transformer NLU accuracy ({})", provenance(&assets)),
        &["method", "SST-2", "MRPC", "CoLA", "MNLI"],
    );
    let eval_model = |model: &Model, task: &str| -> f64 {
        task_accuracy(model, task, &assets.nlu_test(task, n)).unwrap_or(f64::NAN) * 100.0
    };
    let mut base_row = vec!["switch-mini-8 (full)".to_string()];
    for task in tasks {
        base_row.push(format!("{:.2}", eval_model(&assets.model, task)));
    }
    t.row(base_row);
    let mut methods: Vec<&str> = T2_METHODS.to_vec();
    methods.push("resmoe-svd");
    for method in methods {
        let mut row = vec![method.to_string()];
        for task in tasks {
            let accs: Vec<f64> = (0..seeds)
                .map(|s| eval_model(&compress_with(&assets, method, 0.25, s).model, task))
                .collect();
            row.push(Summary::of(&accs).cell(2));
        }
        t.row(row);
    }
    t
}

// ================================================================= Table 3

pub const T3_METHODS: [&str; 10] = [
    "up-concat", "wanda", "sp-concat", "svd-concat", "m-smoe", "git-re-basin", "meo",
    "expert-pruning", "mlp-fusion", "resmoe-up",
];

/// Table 3: zero-shot NLG on mixtral-mini (PPL + three accuracies).
pub fn table3() -> Table {
    let n = bench_n(150);
    let seeds = bench_seeds();
    let assets = Assets::load(&ModelConfig::mixtral_mini());
    let lam = assets.lambada(n);
    let piqa = assets.piqa(n);
    let wino = assets.winogrande(n);
    let mut t = Table::new(
        &format!("Table 3 — Mixtral zero-shot NLG ({})", provenance(&assets)),
        &["method", "WikiText (PPL) ↓", "LAMBADA (ACC)", "PIQA (ACC)", "WinoGrande (ACC)"],
    );
    let eval_all = |m: &Model| -> [f64; 4] {
        [
            perplexity(m, &assets.valid, m.cfg.max_seq),
            lambada_accuracy(m, &lam) * 100.0,
            choice_accuracy(m, &piqa) * 100.0,
            choice_accuracy(m, &wino) * 100.0,
        ]
    };
    let base = eval_all(&assets.model);
    t.row(vec![
        "mixtral-mini (full)".into(),
        format!("{:.3}", base[0]),
        format!("{:.2}", base[1]),
        format!("{:.2}", base[2]),
        format!("{:.2}", base[3]),
    ]);
    let mut methods: Vec<&str> = T3_METHODS.to_vec();
    methods.push("resmoe-svd");
    for method in methods {
        let mut cols: [Vec<f64>; 4] = Default::default();
        for s in 0..seeds {
            let vals = eval_all(&compress_with(&assets, method, 0.25, s).model);
            for (c, v) in cols.iter_mut().zip(vals) {
                c.push(v);
            }
        }
        t.row(vec![
            method.to_string(),
            Summary::of(&cols[0]).cell(3),
            Summary::of(&cols[1]).cell(2),
            Summary::of(&cols[2]).cell(2),
            Summary::of(&cols[3]).cell(2),
        ]);
    }
    t
}

// ================================================================= Table 4

/// Table 4: center ablation — UP vs Avg/Git/WB + UP; SVD vs WB + SVD.
pub fn table4() -> Table {
    let n = bench_n(150);
    let sw = Assets::load(&ModelConfig::switch_mini(8));
    let mx = Assets::load(&ModelConfig::mixtral_mini());
    let lam = mx.lambada(n);
    let piqa = mx.piqa(n);
    let wino = mx.winogrande(n);
    let mut t = Table::new(
        &format!(
            "Table 4 — Center ablation (switch: {}; mixtral: {})",
            provenance(&sw),
            provenance(&mx)
        ),
        &["method", "SST-2", "MRPC", "MNLI", "LAMBADA", "PIQA", "WinoGrande"],
    );
    let rows: [(&str, &str); 6] = [
        ("UP", "up-concat"),
        ("Avg + UP", "resmoe-avg+up"),
        ("Git + UP", "resmoe-git+up"),
        ("WB + UP (ResMoE)", "resmoe-up"),
        ("SVD", "svd-concat"),
        ("WB + SVD (ResMoE)", "resmoe-svd"),
    ];
    for (label, method) in rows {
        let sw_m = compress_with(&sw, method, 0.25, 0).model;
        let mx_m = compress_with(&mx, method, 0.25, 0).model;
        t.row(vec![
            label.to_string(),
            format!("{:.2}", task_accuracy(&sw_m, "sst2", &sw.nlu_test("sst2", n)).unwrap_or(f64::NAN) * 100.0),
            format!("{:.2}", task_accuracy(&sw_m, "mrpc", &sw.nlu_test("mrpc", n)).unwrap_or(f64::NAN) * 100.0),
            format!("{:.2}", task_accuracy(&sw_m, "mnli", &sw.nlu_test("mnli", n)).unwrap_or(f64::NAN) * 100.0),
            format!("{:.2}", lambada_accuracy(&mx_m, &lam) * 100.0),
            format!("{:.2}", choice_accuracy(&mx_m, &piqa) * 100.0),
            format!("{:.2}", choice_accuracy(&mx_m, &wino) * 100.0),
        ]);
    }
    t
}

// ================================================================= Table 5

/// Table 5: switch-base-16 scale test (MRPC analog).
pub fn table5() -> Table {
    let n = bench_n(150);
    let assets = Assets::load(&ModelConfig::switch_mini(16));
    let mut t = Table::new(
        &format!("Table 5 — switch-mini-16 on MRPC ({})", provenance(&assets)),
        &["method", "MRPC"],
    );
    let examples = assets.nlu_test("mrpc", n);
    let acc = |m: &Model| task_accuracy(m, "mrpc", &examples).unwrap_or(f64::NAN) * 100.0;
    t.row(vec!["switch-mini-16 (full)".into(), format!("{:.2}", acc(&assets.model))]);
    for method in [
        "up-concat", "up-sep", "sp-concat", "sp-sep", "svd-concat", "svd-sep", "m-smoe",
        "meo", "mlp-fusion", "resmoe-up",
    ] {
        let m = compress_with(&assets, method, 0.25, 0).model;
        t.row(vec![method.to_string(), format!("{:.2}", acc(&m))]);
    }
    t
}

// ================================================================= Table 7

/// Table 7: DeepSeekMoE zero-shot (shared expert excluded from
/// compression).
pub fn table7() -> Table {
    let n = bench_n(100);
    let assets = Assets::load(&ModelConfig::deepseek_mini());
    let piqa = assets.piqa(n);
    let wino = assets.winogrande(n);
    let mut t = Table::new(
        &format!("Table 7 — DeepSeekMoE zero-shot ({})", provenance(&assets)),
        &["method", "WikiText (PPL) ↓", "PIQA (ACC)", "WinoGrande (ACC)"],
    );
    let eval_all = |m: &Model| -> [f64; 3] {
        [
            perplexity(m, &assets.valid, m.cfg.max_seq),
            choice_accuracy(m, &piqa) * 100.0,
            choice_accuracy(m, &wino) * 100.0,
        ]
    };
    let base = eval_all(&assets.model);
    t.row(vec![
        "deepseek-mini (full)".into(),
        format!("{:.3}", base[0]),
        format!("{:.2}", base[1]),
        format!("{:.2}", base[2]),
    ]);
    for method in ["up-concat", "svd-concat", "m-smoe", "meo", "resmoe-up"] {
        let vals = eval_all(&compress_with(&assets, method, 0.25, 0).model);
        t.row(vec![
            method.to_string(),
            format!("{:.3}", vals[0]),
            format!("{:.2}", vals[1]),
            format!("{:.2}", vals[2]),
        ]);
    }
    t
}

// ================================================================ Table 10

/// Table 10: memory of one compressed MoE layer, Mixtral & DeepSeek
/// geometries, including the App.-A.7 storage-scheme rows.
pub fn table10() -> Table {
    let mut t = Table::new(
        "Table 10 — Memory of one MoE layer (+ App. A.7 storage schemes)",
        &["method", "Mixtral-mini", "DeepSeek-mini"],
    );
    let build = |cfg: &ModelConfig, seed: u64| {
        let mut rng = Rng::new(seed);
        crate::moe::MoeLayer::random(
            cfg.arch,
            cfg.d_model,
            cfg.d_inner,
            cfg.n_experts,
            cfg.top_k,
            true,
            false,
            &mut rng,
        )
    };
    let mx = build(&ModelConfig::mixtral_mini(), 1);
    let ds = build(&ModelConfig::deepseek_mini(), 2);
    t.row(vec![
        "Full (dense f32)".into(),
        format_bytes(memory::dense_layer_bytes(&mx)),
        format_bytes(memory::dense_layer_bytes(&ds)),
    ]);
    let methods = [
        "up-concat", "sp-concat", "svd-concat", "m-smoe", "git-re-basin", "meo",
        "mlp-fusion", "resmoe-up", "resmoe-svd",
    ];
    for method in methods {
        let comp = method_by_name(method).unwrap();
        let cell = |layer: &crate::moe::MoeLayer| {
            let cl = crate::baselines::quick_compress(comp.as_ref(), layer, 0.25, 3);
            format_bytes(cl.memory_bytes())
        };
        t.row(vec![method.to_string(), cell(&mx), cell(&ds)]);
    }
    // App. A.7 scheme rows for the UP representation.
    let comp = method_by_name("up-concat").unwrap();
    for (label, scheme) in [
        ("UP stored as COO+int64 (pytorch default)", memory::SparseScheme::CooI64),
        ("UP stored as COO+int16", memory::SparseScheme::CooI16),
        ("UP stored as CSR+int16 (ours)", memory::SparseScheme::CsrI16),
    ] {
        let cell = |layer: &crate::moe::MoeLayer| {
            let cl = crate::baselines::quick_compress(comp.as_ref(), layer, 0.25, 3);
            format_bytes(memory::layer_bytes_under_scheme(&cl, scheme))
        };
        t.row(vec![label.to_string(), cell(&mx), cell(&ds)]);
    }
    t
}

// ================================================================ Table 11

/// Table 11: serving runtime on the WinoGrande analog, batch 64, through
/// the coordinator (native engine, restored weights — matching the paper's
/// protocol where UP runs restored-dense).
pub fn table11() -> Table {
    use crate::coordinator::{Engine, Request, Server, ServerConfig};
    let n_req = bench_n(64);
    let assets = Assets::load(&ModelConfig::mixtral_mini());
    let wino = assets.winogrande(n_req);
    let mut t = Table::new(
        &format!("Table 11 — Runtime on WinoGrande analog, {} requests ({})", n_req, provenance(&assets)),
        &["method", "runtime (s)", "req/s", "p99 (ms)"],
    );
    let run = |model: Model| -> (f64, f64, f64) {
        let engine = Engine::dense(model);
        let server = Server::start(
            engine,
            ServerConfig { batch_max: 8, batch_wait_us: 200, workers: 2, ..Default::default() },
        );
        let t0 = std::time::Instant::now();
        let replies: Vec<_> = wino
            .iter()
            .map(|e| {
                let mut tokens = e.prefix.clone();
                tokens.extend_from_slice(&e.choices[e.label]);
                server.submit(Request::Score { tokens })
            })
            .collect();
        for r in replies {
            r.recv().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = server.shutdown();
        (wall, n_req as f64 / wall, m.p99_ms())
    };
    let (w, rps, p99) = run(assets.model.clone());
    t.row(vec!["mixtral-mini (full)".into(), format!("{w:.3}"), format!("{rps:.1}"), format!("{p99:.2}")]);
    for method in ["up-concat", "sp-concat", "svd-concat", "m-smoe", "meo", "mlp-fusion", "resmoe-up", "resmoe-svd"] {
        let cm = compress_with(&assets, method, 0.25, 0);
        let (w, rps, p99) = run(cm.model);
        t.row(vec![method.to_string(), format!("{w:.3}"), format!("{rps:.1}"), format!("{p99:.2}")]);
    }
    t
}

// ================================================================ Table 12

/// Table 12: analytic FLOPs per token through one MoE layer.
pub fn table12() -> Table {
    let mut t = Table::new(
        "Table 12 — FLOPs per token per MoE layer (analytic)",
        &["method", "Mixtral-mini (KFLOPs)", "DeepSeek-mini (KFLOPs)"],
    );
    let build = |cfg: &ModelConfig, seed: u64| {
        let mut rng = Rng::new(seed);
        (
            crate::moe::MoeLayer::random(
                cfg.arch, cfg.d_model, cfg.d_inner, cfg.n_experts, cfg.top_k, true,
                cfg.shared_expert, &mut rng,
            ),
            cfg.top_k,
        )
    };
    let (mx, mx_k) = build(&ModelConfig::mixtral_mini(), 1);
    let (ds, ds_k) = build(&ModelConfig::deepseek_mini(), 2);
    let kf = |f: usize| format!("{:.1}", f as f64 / 1e3);
    t.row(vec![
        "Full".into(),
        kf(flops::layer_flops(&mx, mx_k)),
        kf(flops::layer_flops(&ds, ds_k)),
    ]);
    for (method, sparse_exec) in [
        ("up-concat", true),
        ("sp-concat", false),
        ("svd-concat", false),
        ("m-smoe", false),
        ("git-re-basin", false),
        ("meo", false),
        ("mlp-fusion", false),
        ("resmoe-up", false),
        ("resmoe-svd", false),
    ] {
        let comp = method_by_name(method).unwrap();
        let cell = |layer: &crate::moe::MoeLayer, k: usize| {
            let cl = crate::baselines::quick_compress(comp.as_ref(), layer, 0.25, 3);
            kf(flops::compressed_layer_flops(&cl, layer, k, sparse_exec))
        };
        t.row(vec![method.to_string(), cell(&mx, mx_k), cell(&ds, ds_k)]);
    }
    t
}

// ================================================================ Figure 4

pub const FIG4_METHODS: [&str; 6] =
    ["up-concat", "svd-concat", "meo", "git-re-basin", "resmoe-up", "resmoe-svd"];

/// Figure 4: LAMBADA-analog accuracy vs compression rate on mixtral-mini.
/// (Merge methods bottom out at one group — the paper's "cannot reach 10 %"
/// observation — so their low-rate cells report the 1-group floor.)
pub fn fig4(rates: &[f64]) -> Table {
    let n = bench_n(150);
    let assets = Assets::load(&ModelConfig::mixtral_mini());
    let lam = assets.lambada(n);
    let mut header: Vec<String> = vec!["method".into()];
    header.extend(rates.iter().map(|r| format!("{:.0} %", r * 100.0)));
    let headers: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Figure 4 — LAMBADA-analog accuracy vs compression rate ({})", provenance(&assets)),
        &headers,
    );
    let base = lambada_accuracy(&assets.model, &lam) * 100.0;
    let mut row = vec!["mixtral-mini (full)".to_string()];
    row.extend(rates.iter().map(|_| format!("{base:.2}")));
    t.row(row);
    for method in FIG4_METHODS {
        let mut row = vec![method.to_string()];
        for &rate in rates {
            let acc =
                lambada_accuracy(&compress_with(&assets, method, rate, 0).model, &lam) * 100.0;
            row.push(format!("{acc:.2}"));
        }
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_knobs_parse() {
        // (The preset-scale tables themselves are exercised by
        // `cargo bench` — far too slow for debug-build unit tests.)
        assert!(bench_n(100) >= 1);
        assert!(bench_seeds() >= 1);
    }

    #[test]
    fn compress_with_respects_seed_determinism() {
        let assets = Assets::load(&{
            let mut c = ModelConfig::switch_mini(4);
            c.d_model = 16;
            c.d_inner = 32;
            c.n_layers = 2;
            c.n_heads = 2;
            c.vocab_size = 64;
            c.max_seq = 32;
            c
        });
        let a = compress_with(&assets, "resmoe-up", 0.25, 7);
        let b = compress_with(&assets, "resmoe-up", 0.25, 7);
        assert_eq!(a.report.mean_approx_error(), b.report.mean_approx_error());
    }
}
