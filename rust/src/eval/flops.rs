//! Analytic FLOPs accounting (paper Table 12, App. A.8).
//!
//! Counts multiply-accumulates ×2 for a single token through one MoE layer
//! under each storage scheme. Mirrors the paper's observations: merge
//! methods keep full FLOPs (they only reduce the expert *count*, the routed
//! computation per token is unchanged), SP/MLP-Fusion cut FLOPs via the
//! intermediate dimension, SVD replaces one matmul with two thin ones, and
//! ResMoE(UP) restores to dense so FLOPs match the full model while
//! ResMoE(SVD) adds the center's dense cost to the thin residual factors.

use crate::compress::{CompressedLayer, ResidualRepr};
use crate::moe::{ExpertArch, MoeLayer};

/// FLOPs for one token through one dense expert.
pub fn dense_expert_flops(arch: ExpertArch, p: usize, pi: usize) -> usize {
    let gates = if arch == ExpertArch::SwiGlu { 2 } else { 1 };
    // gates × (W1-like matmuls) + W2.
    2 * pi * p * gates + 2 * p * pi
}

/// FLOPs for one token through an original MoE layer (`top_k` experts +
/// optional shared expert).
pub fn layer_flops(layer: &MoeLayer, top_k: usize) -> usize {
    let e = &layer.experts[0];
    let per = dense_expert_flops(e.arch, e.d_model(), e.d_inner());
    let shared = layer.shared_expert.as_ref().map(|_| per).unwrap_or(0);
    let router = 2 * layer.n_experts() * e.d_model();
    top_k * per + shared + router
}

/// FLOPs for one token through a compressed layer at inference, assuming
/// the execution strategy native to each representation:
/// * Dense (incl. restored UP / merge centers) → dense expert cost over the
///   *effective* intermediate dimension (`accounted_params / cols` rows).
/// * SparseCsr executed as restored-dense (the paper: UP "does not reduce
///   FLOPs" when stored restored) — unless `sparse_exec` is set, in which
///   case nnz MACs are counted (the paper's Table-12 UP row).
/// * LowRank → two thin matmuls per factor application **plus** the shared
///   center's dense cost when a base is present (ResMoE(SVD) row).
pub fn compressed_layer_flops(
    cl: &CompressedLayer,
    original: &MoeLayer,
    top_k: usize,
    sparse_exec: bool,
) -> usize {
    let e0 = &original.experts[0];
    let (p, pi) = (e0.d_model(), e0.d_inner());
    let dense = dense_expert_flops(cl.arch, p, pi);
    let per_expert = |idx: usize| -> usize {
        let ce = &cl.experts[idx];
        match &ce.residual {
            ResidualRepr::Dense(m) => {
                // Effective rows actually stored (SP / MLP fusion shrink pI).
                let eff_rows = (ce.accounted_params / m.cols.max(1)).min(pi);
                let scale = eff_rows as f64 / pi as f64;
                let base = if cl.base.is_some() { dense } else { 0 };
                base + (dense as f64 * scale) as usize
            }
            ResidualRepr::SparseCsr(csr) => {
                if sparse_exec {
                    let base = if cl.base.is_some() { dense } else { 0 };
                    base + 2 * csr.nnz()
                } else {
                    // Restored to dense before the matmul (App. A.8: same
                    // runtime/FLOPs as the full model).
                    dense
                }
            }
            ResidualRepr::LowRank(svd) => {
                let k = svd.s.len();
                // x → Vᵀx (k·cols) → U(Σ·) (rows·k), applied on the design
                // matrix's factored form.
                let factors = 2 * k * svd.vt.cols + 2 * svd.u.rows * k;
                let base = if cl.base.is_some() { dense } else { 0 };
                base + factors
            }
        }
    };
    // Average over router slots (slots may share stored experts).
    let avg: f64 = (0..cl.expert_map.len())
        .map(|s| per_expert(cl.expert_map[s]) as f64)
        .sum::<f64>()
        / cl.expert_map.len() as f64;
    let shared = original.shared_expert.as_ref().map(|_| dense).unwrap_or(0);
    let router = 2 * original.n_experts() * p;
    (top_k as f64 * avg) as usize + shared + router
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::compress::{prune::UnstructuredPruning, svd_compress::SvdCompression, ResMoE};
    use crate::util::Rng;

    fn layer(seed: u64) -> MoeLayer {
        let mut rng = Rng::new(seed);
        MoeLayer::random(ExpertArch::Relu, 16, 64, 8, 2, false, false, &mut rng)
    }

    #[test]
    fn dense_formula() {
        assert_eq!(dense_expert_flops(ExpertArch::Relu, 4, 8), 2 * 8 * 4 + 2 * 4 * 8);
        assert_eq!(
            dense_expert_flops(ExpertArch::SwiGlu, 4, 8),
            2 * (2 * 8 * 4) + 2 * 4 * 8
        );
    }

    #[test]
    fn paper_table12_ordering() {
        // Full == merge == ResMoE(UP, restored) > ResMoE(SVD) > SVD > SP.
        let l = layer(1);
        let full = layer_flops(&l, 2);
        let meo = compressed_layer_flops(&quick_compress(&crate::baselines::Meo, &l, 0.25, 1), &l, 2, false);
        let up = compressed_layer_flops(
            &quick_compress(&UnstructuredPruning { concat: true }, &l, 0.25, 1),
            &l,
            2,
            false,
        );
        let resmoe_up =
            compressed_layer_flops(&quick_compress(&ResMoE::up(), &l, 0.25, 1), &l, 2, false);
        let resmoe_svd =
            compressed_layer_flops(&quick_compress(&ResMoE::svd(), &l, 0.25, 1), &l, 2, false);
        let svd = compressed_layer_flops(
            &quick_compress(&SvdCompression { concat: true }, &l, 0.25, 1),
            &l,
            2,
            false,
        );
        let sp = compressed_layer_flops(
            &quick_compress(&crate::compress::prune::StructuredPruning { concat: true }, &l, 0.25, 1),
            &l,
            2,
            false,
        );
        assert_eq!(meo, full);
        assert_eq!(up, full);
        assert_eq!(resmoe_up, full);
        assert!(resmoe_svd > svd, "resmoe_svd={resmoe_svd} svd={svd}");
        assert!(resmoe_svd < full + full, "bounded by 2x full");
        assert!(svd < full);
        assert!(sp < full);
    }

    #[test]
    fn sparse_exec_reduces_up_flops() {
        let l = layer(2);
        let cl = quick_compress(&UnstructuredPruning { concat: true }, &l, 0.25, 2);
        let restored = compressed_layer_flops(&cl, &l, 2, false);
        let sparse = compressed_layer_flops(&cl, &l, 2, true);
        assert!(sparse < restored / 2, "sparse={sparse} restored={restored}");
    }
}
