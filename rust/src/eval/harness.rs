//! Evaluation harness: the method registry shared by every table bench and
//! the CLI, plus asset loading (pretrained checkpoints from `artifacts/`,
//! with a deterministic random-model fallback so tests and benches run
//! before `make artifacts`).

use crate::baselines::{ExpertPruning, GitReBasinMerge, Meo, MlpFusion, MSmoe, OtFusion};
use crate::compress::{
    prune::{StructuredPruning, UnstructuredPruning},
    svd_compress::SvdCompression,
    wanda::Wanda,
    CenterKind, Compressor, ResMoE, ResidualKind,
};
use crate::data::{tasks as dgen, Corpus, Language};
use crate::moe::{model_io, Model, ModelConfig};
use crate::util::Rng;
use std::path::{Path, PathBuf};

/// All method names in the order the paper's tables list them.
pub const ALL_METHODS: [&str; 14] = [
    "up-concat",
    "up-sep",
    "wanda",
    "sp-concat",
    "sp-sep",
    "svd-concat",
    "svd-sep",
    "m-smoe",
    "git-re-basin",
    "meo",
    "expert-pruning",
    "mlp-fusion",
    "resmoe-up",
    "resmoe-svd",
];

/// Instantiate a compressor by its registry name.
pub fn method_by_name(name: &str) -> Option<Box<dyn Compressor>> {
    Some(match name {
        "up-concat" => Box::new(UnstructuredPruning { concat: true }),
        "up-sep" => Box::new(UnstructuredPruning { concat: false }),
        "sp-concat" => Box::new(StructuredPruning { concat: true }),
        "sp-sep" => Box::new(StructuredPruning { concat: false }),
        "svd-concat" => Box::new(SvdCompression { concat: true }),
        "svd-sep" => Box::new(SvdCompression { concat: false }),
        "wanda" => Box::new(Wanda),
        "m-smoe" => Box::new(MSmoe),
        "git-re-basin" => Box::new(GitReBasinMerge),
        "meo" => Box::new(Meo),
        "expert-pruning" => Box::new(ExpertPruning),
        "mlp-fusion" => Box::new(MlpFusion),
        "resmoe-up" => Box::new(ResMoE::up()),
        "resmoe-svd" => Box::new(ResMoE::svd()),
        "resmoe-avg+up" => Box::new(ResMoE::with_center(CenterKind::Average, ResidualKind::PruneConcat)),
        "resmoe-git+up" => {
            Box::new(ResMoE::with_center(CenterKind::GitReBasin, ResidualKind::PruneConcat))
        }
        "ot-fusion" => Box::new(OtFusion),
        _ => return None,
    })
}

/// Everything needed to evaluate one model family.
pub struct Assets {
    pub model: Model,
    pub language: Language,
    /// Held-out token stream for PPL.
    pub valid: Vec<u32>,
    /// Whether the model came from a pretrained checkpoint (vs random
    /// fallback).
    pub pretrained: bool,
}

/// Artifacts directory (env `RESMOE_ARTIFACTS` overrides `artifacts/`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("RESMOE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Shared data seed — must match `resmoe datagen` and pretrain.py.
pub const DATA_SEED: u64 = 20250703;

impl Assets {
    /// Load the pretrained checkpoint for `cfg` if present, else fall back
    /// to a deterministic random model (tests, pre-artifact runs).
    pub fn load(cfg: &ModelConfig) -> Assets {
        let path = artifacts_dir().join(format!("{}.rmw", cfg.name));
        Self::load_from(cfg, &path)
    }

    pub fn load_from(cfg: &ModelConfig, path: &Path) -> Assets {
        let (model, pretrained) = match model_io::load_model(path) {
            Ok(m) => (m, true),
            Err(_) => {
                let mut rng = Rng::new(0xBA5E ^ cfg.n_experts as u64);
                (Model::random(cfg, &mut rng), false)
            }
        };
        let corpus = Corpus::generate(cfg.vocab_size, 2_000, 4_096, DATA_SEED);
        Assets { model, language: corpus.language, valid: corpus.valid, pretrained }
    }

    /// Calibration tokens (C4-analog) for data-dependent methods.
    pub fn calibration_tokens(&self, len: usize) -> Vec<u32> {
        let mut rng = Rng::new(DATA_SEED ^ 0xCA11B);
        self.language.generate(len.min(self.model.cfg.max_seq), &mut rng)
    }

    /// Zero-shot datasets (deterministic).
    pub fn lambada(&self, n: usize) -> Vec<crate::data::LambadaExample> {
        let mut rng = Rng::new(DATA_SEED ^ 1);
        dgen::gen_lambada(&self.language, n, self.model.cfg.max_seq - 4, &mut rng)
    }

    pub fn piqa(&self, n: usize) -> Vec<crate::data::ChoiceExample> {
        let mut rng = Rng::new(DATA_SEED ^ 2);
        dgen::gen_piqa(&self.language, n, self.model.cfg.max_seq - 8, &mut rng)
    }

    pub fn winogrande(&self, n: usize) -> Vec<crate::data::ChoiceExample> {
        let mut rng = Rng::new(DATA_SEED ^ 3);
        dgen::gen_winogrande(&self.language, n, self.model.cfg.max_seq - 8, &mut rng)
    }

    /// NLU test split: prefers the exported artifacts (identical to what the
    /// python heads were trained against), falls back to regeneration.
    pub fn nlu_test(&self, task: &str, n: usize) -> Vec<crate::data::Example> {
        let path = artifacts_dir().join("data").join(format!("{task}.json"));
        if let Ok(examples) = crate::data::export::load_examples(&path, "test") {
            return examples.into_iter().take(n).collect();
        }
        let mut rng = Rng::new(DATA_SEED ^ 0x7A5C5);
        // Mirror export ordering: train (2000) then test (400) per task, in
        // NLU_TASKS order.
        let mut out = Vec::new();
        for t in dgen::NLU_TASKS {
            let _train = dgen::gen_nlu(t, &self.language, 2000, 96, &mut rng);
            let test = dgen::gen_nlu(t, &self.language, 400, 96, &mut rng);
            if t == task {
                out = test;
            }
        }
        out.into_iter().take(n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_instantiates_everything() {
        for name in ALL_METHODS {
            let m = method_by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(m.name().is_empty(), false);
        }
        assert!(method_by_name("resmoe-avg+up").is_some());
        assert!(method_by_name("ot-fusion").is_some());
        assert!(method_by_name("nope").is_none());
    }

    #[test]
    fn assets_fallback_is_deterministic() {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let a = Assets::load_from(&cfg, Path::new("/nonexistent/x.rmw"));
        let b = Assets::load_from(&cfg, Path::new("/nonexistent/x.rmw"));
        assert!(!a.pretrained);
        let t: Vec<u32> = vec![1, 2, 3, 4];
        assert!(a.model.forward(&t).sq_dist(&b.model.forward(&t)) < 1e-12);
        assert_eq!(a.valid, b.valid);
    }

    #[test]
    fn zero_shot_datasets_fit_context() {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 48;
        let a = Assets::load_from(&cfg, Path::new("/nonexistent/x.rmw"));
        for e in a.lambada(20) {
            assert!(e.context.len() <= 48);
        }
        for e in a.piqa(20) {
            assert!(e.prefix.len() + e.choices[0].len().max(e.choices[1].len()) <= 56);
        }
    }
}
