//! Memory accounting (paper Table 10 and App. A.7).
//!
//! Reports the bytes one compressed MoE layer occupies under each storage
//! scheme, including the App.-A.7 pathologies: COO with int64 indices makes
//! a 75 %-pruned layer LARGER than dense; CSR with int16 indices realizes
//! the saving.

use crate::compress::{CompressedLayer, ResidualRepr};
use crate::moe::MoeLayer;
use crate::tensor::sparse::{Coo, IndexWidth};

/// Bytes of the original dense layer's experts.
pub fn dense_layer_bytes(layer: &MoeLayer) -> usize {
    layer.expert_params() * 4
}

/// Storage-scheme variants for sparse residuals/matrices (App. A.7 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparseScheme {
    /// PyTorch-default COO with int64 indices.
    CooI64,
    /// COO with int16 indices.
    CooI16,
    /// CSR with int16 column indices (our default).
    CsrI16,
}

/// Bytes of a compressed layer if its sparse parts used `scheme`.
pub fn layer_bytes_under_scheme(cl: &CompressedLayer, scheme: SparseScheme) -> usize {
    let base = cl.base.as_ref().map(|b| b.n_params() * 4).unwrap_or(0);
    base + cl
        .experts
        .iter()
        .map(|e| {
            let repr = match &e.residual {
                ResidualRepr::SparseCsr(csr) => match scheme {
                    SparseScheme::CsrI16 => {
                        let mut c = csr.clone();
                        c.index_width = IndexWidth::U16;
                        c.memory_bytes()
                    }
                    SparseScheme::CooI64 => {
                        let coo = Coo::from_dense(&csr.to_dense(), IndexWidth::U64);
                        coo.memory_bytes()
                    }
                    SparseScheme::CooI16 => {
                        let coo = Coo::from_dense(&csr.to_dense(), IndexWidth::U16);
                        coo.memory_bytes()
                    }
                },
                ResidualRepr::Dense(_) => e.accounted_params * 4,
                ResidualRepr::LowRank(s) => s.n_params() * 4,
            };
            repr + e.b2.len() * 4
        })
        .sum::<usize>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::compress::{prune::UnstructuredPruning, ResMoE};
    use crate::moe::ExpertArch;
    use crate::util::Rng;

    fn layer(seed: u64) -> MoeLayer {
        let mut rng = Rng::new(seed);
        MoeLayer::random(ExpertArch::Relu, 16, 64, 8, 2, false, false, &mut rng)
    }

    #[test]
    fn appendix_a7_coo64_blowup() {
        // At 25 % density, COO+int64 exceeds the dense layer; CSR+int16
        // stays well under half.
        let l = layer(1);
        let cl = quick_compress(&UnstructuredPruning { concat: true }, &l, 0.25, 1);
        let dense = dense_layer_bytes(&l);
        let coo64 = layer_bytes_under_scheme(&cl, SparseScheme::CooI64);
        let csr16 = layer_bytes_under_scheme(&cl, SparseScheme::CsrI16);
        let coo16 = layer_bytes_under_scheme(&cl, SparseScheme::CooI16);
        assert!(coo64 > dense, "coo64={coo64} dense={dense}");
        assert!(csr16 < dense / 2, "csr16={csr16} dense={dense}");
        assert!(coo16 < coo64 && csr16 < coo16);
    }

    #[test]
    fn resmoe_center_overhead_diminishes_with_experts() {
        // Table 10's narrative: the barycenter overhead amortizes as the
        // expert count grows (8 experts vs 64).
        let overhead_fraction = |n_experts: usize, seed: u64| -> f64 {
            let mut rng = Rng::new(seed);
            let l = MoeLayer::random(ExpertArch::Relu, 8, 16, n_experts, 2, true, false, &mut rng);
            let cl = quick_compress(&ResMoE::up(), &l, 0.25, seed);
            let center = cl.base.as_ref().unwrap().n_params() * 4;
            center as f64 / cl.memory_bytes() as f64
        };
        let few = overhead_fraction(4, 2);
        let many = overhead_fraction(32, 3);
        assert!(many < few, "few={few} many={many}");
    }

    #[test]
    fn resmoe_up_between_svd_and_full() {
        // Table 10 shape: ResMoE(UP) costs more than plain SVD-style dense
        // reduction (center + sparse indices) but far less than full.
        let l = layer(4);
        let full = dense_layer_bytes(&l);
        let resmoe_up = quick_compress(&ResMoE::up(), &l, 0.25, 4).memory_bytes();
        let resmoe_svd = quick_compress(&ResMoE::svd(), &l, 0.25, 4).memory_bytes();
        assert!(resmoe_up < full, "resmoe_up={resmoe_up} full={full}");
        assert!(resmoe_svd < resmoe_up, "svd variant stores fewer bytes");
    }
}
