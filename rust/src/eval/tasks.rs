//! Classification-task evaluation (the paper's NLU tables): apply a trained
//! task head to the final hidden state and measure accuracy.

use crate::data::Example;
use crate::moe::Model;
use crate::tensor::Matrix;

/// Accuracy of `head` (n_classes × d) on classification examples.
pub fn classification_accuracy(model: &Model, head: &Matrix, examples: &[Example]) -> f64 {
    let mut correct = 0usize;
    for e in examples {
        let logits = model.classify(&e.tokens, head);
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if pred == e.label {
            correct += 1;
        }
    }
    correct as f64 / examples.len().max(1) as f64
}

/// Accuracy using the model's stored head for `task`; `None` if missing.
pub fn task_accuracy(model: &Model, task: &str, examples: &[Example]) -> Option<f64> {
    let head = model.head(task)?.clone();
    Some(classification_accuracy(model, &head, examples))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ModelConfig;
    use crate::util::Rng;

    #[test]
    fn random_head_near_chance_perfect_head_wins() {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 24;
        let mut rng = Rng::new(1);
        let m = Model::random(&cfg, &mut rng);
        // Labels decided by a fixed head => that head scores 100 %.
        let head = Matrix::randn(2, 16, 1.0, &mut rng);
        let examples: Vec<Example> = (0..40)
            .map(|i| {
                let tokens: Vec<u32> = (0..10).map(|t| ((t * (i + 3)) % 32) as u32).collect();
                let logits = m.classify(&tokens, &head);
                let label = if logits[1] > logits[0] { 1 } else { 0 };
                Example { tokens, label }
            })
            .collect();
        assert_eq!(classification_accuracy(&m, &head, &examples), 1.0);
        // An unrelated random head is imperfect.
        let other = Matrix::randn(2, 16, 1.0, &mut rng);
        let acc = classification_accuracy(&m, &other, &examples);
        assert!(acc < 1.0);
    }

    #[test]
    fn task_accuracy_uses_stored_head() {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 24;
        let mut rng = Rng::new(2);
        let mut m = Model::random(&cfg, &mut rng);
        assert!(task_accuracy(&m, "sst2", &[]).is_none());
        m.heads.push(("sst2".into(), Matrix::randn(2, 16, 0.1, &mut rng)));
        assert!(task_accuracy(&m, "sst2", &[]).is_some());
    }
}
