//! Language-model metrics: perplexity over a token stream (WikiText analog)
//! and the zero-shot LM-scored tasks (LAMBADA / PIQA / WinoGrande analogs).

use crate::data::{ChoiceExample, Corpus, LambadaExample};
use crate::moe::Model;
use crate::util::stats::logsumexp;

/// Log-probability of each next token in a window; returns (sum, count).
fn window_log_prob(model: &Model, window: &[u32]) -> (f64, usize) {
    let logits = model.forward(window);
    let mut total = 0.0f64;
    let mut count = 0usize;
    for i in 0..window.len() - 1 {
        let row = logits.row(i);
        let lse = logsumexp(row);
        total += (row[window[i + 1] as usize] - lse) as f64;
        count += 1;
    }
    (total, count)
}

/// Perplexity over a stream, evaluated in non-overlapping `window` chunks.
pub fn perplexity(model: &Model, stream: &[u32], window: usize) -> f64 {
    let window = window.min(model.cfg.max_seq);
    let mut total = 0.0f64;
    let mut count = 0usize;
    for w in Corpus::windows(stream, window) {
        let (t, c) = window_log_prob(model, w);
        total += t;
        count += c;
    }
    (-(total / count.max(1) as f64)).exp()
}

/// LAMBADA-analog accuracy: argmax next-token prediction of the final word
/// token.
pub fn lambada_accuracy(model: &Model, examples: &[LambadaExample]) -> f64 {
    let mut correct = 0usize;
    for e in examples {
        let logits = model.forward(&e.context);
        let last = logits.row(logits.rows - 1);
        let pred = last
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as u32)
            .unwrap();
        if pred == e.target {
            correct += 1;
        }
    }
    correct as f64 / examples.len().max(1) as f64
}

/// Sum log-prob of `continuation` given `prefix` (length-normalized), the
/// standard multiple-choice LM scoring rule.
pub fn continuation_score(model: &Model, prefix: &[u32], continuation: &[u32]) -> f64 {
    let mut seq = prefix.to_vec();
    seq.extend_from_slice(continuation);
    let max = model.cfg.max_seq;
    if seq.len() > max {
        seq.drain(..seq.len() - max);
    }
    let start = seq.len() - continuation.len();
    let logits = model.forward(&seq);
    let mut total = 0.0f64;
    for i in start..seq.len() {
        let row = logits.row(i - 1);
        total += (row[seq[i] as usize] - logsumexp(row)) as f64;
    }
    total / continuation.len() as f64
}

/// Accuracy on 2-choice LM-scored tasks (PIQA / WinoGrande analogs).
pub fn choice_accuracy(model: &Model, examples: &[ChoiceExample]) -> f64 {
    let mut correct = 0usize;
    for e in examples {
        let s0 = continuation_score(model, &e.prefix, &e.choices[0]);
        let s1 = continuation_score(model, &e.prefix, &e.choices[1]);
        let pred = if s0 >= s1 { 0 } else { 1 };
        if pred == e.label {
            correct += 1;
        }
    }
    correct as f64 / examples.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ModelConfig;
    use crate::util::Rng;

    fn tiny_model(seed: u64) -> Model {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        Model::random(&cfg, &mut rng)
    }

    #[test]
    fn random_model_ppl_near_vocab_size() {
        // An untrained model is ~uniform → PPL ≈ vocab size.
        let m = tiny_model(1);
        let c = Corpus::generate(32, 512, 0, 2);
        let ppl = perplexity(&m, &c.train, 32);
        assert!(ppl > 16.0 && ppl < 64.0, "ppl={ppl}");
    }

    #[test]
    fn oracle_bigram_model_beats_random() {
        // A model whose lm_head copies the embedding of the next-likely
        // token would do better; here we just check PPL moves with logits
        // sharpness: scaling lm_head changes PPL.
        let m = tiny_model(3);
        let c = Corpus::generate(32, 256, 0, 4);
        let ppl_base = perplexity(&m, &c.train, 32);
        let mut sharper = m.clone();
        sharper.lm_head = sharper.lm_head.scale(3.0);
        let ppl_sharp = perplexity(&sharper, &c.train, 32);
        assert!((ppl_base - ppl_sharp).abs() > 1e-6);
    }

    #[test]
    fn lambada_random_near_chance() {
        let m = tiny_model(5);
        let lang = crate::data::Language::new(32, 50, 6);
        let mut rng = Rng::new(7);
        let ex = crate::data::tasks::gen_lambada(&lang, 40, 30, &mut rng);
        let acc = lambada_accuracy(&m, &ex);
        assert!(acc < 0.5, "untrained acc={acc}");
    }

    #[test]
    fn choice_accuracy_bounds() {
        let m = tiny_model(8);
        let lang = crate::data::Language::new(32, 50, 9);
        let mut rng = Rng::new(10);
        let ex = crate::data::tasks::gen_piqa(&lang, 30, 24, &mut rng);
        let acc = choice_accuracy(&m, &ex);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn continuation_score_prefers_repeated_pattern() {
        // Make the model's embedding/lm_head aligned so that repeating the
        // same token is high-probability: score(same) > score(random) on
        // average for a model with tied-ish structure. Here we only check
        // the function is finite and sensitive to input.
        let m = tiny_model(11);
        let s1 = continuation_score(&m, &[1, 2, 3], &[4, 5]);
        let s2 = continuation_score(&m, &[1, 2, 3], &[9, 9]);
        assert!(s1.is_finite() && s2.is_finite());
        assert_ne!(s1, s2);
    }
}
