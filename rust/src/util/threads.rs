//! Data-parallel helpers over a **persistent worker pool** (rayon is not
//! vendored).
//!
//! The seed spawned scoped threads per matmul; at decode batch sizes the
//! spawn/join cost rivaled the arithmetic. The pool here is lazily
//! initialized on first parallel call, holds `RESMOE_THREADS` (or
//! `available_parallelism`) workers for the life of the process, and is fed
//! through an mpsc channel — the same workers serve the dense matmul
//! kernels, the sparse/low-rank fused-forward SpMMs, and the per-shard
//! compression in `compress/parallel.rs`.
//!
//! Nested parallel sections (a pool worker reaching a parallel helper) run
//! inline on the worker: tasks never block on other tasks, so the pool
//! cannot deadlock and needs no work-stealing.

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Number of worker threads to use (env `RESMOE_THREADS` overrides).
pub fn num_threads() -> usize {
    static CACHE: AtomicUsize = AtomicUsize::new(0);
    let cached = CACHE.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("RESMOE_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        });
    CACHE.store(n, Ordering::Relaxed);
    n
}

thread_local! {
    /// Set on pool workers so nested parallel calls degrade to inline
    /// serial execution instead of re-entering the pool.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

fn in_pool() -> bool {
    IN_POOL.with(|f| f.get())
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Pool {
    tx: Mutex<Sender<Job>>,
}

/// The process-wide pool, created on first use.
fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..num_threads() {
            let rx = Arc::clone(&rx);
            std::thread::Builder::new()
                .name(format!("resmoe-worker-{i}"))
                .spawn(move || {
                    IN_POOL.with(|f| f.set(true));
                    loop {
                        // Hold the receiver lock only to dequeue.
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn resmoe worker");
        }
        Pool { tx: Mutex::new(tx) }
    })
}

/// Countdown latch: the submitting thread blocks until every task of its
/// batch ran (or panicked — the drop guard still counts it down).
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn done(&self) {
        let mut g = self.remaining.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.remaining.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

struct DoneGuard(Arc<Latch>);

impl Drop for DoneGuard {
    fn drop(&mut self) {
        self.0.done();
    }
}

/// Run a batch of borrowing closures on the pool and block until all
/// completed. Safety: the closures may borrow the caller's stack because
/// this function does not return before every task has finished — the same
/// contract `std::thread::scope` enforces, with the lifetime erased to
/// cross the channel. Panics inside tasks are caught on the worker (so the
/// pool thread survives) and re-raised here after the batch drains, like
/// `thread::scope`'s join would.
fn run_scoped<'scope>(tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
    if tasks.is_empty() {
        return;
    }
    let latch = Arc::new(Latch { remaining: Mutex::new(tasks.len()), cv: Condvar::new() });
    let panic_slot: Arc<Mutex<Option<Box<dyn Any + Send>>>> = Arc::new(Mutex::new(None));
    let p = pool();
    for task in tasks {
        // Erase the scope lifetime; soundness comes from latch.wait() below.
        let task: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(task) };
        let guard = DoneGuard(Arc::clone(&latch));
        let panic_slot = Arc::clone(&panic_slot);
        let job: Job = Box::new(move || {
            let _guard = guard;
            if let Err(payload) = catch_unwind(AssertUnwindSafe(task)) {
                let mut slot = panic_slot.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
        });
        p.tx.lock().unwrap().send(job).expect("worker pool alive");
    }
    latch.wait();
    let payload = panic_slot.lock().unwrap().take();
    if let Some(payload) = payload {
        resume_unwind(payload);
    }
}

/// Submit one fire-and-forget job to the pool — the async-prefetch entry
/// point (`store::Prefetcher`). Unlike the scoped helpers this does NOT
/// block: the closure must own its captures (`'static`) and report its
/// result through whatever shared state it captured (e.g. the expert
/// cache's mutex). Called from a pool worker it runs inline instead, so a
/// pool saturated with blocking parallel batches cannot deadlock on its own
/// prefetch traffic.
pub fn spawn_detached(f: impl FnOnce() + Send + 'static) {
    if in_pool() {
        f();
        return;
    }
    let job: Job = Box::new(move || {
        // A panicking prefetch job must not kill the shared worker; the
        // outcome (a missing cache entry) is already tolerated by design.
        let _ = catch_unwind(AssertUnwindSafe(f));
    });
    pool().tx.lock().unwrap().send(job).expect("worker pool alive");
}

/// Run `f(start, end)` over disjoint chunks of `0..n` in parallel.
/// `f` must be `Sync` (immutable captures) — output goes through interior
/// mutability or per-chunk ownership (see `parallel_map`).
pub fn parallel_for_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n < 2 || in_pool() {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(workers);
    for w in 0..workers {
        let start = w * chunk;
        let end = ((w + 1) * chunk).min(n);
        if start >= end {
            break;
        }
        let f = &f;
        tasks.push(Box::new(move || f(start, end)));
    }
    run_scoped(tasks);
}

/// Split `data` (length `rows * row_len`) into at most `num_threads`
/// contiguous row chunks and run `f(first_row, chunk)` per chunk in
/// parallel — the blocked-matmul entry point (a worker keeps its packed
/// panel hot across its whole chunk).
pub fn parallel_row_chunks_mut<F>(data: &mut [f32], rows: usize, row_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    parallel_row_chunks_mut_aligned(data, rows, row_len, 1, f);
}

/// [`parallel_row_chunks_mut`] with **tile-granular** work splitting: chunk
/// boundaries are rounded up to multiples of `align`, so thread boundaries
/// coincide with microkernel row-tile edges (the SIMD GEMM passes its 6-row
/// tile, the SpMM its 8-lane batch tile) and only the final chunk carries a
/// ragged tail. `align = 1` reproduces the historical splitting exactly.
/// Chunking never changes results — every row's arithmetic is independent
/// of its chunk — this is purely about not splitting a tile across workers.
pub fn parallel_row_chunks_mut_aligned<F>(
    data: &mut [f32],
    rows: usize,
    row_len: usize,
    align: usize,
    f: F,
) where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert_eq!(data.len(), rows * row_len);
    if rows == 0 || row_len == 0 {
        return;
    }
    let align = align.max(1);
    let workers = num_threads().min(rows.div_ceil(align));
    if workers <= 1 || in_pool() {
        f(0, data);
        return;
    }
    let chunk_rows = rows.div_ceil(workers).div_ceil(align) * align;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(workers);
    let mut rest = data;
    let mut row0 = 0usize;
    while row0 < rows {
        let take = chunk_rows.min(rows - row0);
        let (head, tail) = rest.split_at_mut(take * row_len);
        let f = &f;
        let base = row0;
        tasks.push(Box::new(move || f(base, head)));
        rest = tail;
        row0 += take;
    }
    run_scoped(tasks);
}

/// Parallel map over mutable disjoint rows: `f(row_index, row)` for every
/// row of `data`, chunked across the pool.
pub fn parallel_rows_mut<F>(data: &mut [f32], rows: usize, row_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    parallel_row_chunks_mut(data, rows, row_len, |row0, chunk| {
        for (i, row) in chunk.chunks_mut(row_len).enumerate() {
            f(row0 + i, row);
        }
    });
}

/// Map `f` over owned items on the pool, preserving order. Used by the
/// per-shard compression path; falls back to a serial map when the pool
/// would not help (single item, one thread, already on a worker).
pub fn parallel_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n <= 1 || num_threads() <= 1 || in_pool() {
        return items.into_iter().map(f).collect();
    }
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    {
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(n);
        for (slot, item) in slots.iter_mut().zip(items) {
            let f = &f;
            tasks.push(Box::new(move || {
                *slot = Some(f(item));
            }));
        }
        run_scoped(tasks);
    }
    slots.into_iter().map(|s| s.expect("pool task completed")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(1000, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_range_is_fine() {
        parallel_for_chunks(0, |_s, _e| {});
    }

    #[test]
    fn rows_mut_processes_every_row() {
        let rows = 37;
        let row_len = 5;
        let mut data = vec![0.0f32; rows * row_len];
        parallel_rows_mut(&mut data, rows, row_len, |r, row| {
            for v in row.iter_mut() {
                *v = r as f32;
            }
        });
        for r in 0..rows {
            for c in 0..row_len {
                assert_eq!(data[r * row_len + c], r as f32);
            }
        }
    }

    #[test]
    fn row_chunks_partition_contiguously() {
        let rows = 23;
        let row_len = 3;
        let mut data = vec![0.0f32; rows * row_len];
        parallel_row_chunks_mut(&mut data, rows, row_len, |row0, chunk| {
            assert_eq!(chunk.len() % row_len, 0);
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = (row0 * row_len + i) as f32;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn aligned_chunks_start_on_tile_boundaries() {
        use std::sync::Mutex;
        for (rows, align) in [(23usize, 6usize), (48, 6), (5, 6), (17, 8), (64, 8), (1, 8)] {
            let row_len = 3;
            let mut data = vec![0.0f32; rows * row_len];
            let starts: Mutex<Vec<(usize, usize)>> = Mutex::new(Vec::new());
            parallel_row_chunks_mut_aligned(&mut data, rows, row_len, align, |row0, chunk| {
                starts.lock().unwrap().push((row0, chunk.len() / row_len));
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (row0 * row_len + i) as f32;
                }
            });
            // Every chunk starts on a tile boundary; only the last may be
            // ragged; the chunks tile 0..rows exactly once.
            let mut starts = starts.into_inner().unwrap();
            starts.sort_unstable();
            let mut expect_next = 0usize;
            for (i, &(row0, take)) in starts.iter().enumerate() {
                assert_eq!(row0, expect_next, "rows={rows} align={align}");
                assert_eq!(row0 % align, 0, "chunk start must be tile-aligned");
                if i + 1 < starts.len() {
                    assert_eq!(take % align, 0, "only the final chunk may be ragged");
                }
                expect_next = row0 + take;
            }
            assert_eq!(expect_next, rows);
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, i as f32);
            }
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(items, |i| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn nested_parallelism_runs_inline_without_deadlock() {
        let total = AtomicUsize::new(0);
        parallel_for_chunks(8, |s, e| {
            for _ in s..e {
                // Nested call: must run inline on the worker.
                parallel_for_chunks(4, |s2, e2| {
                    total.fetch_add(e2 - s2, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn detached_jobs_run_and_survive_panics() {
        use std::sync::mpsc::channel;
        let (tx, rx) = channel::<usize>();
        // A panicking job must not take a worker down...
        spawn_detached(|| panic!("intentional"));
        // ...and later jobs still run on the same pool.
        for i in 0..8 {
            let tx = tx.clone();
            spawn_detached(move || {
                let _ = tx.send(i);
            });
        }
        drop(tx);
        let mut got: Vec<usize> = rx.into_iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn pool_survives_many_batches() {
        for round in 0..50 {
            let mut data = vec![0.0f32; 64];
            parallel_rows_mut(&mut data, 16, 4, |r, row| row.fill((r + round) as f32));
            assert_eq!(data[63], (15 + round) as f32);
        }
    }
}
