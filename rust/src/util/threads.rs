//! Data-parallel helpers over `std::thread::scope` (rayon is not vendored).
//!
//! `parallel_for_chunks` splits an index range into contiguous chunks and runs
//! a worker per chunk; the degree of parallelism defaults to the number of
//! physical cores and can be pinned through `RESMOE_THREADS` (used by the
//! benches to report single- vs multi-thread numbers).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (env `RESMOE_THREADS` overrides).
pub fn num_threads() -> usize {
    static CACHE: AtomicUsize = AtomicUsize::new(0);
    let cached = CACHE.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("RESMOE_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        });
    CACHE.store(n, Ordering::Relaxed);
    n
}

/// Run `f(start, end)` over disjoint chunks of `0..n` in parallel.
/// `f` must be `Sync` (immutable captures) — output goes through interior
/// mutability or per-chunk ownership (see `parallel_map_mut`).
pub fn parallel_for_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n < 2 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            scope.spawn(move || f(start, end));
        }
    });
}

/// Parallel map over mutable disjoint row chunks: splits `data` (length
/// `rows * row_len`) into per-row-chunk mutable slices processed in parallel.
pub fn parallel_rows_mut<F>(data: &mut [f32], rows: usize, row_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert_eq!(data.len(), rows * row_len);
    let workers = num_threads().min(rows.max(1));
    if workers <= 1 || rows < 2 {
        for (r, row) in data.chunks_mut(row_len.max(1)).enumerate() {
            f(r, row);
        }
        return;
    }
    let chunk_rows = rows.div_ceil(workers);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut row0 = 0usize;
        while row0 < rows {
            let take = chunk_rows.min(rows - row0);
            let (head, tail) = rest.split_at_mut(take * row_len);
            let f = &f;
            let base = row0;
            scope.spawn(move || {
                for (i, row) in head.chunks_mut(row_len).enumerate() {
                    f(base + i, row);
                }
            });
            rest = tail;
            row0 += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(1000, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_range_is_fine() {
        parallel_for_chunks(0, |_s, _e| {});
    }

    #[test]
    fn rows_mut_processes_every_row() {
        let rows = 37;
        let row_len = 5;
        let mut data = vec![0.0f32; rows * row_len];
        parallel_rows_mut(&mut data, rows, row_len, |r, row| {
            for v in row.iter_mut() {
                *v = r as f32;
            }
        });
        for r in 0..rows {
            for c in 0..row_len {
                assert_eq!(data[r * row_len + c], r as f32);
            }
        }
    }
}
