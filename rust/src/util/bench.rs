//! In-tree benchmark runner (criterion is not in the offline vendor set).
//!
//! Every `rust/benches/*.rs` target is a `harness = false` binary that uses
//! [`time_fn`] / [`BenchRunner`] for timing and [`Table`] to print the
//! corresponding paper table in aligned markdown. Reports are also dumped to
//! `reports/*.json` for EXPERIMENTS.md bookkeeping.

use super::stats::{percentile, Summary};
use std::time::Instant;

/// Time a closure: warmup runs, then `iters` measured runs (seconds each).
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect()
}

/// Named timing result with percentile helpers.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples)
    }
    pub fn p50_ms(&self) -> f64 {
        percentile(&self.samples, 50.0) * 1e3
    }
    pub fn p99_ms(&self) -> f64 {
        percentile(&self.samples, 99.0) * 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.summary().mean * 1e3
    }
}

/// Collects timed benches and prints a summary block.
#[derive(Default)]
pub struct BenchRunner {
    pub results: Vec<BenchResult>,
}

impl BenchRunner {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, f: F) {
        let samples = time_fn(warmup, iters, f);
        let r = BenchResult { name: name.to_string(), samples };
        eprintln!(
            "  bench {:<40} mean {:>9.3} ms   p50 {:>9.3} ms   p99 {:>9.3} ms ({} iters)",
            r.name,
            r.mean_ms(),
            r.p50_ms(),
            r.p99_ms(),
            r.samples.len()
        );
        self.results.push(r);
    }
}

/// Aligned markdown table, mirroring the layout of a paper table.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n\n", self.title));
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i].saturating_sub(c.chars().count());
                line.push_str(&format!(" {}{} |", c, " ".repeat(pad)));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Persist as JSON under `reports/` (best-effort; benches still succeed
    /// when the directory cannot be created, e.g. read-only checkouts).
    pub fn save_json(&self, stem: &str) {
        use super::json::Json;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
            .collect();
        let doc = Json::obj(vec![
            ("title", Json::str(&self.title)),
            (
                "header",
                Json::Arr(self.header.iter().map(|h| Json::Str(h.clone())).collect()),
            ),
            ("rows", Json::Arr(rows)),
        ]);
        if std::fs::create_dir_all("reports").is_ok() {
            let _ = std::fs::write(format!("reports/{stem}.json"), doc.to_string());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_iters() {
        let mut n = 0usize;
        let samples = time_fn(2, 5, || n += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(n, 7);
        assert!(samples.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn table_render_is_aligned() {
        let mut t = Table::new("Demo", &["method", "score"]);
        t.row(vec!["resmoe-up".into(), "1.0".into()]);
        t.row(vec!["up".into(), "12.5".into()]);
        let r = t.render();
        assert!(r.contains("| method    | score |"));
        assert!(r.contains("| resmoe-up | 1.0   |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
