//! Deterministic fault injection for chaos-testing the serving stack.
//!
//! The store's read paths consult a seeded failpoint registry before (and
//! during) every shard fetch. A plan is configured through the
//! `RESMOE_FAULTS` environment variable:
//!
//! ```text
//! RESMOE_FAULTS="seed:7,spec:transient@store.read*2;corrupt@store.read/b1e3"
//! ```
//!
//! Grammar: `seed:<u64>,spec:<rule>[;<rule>...]` where each rule is
//!
//! ```text
//! <kind>@<site>[/b<block>[e<expert>]][*<count>][~<prob>][+<latency_us>]
//! ```
//!
//! * `kind` — `transient` (retryable read error), `corrupt` (one payload
//!   byte flipped so the store's CRC-32 check trips), `truncate` (short
//!   read), or `latency` (sleep `latency_us`, default 200, then proceed).
//! * `site` — failpoint name (`store.read` for expert residual shards,
//!   `store.meta` for backbone/skeleton loads at open) or `*` for any.
//! * `/b<block>e<expert>` — restrict to one target; omit `e` to hit every
//!   shard of a block, omit the whole clause to hit every target.
//! * `*<count>` — only the first `count` attempts **per target** can fault
//!   (the lever for "transient storm that converges": `*2` with a retry
//!   budget of 3 means every fetch succeeds on its third attempt).
//! * `~<prob>` — fault with this probability per attempt (default 1.0).
//!
//! Determinism is the whole point: a decision is a pure function of
//! `(seed, rule, site, block, slot, attempt#)` where the attempt counter
//! is tracked per target, NOT globally — so two runs of the same workload
//! inject the same faults at the same per-target attempts regardless of
//! thread interleaving, and chaos tests are exact replays.
//!
//! Zero-cost contract (same as `RESMOE_TRACE` in `obs/trace.rs`): with the
//! variable unset, [`check`] compiles to a single relaxed atomic load.

use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::atomic::{AtomicI8, Ordering::Relaxed};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// What the failpoint asks the call site to do. The store interprets these;
/// the registry only decides.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Fail the read with a retryable error before touching the file.
    Transient,
    /// Flip one payload byte so the real CRC-32 integrity check fires.
    Corrupt,
    /// Return a short-read error after the (successful) file read.
    Truncate,
    /// Sleep this many microseconds, then proceed normally.
    Latency(u64),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Transient,
    Corrupt,
    Truncate,
    Latency,
}

/// One parsed spec rule. Rules are tried in order; the first match wins.
#[derive(Debug, Clone)]
struct Rule {
    kind: Kind,
    site: String,
    block: Option<i64>,
    slot: Option<i64>,
    /// Only the first `count` attempts per target can fault (None = all).
    count: Option<u64>,
    prob: f64,
    latency_us: u64,
}

impl Rule {
    fn parse(src: &str) -> Result<Rule, String> {
        let (kind_s, rest) = src
            .split_once('@')
            .ok_or_else(|| format!("rule '{src}': want <kind>@<site>"))?;
        let kind = match kind_s {
            "transient" => Kind::Transient,
            "corrupt" => Kind::Corrupt,
            "truncate" => Kind::Truncate,
            "latency" => Kind::Latency,
            other => return Err(format!("rule '{src}': unknown fault kind '{other}'")),
        };
        let mut rule = Rule {
            kind,
            site: String::new(),
            block: None,
            slot: None,
            count: None,
            prob: 1.0,
            latency_us: 200,
        };
        // A leading '*' is the wildcard site, not the count marker.
        let site_end = if rest.starts_with('*') {
            1
        } else {
            rest.find(['/', '*', '~', '+']).unwrap_or(rest.len())
        };
        rule.site = rest[..site_end].to_string();
        if rule.site.is_empty() {
            return Err(format!("rule '{src}': empty site"));
        }
        let mut tail = &rest[site_end..];
        while !tail.is_empty() {
            let marker = tail.as_bytes()[0] as char;
            let end = tail[1..].find(['/', '*', '~', '+']).map_or(tail.len(), |i| i + 1);
            let body = &tail[1..end];
            match marker {
                '/' => {
                    let body = body.strip_prefix('b').ok_or_else(|| {
                        format!("rule '{src}': target wants /b<block>[e<expert>]")
                    })?;
                    let (b, e) = match body.split_once('e') {
                        Some((b, e)) => (b, Some(e)),
                        None => (body, None),
                    };
                    rule.block =
                        Some(b.parse().map_err(|_| format!("rule '{src}': bad block '{b}'"))?);
                    if let Some(e) = e {
                        rule.slot = Some(
                            e.parse().map_err(|_| format!("rule '{src}': bad expert '{e}'"))?,
                        );
                    }
                }
                '*' => {
                    rule.count = Some(
                        body.parse().map_err(|_| format!("rule '{src}': bad count '{body}'"))?,
                    )
                }
                '~' => {
                    rule.prob = body
                        .parse()
                        .map_err(|_| format!("rule '{src}': bad probability '{body}'"))?
                }
                '+' => {
                    rule.latency_us = body
                        .parse()
                        .map_err(|_| format!("rule '{src}': bad latency '{body}'"))?
                }
                _ => unreachable!("site scan stops only on a marker"),
            }
            tail = &tail[end..];
        }
        Ok(rule)
    }

    fn matches_target(&self, site: &str, block: i64, slot: i64) -> bool {
        (self.site == "*" || self.site == site)
            && self.block.map_or(true, |b| b == block)
            && self.slot.map_or(true, |s| s == slot)
    }

    fn fault(&self) -> Fault {
        match self.kind {
            Kind::Transient => Fault::Transient,
            Kind::Corrupt => Fault::Corrupt,
            Kind::Truncate => Fault::Truncate,
            Kind::Latency => Fault::Latency(self.latency_us),
        }
    }
}

/// A full parsed `RESMOE_FAULTS` plan: the seed plus an ordered rule list.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<Rule>,
}

impl FaultPlan {
    /// Parse the full `seed:<u64>,spec:<rules>` document.
    pub fn parse(env: &str) -> Result<FaultPlan, String> {
        let (head, spec) = env
            .split_once("spec:")
            .ok_or_else(|| "RESMOE_FAULTS needs a 'spec:' section".to_string())?;
        let mut seed = 0u64;
        for part in head.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            if let Some(v) = part.strip_prefix("seed:") {
                seed = v.trim().parse().map_err(|_| format!("bad seed '{v}'"))?;
            } else {
                return Err(format!("unknown RESMOE_FAULTS key '{part}'"));
            }
        }
        let mut rules = Vec::new();
        for r in spec.split(';') {
            let r = r.trim();
            if !r.is_empty() {
                rules.push(Rule::parse(r)?);
            }
        }
        if rules.is_empty() {
            return Err("empty fault spec".into());
        }
        Ok(FaultPlan { seed, rules })
    }
}

// -1 = follow the environment, 0 = forced off, 1 = forced on with the test
// plan. The ONLY cost on the disabled hot path is this relaxed load.
static OVERRIDE: AtomicI8 = AtomicI8::new(-1);

fn env_plan() -> &'static Option<FaultPlan> {
    static PLAN: OnceLock<Option<FaultPlan>> = OnceLock::new();
    PLAN.get_or_init(|| match std::env::var("RESMOE_FAULTS") {
        Err(_) => None,
        Ok(v) if matches!(v.as_str(), "" | "0" | "off" | "false") => None,
        Ok(v) => Some(
            FaultPlan::parse(&v).unwrap_or_else(|e| panic!("RESMOE_FAULTS: {e}")),
        ),
    })
}

fn test_plan() -> &'static Mutex<Option<FaultPlan>> {
    static PLAN: OnceLock<Mutex<Option<FaultPlan>>> = OnceLock::new();
    PLAN.get_or_init(|| Mutex::new(None))
}

/// Per-target attempt counters: the identity that makes decisions replay
/// exactly under any thread interleaving. Only touched when faults are on.
type TargetKey = (&'static str, i64, i64);

fn counts() -> &'static Mutex<HashMap<TargetKey, u64>> {
    static COUNTS: OnceLock<Mutex<HashMap<TargetKey, u64>>> = OnceLock::new();
    COUNTS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Is any fault plan active? One relaxed atomic load in production.
#[inline]
pub fn enabled() -> bool {
    match OVERRIDE.load(Relaxed) {
        0 => false,
        1 => true,
        _ => env_plan().is_some(),
    }
}

/// Consult the failpoint registry. `block`/`slot` identify the target
/// (-1 where the dimension does not apply, e.g. the backbone shard).
#[inline]
pub fn check(site: &'static str, block: i64, slot: i64) -> Option<Fault> {
    if !enabled() {
        return None;
    }
    check_slow(site, block, slot)
}

#[cold]
fn check_slow(site: &'static str, block: i64, slot: i64) -> Option<Fault> {
    let plan = if OVERRIDE.load(Relaxed) == 1 {
        test_plan().lock().unwrap_or_else(|e| e.into_inner()).clone()?
    } else {
        env_plan().clone()?
    };
    let attempt = {
        let mut c = counts().lock().unwrap_or_else(|e| e.into_inner());
        let n = c.entry((site, block, slot)).or_insert(0);
        let a = *n;
        *n += 1;
        a
    };
    for (i, rule) in plan.rules.iter().enumerate() {
        if !rule.matches_target(site, block, slot) {
            continue;
        }
        if let Some(limit) = rule.count {
            if attempt >= limit {
                continue;
            }
        }
        if rule.prob < 1.0 && draw(plan.seed, i as u64, site, block, slot, attempt) >= rule.prob {
            continue;
        }
        return Some(rule.fault());
    }
    None
}

/// Pure hash → uniform draw for probabilistic rules. No global RNG state:
/// the decision depends only on the target identity and attempt number.
fn draw(seed: u64, rule: u64, site: &str, block: i64, slot: i64, attempt: u64) -> f64 {
    let mut h = seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(rule + 1);
    for &b in site.as_bytes() {
        h = h.wrapping_mul(0x0000_0100_0000_01B3) ^ b as u64;
    }
    h ^= (block as u64).wrapping_mul(0xA076_1D64_78BD_642F);
    h ^= (slot as u64).rotate_left(17).wrapping_mul(0xE703_7ED1_A0B4_28DB);
    h ^= attempt.wrapping_mul(0x8EBC_6AF0_9C88_C6E3);
    let mut rng = Rng::new(h);
    rng.uniform()
}

/// Install a plan (`Some`) or return control to the environment (`None`).
/// Both directions reset the per-target attempt counters so every test run
/// is an exact replay. Hold [`test_serial`] across the whole test.
pub fn force_for_tests(plan: Option<FaultPlan>) {
    let on = plan.is_some();
    *test_plan().lock().unwrap_or_else(|e| e.into_inner()) = plan;
    reset_for_tests();
    OVERRIDE.store(if on { 1 } else { -1 }, Relaxed);
}

/// Force the disabled path regardless of the environment (the parity pin).
pub fn force_disabled_for_tests() {
    OVERRIDE.store(0, Relaxed);
}

/// Clear the per-target attempt counters (fresh replay of the same plan).
pub fn reset_for_tests() {
    counts().lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// Tests that flip the global override must not interleave.
pub fn test_serial() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let p = FaultPlan::parse(
            "seed:42,spec:transient@store.read*2;corrupt@store.read/b1e3;\
             latency@*~0.5+300;truncate@store.read/b2",
        )
        .unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(p.rules.len(), 4);
        assert_eq!(p.rules[0].kind, Kind::Transient);
        assert_eq!(p.rules[0].count, Some(2));
        assert_eq!(p.rules[1].block, Some(1));
        assert_eq!(p.rules[1].slot, Some(3));
        assert_eq!(p.rules[2].site, "*");
        assert!((p.rules[2].prob - 0.5).abs() < 1e-12);
        assert_eq!(p.rules[2].latency_us, 300);
        assert_eq!(p.rules[3].block, Some(2));
        assert_eq!(p.rules[3].slot, None);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "no spec section",
            "spec:",
            "spec:transient",               // no site
            "spec:explode@store.read",      // unknown kind
            "spec:transient@store.read*x",  // bad count
            "seed:zz,spec:transient@*",     // bad seed
            "spec:transient@store.read/e3", // target without block
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn count_limited_rule_faults_only_first_attempts_per_target() {
        let _guard = test_serial();
        let plan = FaultPlan::parse("seed:1,spec:transient@store.read*2").unwrap();
        force_for_tests(Some(plan));
        // First two attempts per target fault, later ones pass; a second
        // target gets its own budget.
        assert_eq!(check("store.read", 1, 0), Some(Fault::Transient));
        assert_eq!(check("store.read", 1, 0), Some(Fault::Transient));
        assert_eq!(check("store.read", 1, 0), None);
        assert_eq!(check("store.read", 1, 1), Some(Fault::Transient));
        // Unrelated site never matches.
        assert_eq!(check("store.meta", 1, 0), None);
        force_for_tests(None);
    }

    #[test]
    fn probabilistic_rules_replay_exactly() {
        let _guard = test_serial();
        let plan = FaultPlan::parse("seed:9,spec:transient@store.read~0.4").unwrap();
        let run = |plan: &FaultPlan| -> Vec<Option<Fault>> {
            force_for_tests(Some(plan.clone()));
            (0..64).map(|i| check("store.read", i % 4, i % 8)).collect()
        };
        let a = run(&plan);
        let b = run(&plan);
        assert_eq!(a, b, "same plan must replay bit-identically");
        let hits = a.iter().filter(|f| f.is_some()).count();
        assert!(hits > 8 && hits < 56, "~0.4 rule fired {hits}/64 times");
        // A different seed draws a different sample path.
        let other = FaultPlan::parse("seed:10,spec:transient@store.read~0.4").unwrap();
        assert_ne!(run(&other), a, "seed must steer the sample path");
        force_for_tests(None);
    }

    #[test]
    fn disabled_registry_is_inert() {
        let _guard = test_serial();
        force_disabled_for_tests();
        for i in 0..8 {
            assert_eq!(check("store.read", i, i), None);
        }
        force_for_tests(None);
    }
}
