//! Infrastructure substrate: deterministic RNG, stats, JSON, CLI parsing,
//! bench runner, property-test harness, and thread-pool helpers.
//!
//! These exist in-tree because the sandbox's vendored crate set carries no
//! rand / serde / clap / criterion / proptest / rayon; each module documents
//! which external crate it replaces.

pub mod bench;
pub mod bytes;
pub mod cli;
pub mod crc32;
pub mod env;
pub mod fault;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threads;

pub use rng::Rng;

/// Human-readable byte size (Table 10 formatting).
pub fn format_bytes(bytes: usize) -> String {
    const KB: f64 = 1024.0;
    let b = bytes as f64;
    if b >= KB * KB * KB {
        format!("{:.2} GB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.1} MB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.1} KB", b / KB)
    } else {
        format!("{bytes} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(2048), "2.0 KB");
        assert_eq!(format_bytes(5 * 1024 * 1024), "5.0 MB");
        assert_eq!(format_bytes(3 * 1024 * 1024 * 1024), "3.00 GB");
    }
}
