//! Minimal JSON parser/serializer (serde is not in the offline vendor set).
//!
//! Used for: run configs, the artifact manifest written by `python/compile/aot.py`,
//! and machine-readable bench reports. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for our ASCII configs).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for artifact-stamp stability.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ accessors
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` convenience: None if not an object or key missing.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // --------------------------------------------------------- constructors
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"k":[1,2.5,true,null,"s\n"]},"z":-3}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn as_usize_rejects_negative_and_fractional() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(2.5).as_usize(), None);
    }

    #[test]
    fn deterministic_serialization() {
        let a = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(a.to_string(), r#"{"a":2,"b":1}"#);
    }
}
