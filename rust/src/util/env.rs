//! Checked parsing for `RESMOE_*` environment knobs.
//!
//! Every env-tunable integer knob in the serving stack goes through this
//! one parser so the edge-case semantics are uniform and documented:
//!
//! - **unset / empty / whitespace** → the caller's default;
//! - **non-numeric garbage** (`"fast"`, `"-3"`, `"1e9"`) → the caller's
//!   default — never a silent zero;
//! - **numeric but wider than `u64`** → saturate to `u64::MAX` (an operator
//!   writing a huge number means "effectively unbounded", not "default");
//! - **`u64` → `usize` narrowing** saturates, so 32-bit targets clamp
//!   instead of truncating high bits (`parse() as usize` used to wrap).
//!
//! Zero is always passed through untouched: each knob documents its own
//! zero semantics at the consumer (`RESMOE_BATCH=0` clamps to 1,
//! `RESMOE_MAX_QUEUE=0` means *unbounded*, `RESMOE_DEADLINE_MS=0` means
//! *no deadline*, `RESMOE_LINGER_US=0` means *flush immediately*).

/// Parse a decimal digit string as `u64`, saturating on overflow.
///
/// Returns `None` for anything that is not a plain non-empty run of ASCII
/// digits (after trimming whitespace) — callers substitute their default.
pub fn parse_u64_saturating(s: &str) -> Option<u64> {
    let t = s.trim();
    if t.is_empty() || !t.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    let mut v: u64 = 0;
    for b in t.bytes() {
        v = v.saturating_mul(10).saturating_add(u64::from(b - b'0'));
    }
    Some(v)
}

/// Read knob `name` through `lookup` with the module-level semantics.
pub fn knob_u64(
    lookup: impl Fn(&str) -> Option<String>,
    name: &str,
    default: u64,
) -> u64 {
    lookup(name)
        .as_deref()
        .and_then(parse_u64_saturating)
        .unwrap_or(default)
}

/// [`knob_u64`] narrowed to `usize` with saturation (32-bit safe).
pub fn knob_usize(
    lookup: impl Fn(&str) -> Option<String>,
    name: &str,
    default: usize,
) -> usize {
    u64_to_usize(knob_u64(lookup, name, default as u64))
}

/// Saturating `u64` → `usize` (identity on 64-bit targets).
pub fn u64_to_usize(v: u64) -> usize {
    usize::try_from(v).unwrap_or(usize::MAX)
}

/// [`knob_u64`] against the process environment.
pub fn env_u64(name: &str, default: u64) -> u64 {
    knob_u64(|n| std::env::var(n).ok(), name, default)
}

/// [`knob_usize`] against the process environment.
pub fn env_usize(name: &str, default: usize) -> usize {
    knob_usize(|n| std::env::var(n).ok(), name, default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_parse_exactly() {
        assert_eq!(parse_u64_saturating("0"), Some(0));
        assert_eq!(parse_u64_saturating("8"), Some(8));
        assert_eq!(parse_u64_saturating(" 500 "), Some(500));
        assert_eq!(
            parse_u64_saturating("18446744073709551615"),
            Some(u64::MAX)
        );
    }

    #[test]
    fn overflow_saturates_instead_of_defaulting() {
        // One past u64::MAX and something absurd both clamp to MAX — the
        // operator asked for "huge", not for the default.
        assert_eq!(parse_u64_saturating("18446744073709551616"), Some(u64::MAX));
        assert_eq!(
            parse_u64_saturating("99999999999999999999999999"),
            Some(u64::MAX)
        );
    }

    #[test]
    fn garbage_is_none_never_zero() {
        for bad in ["", "  ", "fast", "-3", "+4", "1e9", "0x10", "12.5", "7_000"] {
            assert_eq!(parse_u64_saturating(bad), None, "input {bad:?}");
        }
    }

    #[test]
    fn knob_lookup_semantics() {
        let env = |pairs: &'static [(&str, &str)]| {
            move |name: &str| {
                pairs
                    .iter()
                    .find(|(k, _)| *k == name)
                    .map(|(_, v)| v.to_string())
            }
        };
        // unset → default; garbage → default; digits → value.
        assert_eq!(knob_u64(env(&[]), "K", 42), 42);
        assert_eq!(knob_u64(env(&[("K", "junk")]), "K", 42), 42);
        assert_eq!(knob_u64(env(&[("K", "7")]), "K", 42), 7);
        // zero passes through — zero semantics belong to the consumer.
        assert_eq!(knob_u64(env(&[("K", "0")]), "K", 42), 0);
        // overflow saturates, and the usize narrowing saturates too.
        assert_eq!(
            knob_usize(env(&[("K", "99999999999999999999999")]), "K", 1),
            usize::MAX
        );
    }
}
