//! Table-driven CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) for
//! artifact-shard checksums in the [`crate::store`] format. The store must
//! detect bit rot / truncation in any individual shard without reading the
//! rest of the file, so every shard carries the CRC of its on-disk bytes.
//!
//! In-tree because the offline vendor set carries no `crc32fast`; the
//! 256-entry table is built in a `const fn` so there is no runtime init.

/// The standard reflected polynomial (zlib / PNG / gzip "CRC-32").
const POLY: u32 = 0xEDB8_8320;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Streaming CRC-32 state. `Crc32::new().update(a).update(b).finish()`
/// equals [`crc32`] over the concatenation of `a` and `b`.
#[derive(Debug, Clone, Copy)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(mut self, bytes: &[u8]) -> Crc32 {
        for &b in bytes {
            let idx = ((self.state ^ b as u32) & 0xFF) as usize;
            self.state = (self.state >> 8) ^ TABLE[idx];
        }
        self
    }

    pub fn finish(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    Crc32::new().update(bytes).finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answers() {
        // Reference vectors from the zlib/PNG CRC-32 definition.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"abc"), 0x3524_41C2);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_equals_oneshot() {
        let data = b"shard-checksum-streaming-equivalence";
        let (a, b) = data.split_at(11);
        assert_eq!(Crc32::new().update(a).update(b).finish(), crc32(data));
        // Byte-at-a-time too.
        let mut st = Crc32::new();
        for byte in data.iter() {
            st = st.update(std::slice::from_ref(byte));
        }
        assert_eq!(st.finish(), crc32(data));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![0x5Au8; 1024];
        let clean = crc32(&data);
        for byte in [0usize, 511, 1023] {
            for bit in 0..8 {
                data[byte] ^= 1 << bit;
                assert_ne!(crc32(&data), clean, "flip at {byte}:{bit} undetected");
                data[byte] ^= 1 << bit;
            }
        }
        assert_eq!(crc32(&data), clean);
    }
}
