//! Small statistics helpers shared by the eval harness and the bench runner.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0.0 for fewer than 2 samples).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (linear interpolation), p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Summary of repeated measurements, formatted like the paper's `m±s` cells.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            mean: mean(xs),
            std: stddev(xs),
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            n: xs.len(),
        }
    }

    /// `12.34±0.56` with the given number of decimals — the paper's cell format.
    pub fn cell(&self, decimals: usize) -> String {
        format!("{:.d$}\u{b1}{:.d$}", self.mean, self.std, d = decimals)
    }
}

/// Softmax over a slice (numerically stable; used by routers and LM eval).
/// Dispatches through the kernel layer: max-subtract, exp, scale by the
/// reciprocal of the sum (vectorized `exp` under AVX2, `RESMOE_SIMD=0`
/// pins the scalar twin).
pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let mut out = xs.to_vec();
    crate::tensor::kernel::softmax_inplace(&mut out);
    out
}

/// log(sum(exp(xs))) — numerically stable.
pub fn logsumexp(xs: &[f32]) -> f32 {
    let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if max.is_infinite() {
        return max;
    }
    max + xs.iter().map(|x| (x - max).exp()).sum::<f32>().ln()
}

/// Indices of the k largest entries, descending by value (ties: lower index
/// first, matching `TopK` in the paper's router definition).
pub fn top_k_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((median(&xs) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn summary_cell_format() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.cell(2), "2.00\u{b1}1.00");
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        let sum: f32 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let p = softmax(&[1000.0, 1000.0]);
        assert!((p[0] - 0.5).abs() < 1e-6);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn logsumexp_matches_naive_on_small() {
        let xs = [0.1f32, -0.3, 0.7];
        let naive = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((logsumexp(&xs) - naive).abs() < 1e-6);
    }

    #[test]
    fn top_k_ordering_and_ties() {
        assert_eq!(top_k_indices(&[0.1, 0.9, 0.5, 0.9], 2), vec![1, 3]);
        assert_eq!(top_k_indices(&[3.0, 1.0, 2.0], 3), vec![0, 2, 1]);
    }
}
