//! Tiny argv parser (clap is not in the offline vendor set).
//!
//! Grammar: `resmoe <subcommand> [--flag] [--key value] [positional...]`.
//! Both `--key value` and `--key=value` are accepted.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        out.flags.push(stripped.to_string());
                    } else {
                        out.options.insert(stripped.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env(flag_names: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["compress", "model.bin", "out.bin"], &[]);
        assert_eq!(a.subcommand.as_deref(), Some("compress"));
        assert_eq!(a.positional, vec!["model.bin", "out.bin"]);
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["eval", "--rate", "0.25", "--method=resmoe-up"], &[]);
        assert_eq!(a.get("rate"), Some("0.25"));
        assert_eq!(a.get("method"), Some("resmoe-up"));
        assert_eq!(a.get_f64("rate", 0.0), 0.25);
    }

    #[test]
    fn declared_flags_consume_no_value() {
        let a = parse(&["serve", "--verbose", "model.bin"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["model.bin"]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["x", "--fast"], &[]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse(&["x", "--fast", "--k", "3"], &[]);
        assert!(a.flag("fast"));
        assert_eq!(a.get_usize("k", 0), 3);
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"], &[]);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
    }
}
