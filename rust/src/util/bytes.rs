//! Little-endian byte (de)serialization helpers shared by the shard codecs
//! in `compress/formats.rs` and the `store` artifact format. Reads are
//! bounds-checked and return errors instead of panicking so a truncated or
//! corrupt shard surfaces as a clean decode failure.

use anyhow::{bail, Result};

/// Append-only little-endian writer over a `Vec<u8>`.
pub trait PutLe {
    fn put_u8(&mut self, v: u8);
    fn put_u32(&mut self, v: u32);
    fn put_u64(&mut self, v: u64);
    fn put_f32(&mut self, v: f32);
    fn put_f32s(&mut self, vs: &[f32]);
    fn put_u32s(&mut self, vs: &[u32]);
    fn put_i8s(&mut self, vs: &[i8]);
}

impl PutLe for Vec<u8> {
    fn put_u8(&mut self, v: u8) {
        self.push(v);
    }
    fn put_u32(&mut self, v: u32) {
        self.extend_from_slice(&v.to_le_bytes());
    }
    fn put_u64(&mut self, v: u64) {
        self.extend_from_slice(&v.to_le_bytes());
    }
    fn put_f32(&mut self, v: f32) {
        self.extend_from_slice(&v.to_le_bytes());
    }
    fn put_f32s(&mut self, vs: &[f32]) {
        self.reserve(vs.len() * 4);
        for v in vs {
            self.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn put_u32s(&mut self, vs: &[u32]) {
        self.reserve(vs.len() * 4);
        for v in vs {
            self.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn put_i8s(&mut self, vs: &[i8]) {
        // i8 → u8 is a bit-preserving two's-complement cast.
        self.extend(vs.iter().map(|&v| v as u8));
    }
}

/// Bounds-checked little-endian cursor over a byte slice.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "byte reader underrun: need {n} bytes at offset {}, {} left",
                self.pos,
                self.remaining()
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    /// u32 read as usize (all shard dimensions fit u32 by construction).
    pub fn len(&mut self) -> Result<usize> {
        Ok(self.u32()? as usize)
    }

    pub fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("length overflow"))?)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn i8s(&mut self, n: usize) -> Result<Vec<i8>> {
        let b = self.take(n)?;
        Ok(b.iter().map(|&v| v as i8).collect())
    }

    pub fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let b = self.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("length overflow"))?)?;
        Ok(b.chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Fail unless the reader consumed every byte — shard payloads are
    /// self-delimiting, so trailing garbage means corruption.
    pub fn expect_done(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("{} trailing bytes after decoded payload", self.remaining());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars_and_slices() {
        let mut buf = Vec::new();
        buf.put_u8(7);
        buf.put_u32(0xDEAD_BEEF);
        buf.put_u64(1 << 40);
        buf.put_f32(-1.5);
        buf.put_f32s(&[0.0, 3.25]);
        buf.put_u32s(&[1, 2, 3]);
        buf.put_i8s(&[-128, -1, 0, 127]);
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32s(3).unwrap(), vec![-1.5, 0.0, 3.25]);
        assert_eq!(r.u32s(3).unwrap(), vec![1, 2, 3]);
        assert_eq!(r.i8s(4).unwrap(), vec![-128, -1, 0, 127]);
        r.expect_done().unwrap();
    }

    #[test]
    fn underrun_and_trailing_are_errors() {
        let mut buf = Vec::new();
        buf.put_u32(5);
        let mut r = ByteReader::new(&buf);
        assert!(r.u64().is_err());
        assert_eq!(r.u32().unwrap(), 5);
        let buf2 = [1u8, 2, 3];
        let mut r2 = ByteReader::new(&buf2);
        assert_eq!(r2.u8().unwrap(), 1);
        assert!(r2.expect_done().is_err());
    }
}
