//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set carries no `rand` crate, so we ship a small,
//! well-tested xoshiro256** generator seeded through SplitMix64 (the
//! construction recommended by the xoshiro authors). Everything in the
//! repository that needs randomness — weight init, synthetic data, pruning
//! tie-breaks, the mini property-test harness — goes through this type so
//! every experiment is reproducible from a single `u64` seed.

/// xoshiro256** PRNG. Deterministic, seedable, `Clone` for forked streams.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed via SplitMix64 state expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Fork an independent stream (used to give each thread / each expert its
    /// own generator without coupling their sequences).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; weight init is not a hot path).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 > 1e-300 {
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// N(0, std^2) sample.
    #[inline]
    pub fn normal_scaled(&mut self, std: f32) -> f32 {
        self.normal() * std
    }

    /// Vector of i.i.d. N(0, std^2) samples.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_scaled(std)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut u = self.uniform() as f32 * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Rng::new(9);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(13);
        let picks = r.choose_k(50, 20);
        assert_eq!(picks.len(), 20);
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(17);
        let w = [0.0f32, 0.0, 1.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.categorical(&w), 2);
        }
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut base = Rng::new(21);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }
}
