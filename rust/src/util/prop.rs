//! Mini property-testing harness (proptest is not in the offline vendor set).
//!
//! Provides seeded generators over the project's [`Rng`] plus a `check`
//! driver that reports the failing case's seed/index so a failure is
//! reproducible by construction. No shrinking — cases are kept small instead.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop` against `cases` inputs drawn by `gen`. Panics with the case
/// index + seed on the first counterexample.
pub fn check<T: std::fmt::Debug, G, P>(cfg: PropConfig, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{} (seed {:#x}): {msg}\ninput: {input:?}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    pub fn f32_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal_scaled(scale)).collect()
    }

    /// A vector guaranteed to contain at least one finite non-zero value.
    pub fn nonzero_f32_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        let mut v = f32_vec(rng, n, scale);
        if v.iter().all(|x| *x == 0.0) {
            v[0] = scale.max(1e-3);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(
            PropConfig::default(),
            |rng| gen::usize_in(rng, 1, 100),
            |&n| {
                if n >= 1 && n <= 100 {
                    Ok(())
                } else {
                    Err(format!("{n} out of range"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_counterexample() {
        check(
            PropConfig { cases: 16, seed: 1 },
            |rng| gen::usize_in(rng, 0, 10),
            |&n| if n < 100 { Err(format!("forced failure for {n}")) } else { Ok(()) },
        );
    }

    #[test]
    fn failures_are_reproducible() {
        // The same seed must produce the same sequence of inputs.
        let collect = |seed: u64| {
            let mut out = Vec::new();
            check(
                PropConfig { cases: 8, seed },
                |rng| gen::usize_in(rng, 0, 1000),
                |&n| {
                    out.push(n);
                    Ok(())
                },
            );
            out
        };
        assert_eq!(collect(42), collect(42));
        assert_ne!(collect(42), collect(43));
    }
}
