//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json` + `*.hlo.txt`) and the rust runtime.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub output_shape: Vec<usize>,
    /// Extra metadata (model name, batch, geometry...) as raw JSON.
    pub meta: Json,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec> {
    Ok(TensorSpec {
        name: j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("input missing name"))?
            .to_string(),
        shape: j
            .get("shape")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("input missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<_>>()?,
        dtype: j
            .get("dtype")
            .and_then(|v| v.as_str())
            .unwrap_or("float32")
            .to_string(),
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        let artifacts = arts
            .iter()
            .map(|a| {
                Ok(ArtifactSpec {
                    name: a
                        .get("name")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("artifact missing name"))?
                        .to_string(),
                    kind: a.get("kind").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                    file: dir.join(
                        a.get("file")
                            .and_then(|v| v.as_str())
                            .ok_or_else(|| anyhow!("artifact missing file"))?,
                    ),
                    inputs: a
                        .get("inputs")
                        .and_then(|v| v.as_arr())
                        .ok_or_else(|| anyhow!("artifact missing inputs"))?
                        .iter()
                        .map(tensor_spec)
                        .collect::<Result<_>>()?,
                    output_shape: a
                        .get("output")
                        .and_then(|o| o.get("shape"))
                        .and_then(|v| v.as_arr())
                        .map(|dims| {
                            dims.iter()
                                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                                .collect::<Result<Vec<_>>>()
                        })
                        .transpose()?
                        .unwrap_or_default(),
                    meta: a.clone(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { artifacts, dir: dir.to_path_buf() })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// lm_score artifacts for a model, sorted by batch size ascending.
    pub fn lm_score_batches(&self, model: &str) -> Vec<(usize, &ArtifactSpec)> {
        let mut out: Vec<(usize, &ArtifactSpec)> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "lm_score")
            .filter(|a| a.meta.get("model").and_then(|m| m.as_str()) == Some(model))
            .filter_map(|a| a.meta.get("batch").and_then(|b| b.as_usize()).map(|b| (b, a)))
            .collect();
        out.sort_by_key(|(b, _)| *b);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("resmoe-manifest-test");
        write_manifest(
            &dir,
            r#"{"version":1,"artifacts":[
                {"name":"lm_score_m_b4","kind":"lm_score","model":"m","batch":4,
                 "file":"a.hlo.txt",
                 "inputs":[{"name":"tokens","shape":[4,16],"dtype":"int32"}],
                 "output":{"shape":[4,16,32],"dtype":"float32"}},
                {"name":"lm_score_m_b1","kind":"lm_score","model":"m","batch":1,
                 "file":"b.hlo.txt","inputs":[],"output":{"shape":[1]}}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find("lm_score_m_b4").unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 16]);
        assert_eq!(a.inputs[0].dtype, "int32");
        assert_eq!(a.output_shape, vec![4, 16, 32]);
        let batches: Vec<usize> = m.lm_score_batches("m").iter().map(|(b, _)| *b).collect();
        assert_eq!(batches, vec![1, 4]);
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(Manifest::load(Path::new("/nonexistent-dir-xyz")).is_err());
    }

    #[test]
    fn malformed_manifest_errors() {
        let dir = std::env::temp_dir().join("resmoe-manifest-bad");
        write_manifest(&dir, r#"{"artifacts": [{"name": 7}]}"#);
        assert!(Manifest::load(&dir).is_err());
    }
}
