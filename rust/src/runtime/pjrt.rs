//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client via the `xla` crate (the pattern from /opt/xla-example/load_hlo).
//!
//! Python is never involved here — artifacts were lowered once at build
//! time, and this module is the only place the request path touches XLA.

use super::artifacts::ArtifactSpec;
use anyhow::{anyhow, ensure, Context, Result};
use std::path::Path;

/// A typed input buffer for an artifact call.
pub enum ArtifactInput<'a> {
    F32(&'a [f32], Vec<i64>),
    I32(&'a [i32], Vec<i64>),
}

impl ArtifactInput<'_> {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            ArtifactInput::F32(data, shape) => {
                xla::Literal::vec1(data).reshape(shape).context("reshape f32 input")?
            }
            ArtifactInput::I32(data, shape) => {
                xla::Literal::vec1(data).reshape(shape).context("reshape i32 input")?
            }
        })
    }
}

/// The PJRT CPU runtime.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// One compiled artifact, ready to execute.
pub struct LoadedArtifact {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load(&self, spec: &ArtifactSpec) -> Result<LoadedArtifact> {
        self.load_file(&spec.file, spec.clone())
    }

    pub fn load_file(&self, path: &Path, spec: ArtifactSpec) -> Result<LoadedArtifact> {
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {}", path.display()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parse HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(LoadedArtifact { exe, spec })
    }
}

impl LoadedArtifact {
    /// Execute with the given inputs (manifest order) and return the f32
    /// output buffer (artifacts are lowered with `return_tuple=True` and a
    /// single element — unwrapped here).
    pub fn execute_f32(&self, inputs: &[ArtifactInput]) -> Result<Vec<f32>> {
        ensure!(
            inputs.len() == self.spec.inputs.len(),
            "artifact {} expects {} inputs, got {}",
            self.spec.name,
            self.spec.inputs.len(),
            inputs.len()
        );
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|i| i.to_literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.spec.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let out = literal.to_tuple1().map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec<f32>: {e:?}"))
    }
}

/// Build the input list for an artifact from named f32 buffers plus the
/// leading token buffer (used by the LM scorer).
pub fn shape_i64(shape: &[usize]) -> Vec<i64> {
    shape.iter().map(|&d| d as i64).collect()
}
