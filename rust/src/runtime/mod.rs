//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` (L2 JAX model + L1 Pallas kernels) and executes
//! them from the rust request path. Python never runs at serving time.

pub mod artifacts;
#[cfg(feature = "xla")]
pub mod pjrt;
/// Offline builds carry no `xla` crate: an API-identical stub keeps the
/// scorer and the integration tests compiling; they skip at runtime on the
/// missing artifacts manifest before touching PJRT.
#[cfg(not(feature = "xla"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
pub mod scorer;

pub use artifacts::{ArtifactSpec, Manifest, TensorSpec};
pub use pjrt::{ArtifactInput, LoadedArtifact, PjrtRuntime};
pub use scorer::LmScorer;
