//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` (L2 JAX model + L1 Pallas kernels) and executes
//! them from the rust request path. Python never runs at serving time.

pub mod artifacts;
pub mod pjrt;
pub mod scorer;

pub use artifacts::{ArtifactSpec, Manifest, TensorSpec};
pub use pjrt::{ArtifactInput, LoadedArtifact, PjrtRuntime};
pub use scorer::LmScorer;
