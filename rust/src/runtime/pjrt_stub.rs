//! API-identical stand-in for [`pjrt`](super::pjrt) when the `xla` crate is
//! not vendored (the default offline build). Every constructor reports the
//! runtime as unavailable; the integration tests and `examples/end_to_end`
//! skip on the missing artifacts manifest before ever reaching these, so
//! the rest of the suite stays green without XLA.

use super::artifacts::ArtifactSpec;
use anyhow::{bail, Result};
use std::path::Path;

/// A typed input buffer for an artifact call.
pub enum ArtifactInput<'a> {
    F32(&'a [f32], Vec<i64>),
    I32(&'a [i32], Vec<i64>),
}

/// The PJRT CPU runtime (stub: construction always fails).
pub struct PjrtRuntime {
    _private: (),
}

/// One compiled artifact, ready to execute (stub: never constructed).
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
}

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: resmoe was built without the `xla` feature";

impl PjrtRuntime {
    pub fn cpu() -> Result<PjrtRuntime> {
        bail!("{UNAVAILABLE}")
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load(&self, _spec: &ArtifactSpec) -> Result<LoadedArtifact> {
        bail!("{UNAVAILABLE}")
    }

    pub fn load_file(&self, _path: &Path, _spec: ArtifactSpec) -> Result<LoadedArtifact> {
        bail!("{UNAVAILABLE}")
    }
}

impl LoadedArtifact {
    pub fn execute_f32(&self, _inputs: &[ArtifactInput]) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}")
    }
}

/// Build the i64 shape vector for an artifact input (shared helper, same as
/// the real module's).
pub fn shape_i64(shape: &[usize]) -> Vec<i64> {
    shape.iter().map(|&d| d as i64).collect()
}
