//! Batched LM scoring through the PJRT artifacts: the serving path that
//! runs the JAX/Pallas-lowered model end-to-end from rust (tokens in,
//! logits out), with weights fed once from the RMW1 checkpoint.

use super::artifacts::Manifest;
use super::pjrt::{shape_i64, ArtifactInput, LoadedArtifact, PjrtRuntime};
use crate::moe::model_io;
use anyhow::{anyhow, ensure, Context, Result};
use std::path::Path;

/// A compiled lm_score artifact for one batch size, plus the weight buffers
/// in manifest order.
pub struct LmScorer {
    /// (batch size, artifact), ascending.
    artifacts: Vec<(usize, LoadedArtifact)>,
    /// Weight buffers in manifest input order (after `tokens`): flattened
    /// f32 data + shape.
    weights: Vec<(Vec<f32>, Vec<i64>)>,
    pub seq: usize,
    pub vocab: usize,
}

impl LmScorer {
    /// Load every lm_score artifact for `model_name` and bind the weights
    /// from the RMW1 checkpoint.
    pub fn load(
        runtime: &PjrtRuntime,
        manifest: &Manifest,
        model_name: &str,
        checkpoint_path: &Path,
    ) -> Result<LmScorer> {
        let specs = manifest.lm_score_batches(model_name);
        ensure!(!specs.is_empty(), "no lm_score artifacts for {model_name}");
        let ckpt = model_io::load_checkpoint(checkpoint_path)
            .with_context(|| format!("checkpoint {}", checkpoint_path.display()))?;
        // Weight order comes from the first artifact's manifest inputs
        // (identical across batch sizes).
        let first = specs[0].1;
        let seq = first.inputs[0].shape[1];
        let vocab = *first.output_shape.last().ok_or_else(|| anyhow!("no output shape"))?;
        let mut weights = Vec::new();
        for input in &first.inputs[1..] {
            let tensor = ckpt
                .tensors
                .get(&input.name)
                .ok_or_else(|| anyhow!("checkpoint missing tensor {}", input.name))?;
            let expect: usize = input.shape.iter().product();
            ensure!(
                tensor.n_params() == expect,
                "tensor {} has {} elements, manifest expects {:?}",
                input.name,
                tensor.n_params(),
                input.shape
            );
            weights.push((tensor.data.to_vec(), shape_i64(&input.shape)));
        }
        let mut artifacts = Vec::new();
        for (b, spec) in specs {
            artifacts.push((b, runtime.load(spec)?));
        }
        Ok(LmScorer { artifacts, weights, seq, vocab })
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        self.artifacts.iter().map(|(b, _)| *b).collect()
    }

    /// Smallest compiled batch size that fits `n` sequences (or the largest
    /// available, for chunking).
    fn pick_batch(&self, n: usize) -> usize {
        for (b, _) in &self.artifacts {
            if *b >= n {
                return *b;
            }
        }
        self.artifacts.last().unwrap().0
    }

    /// Score sequences: returns per-sequence logits [T × V] flattened.
    /// Sequences are right-padded to `seq` with token 0 and processed in
    /// padded batches; callers slice by true length.
    pub fn score(&self, sequences: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(sequences.len());
        let mut i = 0usize;
        while i < sequences.len() {
            let remaining = sequences.len() - i;
            let b = self.pick_batch(remaining);
            let chunk = &sequences[i..(i + b.min(remaining))];
            let artifact = self
                .artifacts
                .iter()
                .find(|(ab, _)| *ab == b)
                .map(|(_, a)| a)
                .unwrap();
            let mut tokens = vec![0i32; b * self.seq];
            for (j, seq) in chunk.iter().enumerate() {
                ensure!(seq.len() <= self.seq, "sequence longer than artifact seq");
                for (t, &tok) in seq.iter().enumerate() {
                    tokens[j * self.seq + t] = tok as i32;
                }
            }
            let mut inputs: Vec<ArtifactInput> =
                vec![ArtifactInput::I32(&tokens, vec![b as i64, self.seq as i64])];
            for (data, shape) in &self.weights {
                inputs.push(ArtifactInput::F32(data, shape.clone()));
            }
            let logits = artifact.execute_f32(&inputs)?;
            let per_seq = self.seq * self.vocab;
            for j in 0..chunk.len() {
                out.push(logits[j * per_seq..(j + 1) * per_seq].to_vec());
            }
            i += chunk.len();
        }
        Ok(out)
    }

    /// Mean next-token log-prob of one sequence via the PJRT path.
    pub fn mean_log_prob(&self, tokens: &[u32]) -> Result<f64> {
        ensure!(tokens.len() >= 2, "need at least 2 tokens");
        let logits = &self.score(std::slice::from_ref(&tokens.to_vec()))?[0];
        let v = self.vocab;
        let mut total = 0.0f64;
        for i in 0..tokens.len() - 1 {
            let row = &logits[i * v..(i + 1) * v];
            let lse = crate::util::stats::logsumexp(row);
            total += (row[tokens[i + 1] as usize] - lse) as f64;
        }
        Ok(total / (tokens.len() - 1) as f64)
    }
}
