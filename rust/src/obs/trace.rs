//! Per-request stage tracing, gated by the `RESMOE_TRACE` env switch.
//!
//! A trace is a flat list of [`Span`]s collected in a thread-local buffer
//! between [`begin`] and [`finish`]. The engine installs the buffer once
//! per request (or once per batched window) on the executing thread; every
//! layer below — routing, cache decisions, singleflight waits, shard
//! fetches, zstd decode, CRC checks, restore matmuls — drops spans into it
//! through the same [`span`] call without any plumbing through function
//! signatures. Nesting is tracked with a depth counter: a span opened
//! while another is active records `depth + 1`, so the JSONL consumer can
//! rebuild the tree from `(t0, dur, depth)` alone.
//!
//! **Overhead contract.** With tracing off (the default), [`span`] is one
//! relaxed atomic load returning a no-op guard: no thread-local access, no
//! clock read, no allocation. Observation never feeds back into serving
//! decisions, so tracing on/off leaves responses and cache-counter
//! sequences bit-for-bit identical — `scripts/ci.sh` enforces both the
//! ≤3% tracing-off throughput overhead and the parity claim.
//!
//! `RESMOE_TRACE` values: unset/`0`/`off`/`false` → disabled; `1`/`on`/
//! `stderr` → JSONL on stderr; anything else → append to that file path.

use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicI8, AtomicU64, Ordering::Relaxed};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

// ------------------------------------------------------------------ config

#[derive(Clone, Debug, PartialEq)]
enum Sink {
    Off,
    Stderr,
    File(String),
}

fn env_sink() -> &'static Sink {
    static SINK: OnceLock<Sink> = OnceLock::new();
    SINK.get_or_init(|| match std::env::var("RESMOE_TRACE") {
        Err(_) => Sink::Off,
        Ok(v) => match v.as_str() {
            "" | "0" | "off" | "false" => Sink::Off,
            "1" | "on" | "stderr" => Sink::Stderr,
            path => Sink::File(path.to_string()),
        },
    })
}

/// Test override: -1 = follow the env (default), 0 = force off, 1 = force
/// on with the in-memory sink.
static OVERRIDE: AtomicI8 = AtomicI8::new(-1);

/// Is tracing active? One relaxed load on the hot path.
#[inline]
pub fn enabled() -> bool {
    match OVERRIDE.load(Relaxed) {
        0 => false,
        1 => true,
        _ => *env_sink() != Sink::Off,
    }
}

/// Force tracing on (in-memory sink) / off / back to the env setting.
/// Tests that touch this global must serialize on [`test_serial`].
#[doc(hidden)]
pub fn force_for_tests(on: Option<bool>) {
    OVERRIDE.store(match on { None => -1, Some(false) => 0, Some(true) => 1 }, Relaxed);
}

/// Serializes tests (and benches) that flip [`force_for_tests`] or drain
/// the memory sink — the switch is process-global, the test runner is not.
#[doc(hidden)]
pub fn test_serial() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn memory_sink() -> &'static Mutex<Vec<String>> {
    static MEM: OnceLock<Mutex<Vec<String>>> = OnceLock::new();
    MEM.get_or_init(|| Mutex::new(Vec::new()))
}

/// Drain every JSONL line captured by the in-memory test sink.
#[doc(hidden)]
pub fn drain_test_lines() -> Vec<String> {
    std::mem::take(&mut *memory_sink().lock().unwrap())
}

fn file_sink(path: &str) -> &'static Mutex<std::fs::File> {
    static FILE: OnceLock<Mutex<std::fs::File>> = OnceLock::new();
    FILE.get_or_init(|| {
        let f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .unwrap_or_else(|e| panic!("RESMOE_TRACE: cannot open '{path}': {e}"));
        Mutex::new(f)
    })
}

fn write_line(line: &str) {
    if OVERRIDE.load(Relaxed) == 1 {
        memory_sink().lock().unwrap().push(line.to_string());
        return;
    }
    match env_sink() {
        Sink::Off => {}
        Sink::Stderr => eprintln!("{line}"),
        Sink::File(path) => {
            let mut f = file_sink(path).lock().unwrap();
            let _ = writeln!(f, "{line}");
        }
    }
}

// ------------------------------------------------------------------- spans

/// One completed stage interval, relative to the trace's start.
#[derive(Clone, Debug)]
pub struct Span {
    pub stage: &'static str,
    pub start_ns: u64,
    pub end_ns: u64,
    pub depth: u8,
    /// MoE block index, or -1 when not applicable.
    pub block: i64,
    /// Expert slot, or -1 when not applicable.
    pub slot: i64,
    /// Quantization tier of the materialized weights ("f32"/"q8"), if known.
    pub tier: Option<&'static str>,
}

struct Active {
    start: Instant,
    depth: u8,
    spans: Vec<Span>,
}

thread_local! {
    static ACTIVE: RefCell<Option<Active>> = const { RefCell::new(None) };
}

/// Install a trace buffer on this thread. Returns `false` (and does
/// nothing) when tracing is disabled or a trace is already active — e.g.
/// `handle` called from inside `handle_batch` joins the window's trace
/// instead of starting its own. The caller that got `true` owns the
/// matching [`finish`].
pub fn begin() -> bool {
    if !enabled() {
        return false;
    }
    ACTIVE.with(|a| {
        let mut a = a.borrow_mut();
        if a.is_some() {
            return false;
        }
        *a = Some(Active { start: Instant::now(), depth: 0, spans: Vec::with_capacity(32) });
        true
    })
}

/// Tear down the trace installed by the matching [`begin`], returning the
/// measured wall time and the collected spans.
pub fn finish() -> Option<(u64, Vec<Span>)> {
    ACTIVE.with(|a| {
        a.borrow_mut().take().map(|act| {
            let wall = act.start.elapsed().as_nanos() as u64;
            (wall, act.spans)
        })
    })
}

/// RAII guard for one stage. Created by [`span`]; the interval closes when
/// the guard drops. `Inactive` (the disabled / no-trace-installed case) is
/// a pure no-op.
pub enum SpanGuard {
    Inactive,
    Open { stage: &'static str, start_ns: u64, depth: u8, block: i64, slot: i64, tier: Option<&'static str> },
}

impl SpanGuard {
    /// Tag the span with a (block, slot) cache key.
    #[inline]
    pub fn key(&mut self, b: usize, s: usize) {
        if let SpanGuard::Open { block, slot, .. } = self {
            *block = b as i64;
            *slot = s as i64;
        }
    }

    /// Tag the span with a MoE block only.
    #[inline]
    pub fn block(&mut self, b: usize) {
        if let SpanGuard::Open { block, .. } = self {
            *block = b as i64;
        }
    }

    /// Tag the span with the quantization tier of the weights it touched
    /// (set after the fetch/restore reveals it).
    #[inline]
    pub fn tier(&mut self, t: &'static str) {
        if let SpanGuard::Open { tier, .. } = self {
            *tier = Some(t);
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let SpanGuard::Open { stage, start_ns, depth, block, slot, tier } = self {
            ACTIVE.with(|a| {
                if let Some(act) = a.borrow_mut().as_mut() {
                    let end_ns = act.start.elapsed().as_nanos() as u64;
                    act.spans.push(Span {
                        stage,
                        start_ns: *start_ns,
                        end_ns,
                        depth: *depth,
                        block: *block,
                        slot: *slot,
                        tier: *tier,
                    });
                    act.depth = act.depth.saturating_sub(1);
                }
            });
        }
    }
}

/// Open a stage span on the current thread's trace. Disabled tracing (or
/// no installed trace, e.g. a prefetch worker thread) returns the no-op
/// guard after a single atomic load.
#[inline]
pub fn span(stage: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard::Inactive;
    }
    ACTIVE.with(|a| {
        let mut a = a.borrow_mut();
        match a.as_mut() {
            None => SpanGuard::Inactive,
            Some(act) => {
                let depth = act.depth;
                act.depth = act.depth.saturating_add(1);
                SpanGuard::Open {
                    stage,
                    start_ns: act.start.elapsed().as_nanos() as u64,
                    depth,
                    block: -1,
                    slot: -1,
                    tier: None,
                }
            }
        }
    })
}

// ---------------------------------------------------------------- emission

static NEXT_REQ_ID: AtomicU64 = AtomicU64::new(1);

/// Process-unique request id for trace lines.
pub fn next_request_id() -> u64 {
    NEXT_REQ_ID.fetch_add(1, Relaxed)
}

/// Serialize one request's trace as a JSONL line and write it to the
/// configured sink.
///
/// `queue_ns` is the time the request spent waiting for admission before
/// the traced execution began; it is prepended as a depth-0 `queue.wait`
/// span and every execution span is shifted right by it, so a line's spans
/// always start at t0=0 and `wall_ns = queue_ns + execution wall`. Batched
/// windows emit one line per member request sharing the window's execution
/// spans (the trace describes the work that produced that response, which
/// for a batch is shared by construction).
pub fn emit_request(req_id: u64, kind: &'static str, kernel: &'static str, queue_ns: u64, wall_ns: u64, spans: &[Span]) {
    let mut arr: Vec<Json> = Vec::with_capacity(spans.len() + 1);
    if queue_ns > 0 {
        arr.push(span_json("queue.wait", 0, queue_ns, 0, -1, -1, None));
    }
    for s in spans {
        arr.push(span_json(
            s.stage,
            s.start_ns + queue_ns,
            s.end_ns.saturating_sub(s.start_ns),
            s.depth,
            s.block,
            s.slot,
            s.tier,
        ));
    }
    let mut root = BTreeMap::new();
    root.insert("req".into(), Json::Num(req_id as f64));
    root.insert("kind".into(), Json::Str(kind.into()));
    root.insert("kernel".into(), Json::Str(kernel.into()));
    root.insert("queue_ns".into(), Json::Num(queue_ns as f64));
    root.insert("wall_ns".into(), Json::Num(wall_ns as f64));
    root.insert("spans".into(), Json::Arr(arr));
    write_line(&Json::Obj(root).to_string());
}

fn span_json(stage: &str, t0: u64, dur: u64, depth: u8, block: i64, slot: i64, tier: Option<&'static str>) -> Json {
    let mut o = BTreeMap::new();
    o.insert("stage".into(), Json::Str(stage.into()));
    o.insert("t0".into(), Json::Num(t0 as f64));
    o.insert("dur".into(), Json::Num(dur as f64));
    o.insert("depth".into(), Json::Num(depth as f64));
    if block >= 0 {
        o.insert("block".into(), Json::Num(block as f64));
    }
    if slot >= 0 {
        o.insert("slot".into(), Json::Num(slot as f64));
    }
    if let Some(t) = tier {
        o.insert("tier".into(), Json::Str(t.into()));
    }
    Json::Obj(o)
}

// ------------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracing_is_inert() {
        let _g = test_serial();
        force_for_tests(Some(false));
        assert!(!begin());
        {
            let mut g = span("x");
            g.key(1, 2); // no-op on the inactive guard
        }
        assert!(finish().is_none());
        force_for_tests(None);
    }

    #[test]
    fn spans_nest_with_depth_and_emit_jsonl() {
        let _g = test_serial();
        force_for_tests(Some(true));
        drain_test_lines();
        assert!(begin());
        assert!(!begin(), "second begin on the same thread joins, not replaces");
        {
            let mut outer = span("moe.block");
            outer.block(3);
            {
                let mut inner = span("cache.restore");
                inner.key(3, 1);
                inner.tier("q8");
            }
        }
        let (wall, spans) = finish().unwrap();
        assert_eq!(spans.len(), 2);
        // Inner closed first (flat list is in close order), depth below outer.
        assert_eq!(spans[0].stage, "cache.restore");
        assert_eq!(spans[0].depth, 1);
        assert_eq!((spans[0].block, spans[0].slot), (3, 1));
        assert_eq!(spans[0].tier, Some("q8"));
        assert_eq!(spans[1].stage, "moe.block");
        assert_eq!(spans[1].depth, 0);
        assert!(spans[1].start_ns <= spans[0].start_ns);
        assert!(spans[1].end_ns >= spans[0].end_ns);
        assert!(spans.iter().all(|s| s.end_ns <= wall));

        emit_request(7, "score", "scalar", 500, wall + 500, &spans);
        let lines = drain_test_lines();
        assert_eq!(lines.len(), 1);
        let j = Json::parse(&lines[0]).unwrap();
        assert_eq!(j.get("req").and_then(|v| v.as_f64()), Some(7.0));
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("score"));
        let arr = j.get("spans").unwrap().as_arr().unwrap();
        // queue.wait prepended, then the two spans shifted by queue_ns.
        assert_eq!(arr[0].get("stage").and_then(|v| v.as_str()), Some("queue.wait"));
        assert_eq!(arr[0].get("dur").and_then(|v| v.as_f64()), Some(500.0));
        assert_eq!(arr[1].get("tier").and_then(|v| v.as_str()), Some("q8"));
        assert_eq!(
            arr[1].get("t0").and_then(|v| v.as_f64()),
            Some(spans[0].start_ns as f64 + 500.0)
        );
        force_for_tests(None);
    }

    #[test]
    fn spans_without_installed_trace_are_dropped() {
        let _g = test_serial();
        force_for_tests(Some(true));
        drain_test_lines();
        // A prefetch-style worker thread with no begin(): spans vanish.
        {
            let _s = span("store.read");
        }
        assert!(finish().is_none());
        assert!(drain_test_lines().is_empty());
        force_for_tests(None);
    }

    #[test]
    fn request_ids_are_unique() {
        let a = next_request_id();
        let b = next_request_id();
        assert!(b > a);
    }
}
