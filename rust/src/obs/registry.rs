//! Lock-free metrics registry: atomic counters, gauges, and fixed-bucket
//! log-scale histograms.
//!
//! Design constraints (PR 7):
//!
//! * **The record path takes no lock.** `Counter::add`, `Gauge::set` and
//!   `Histogram::record` are a handful of relaxed atomic operations on
//!   pre-registered instruments. In particular, recording a cache event
//!   never touches the cache's metadata mutex, so instrumentation cannot
//!   extend a PR-3 critical section or introduce a new lock-order edge.
//! * **Registration is the cold path.** `Registry` keeps a mutex-guarded
//!   name → instrument table; `counter()`/`gauge()`/`histogram()` take
//!   that mutex once at construction time and hand back an `Arc` the hot
//!   path uses forever after. Snapshots walk the same table, reading each
//!   atomic — again without any serving lock.
//! * **Bounded memory.** The histogram replaces the old unbounded
//!   sorted-`Vec` percentile estimator in `ServerMetrics`: a fixed array
//!   of log-linear buckets (16 sub-buckets per power of two) gives
//!   quantiles with ≤ 1/16 relative error at O(1) record cost and O(592)
//!   read cost, independent of how many samples were recorded.
//!
//! Observation never feeds back into serving decisions, so none of the
//! bit-for-bit parity theorems from PRs 2–6 are affected by anything in
//! this module.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

// ------------------------------------------------------------- instruments

/// Monotonic event counter. Relaxed atomics: totals are exact once the
/// recording threads are joined (tests rely on this), ordering between
/// distinct counters is not guaranteed mid-flight.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Last-write-wins f64 gauge (stored as bits).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Relaxed);
    }
    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Relaxed))
    }
}

// ---------------------------------------------------------- histogram scale

/// Sub-buckets per power of two (relative quantile error ≤ 1/SUB).
pub const HIST_SUB: usize = 16;
/// Values below this are stored exactly, one bucket each.
const LINEAR_MAX: u64 = 16;
/// Largest exponent with its own octave of buckets; values ≥ 2^(MAX_EXP+1)
/// are clamped into the top octave. 2^40 ns ≈ 18 minutes, 2^40 µs ≈ 2 weeks
/// — far beyond any latency this system records.
const MAX_EXP: u32 = 39;
/// Total bucket count: 16 exact + 36 octaves × 16 sub-buckets = 592.
pub const HIST_BUCKETS: usize = LINEAR_MAX as usize + (MAX_EXP as usize - 3) * HIST_SUB;

/// Map a value to its bucket index (log-linear, HDR-style).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < LINEAR_MAX {
        return v as usize;
    }
    let v = v.min((1u64 << (MAX_EXP + 1)) - 1);
    let e = 63 - v.leading_zeros(); // 4..=MAX_EXP
    LINEAR_MAX as usize + (e as usize - 4) * HIST_SUB + ((v >> (e - 4)) & 15) as usize
}

/// Inclusive lower bound of a bucket.
pub fn bucket_lower(idx: usize) -> u64 {
    if idx < LINEAR_MAX as usize {
        return idx as u64;
    }
    let e = 4 + (idx - LINEAR_MAX as usize) / HIST_SUB;
    let m = ((idx - LINEAR_MAX as usize) % HIST_SUB) as u64;
    (LINEAR_MAX + m) << (e - 4)
}

/// Inclusive upper bound of a bucket (quantiles report this bound, so the
/// estimate is conservative: never below the true quantile).
pub fn bucket_upper(idx: usize) -> u64 {
    if idx < LINEAR_MAX as usize {
        return idx as u64;
    }
    let e = 4 + (idx - LINEAR_MAX as usize) / HIST_SUB;
    bucket_lower(idx) + (1u64 << (e - 4)) - 1
}

/// Fixed-bucket log-scale histogram. Record cost: three relaxed adds.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>, // HIST_BUCKETS entries
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Relaxed)).collect(),
            count: self.count.load(Relaxed),
            sum: self.sum.load(Relaxed),
        }
    }
}

/// Point-in-time copy of a histogram's buckets, cheap to query repeatedly.
#[derive(Debug, Clone, Default)]
pub struct HistogramSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl HistogramSnapshot {
    /// Upper bound of the bucket holding the q-quantile sample (0 when
    /// empty). Overestimates by at most one bucket width: relative error
    /// ≤ 1/16 for values ≥ 16, exact below that.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper(idx);
            }
        }
        bucket_upper(HIST_BUCKETS - 1)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the highest non-empty bucket (0 when empty).
    pub fn max_bound(&self) -> u64 {
        self.buckets
            .iter()
            .enumerate()
            .rev()
            .find(|(_, &c)| c > 0)
            .map(|(idx, _)| bucket_upper(idx))
            .unwrap_or(0)
    }
}

// ---------------------------------------------------------------- registry

enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Name → instrument table. One registry per engine (NOT global): the test
/// suites build pairs of engines and compare their counters one-for-one,
/// which a process-global registry would conflate.
#[derive(Default)]
pub struct Registry {
    instruments: Mutex<Vec<(String, Instrument)>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-register a counter. Panics if `name` is already registered as
    /// a different instrument kind (a wiring bug, not a runtime condition).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut table = self.instruments.lock().unwrap();
        if let Some((_, inst)) = table.iter().find(|(n, _)| n == name) {
            match inst {
                Instrument::Counter(c) => return c.clone(),
                _ => panic!("instrument '{name}' already registered with another kind"),
            }
        }
        let c = Arc::new(Counter::new());
        table.push((name.to_string(), Instrument::Counter(c.clone())));
        c
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut table = self.instruments.lock().unwrap();
        if let Some((_, inst)) = table.iter().find(|(n, _)| n == name) {
            match inst {
                Instrument::Gauge(g) => return g.clone(),
                _ => panic!("instrument '{name}' already registered with another kind"),
            }
        }
        let g = Arc::new(Gauge::new());
        table.push((name.to_string(), Instrument::Gauge(g.clone())));
        g
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut table = self.instruments.lock().unwrap();
        if let Some((_, inst)) = table.iter().find(|(n, _)| n == name) {
            match inst {
                Instrument::Histogram(h) => return h.clone(),
                _ => panic!("instrument '{name}' already registered with another kind"),
            }
        }
        let h = Arc::new(Histogram::new());
        table.push((name.to_string(), Instrument::Histogram(h.clone())));
        h
    }

    /// Read every instrument. Takes the registry's own table mutex (never
    /// contended by recording, which only touches the `Arc`s) and no other
    /// lock — snapshotting while a serving thread holds the cache mutex is
    /// safe and non-blocking.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let table = self.instruments.lock().unwrap();
        let mut snap = MetricsSnapshot::default();
        for (name, inst) in table.iter() {
            match inst {
                Instrument::Counter(c) => snap.counters.push((name.clone(), c.get())),
                Instrument::Gauge(g) => snap.gauges.push((name.clone(), g.get())),
                Instrument::Histogram(h) => snap.histograms.push((name.clone(), h.snapshot())),
            }
        }
        snap
    }
}

// ---------------------------------------------------------------- snapshot

/// Point-in-time view of a whole registry, serializable to Prometheus-style
/// text or JSON.
#[derive(Default, Clone, Debug)]
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
    /// Optional tenant tag: multi-tenant deployments (two engines, one
    /// store — see the loadgen harness) label each engine's snapshot so
    /// exported documents stay attributable. `None` (the default) leaves
    /// the serialized forms byte-identical to the untagged output.
    pub tenant: Option<String>,
}

fn prom_name(name: &str) -> String {
    let mut s = String::with_capacity(name.len() + 7);
    s.push_str("resmoe_");
    for ch in name.chars() {
        s.push(if ch.is_ascii_alphanumeric() { ch } else { '_' });
    }
    s
}

impl MetricsSnapshot {
    /// Tag this snapshot with a tenant name (builder-style).
    pub fn with_tenant(mut self, tenant: impl Into<String>) -> MetricsSnapshot {
        self.tenant = Some(tenant.into());
        self
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// Prometheus exposition-style text: counters and gauges verbatim,
    /// histograms as summaries (quantile upper bounds + sum + count).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            let p = prom_name(name);
            out.push_str(&format!("# TYPE {p} counter\n{p} {v}\n"));
        }
        for (name, v) in &self.gauges {
            let p = prom_name(name);
            out.push_str(&format!("# TYPE {p} gauge\n{p} {v}\n"));
        }
        for (name, h) in &self.histograms {
            let p = prom_name(name);
            out.push_str(&format!("# TYPE {p} summary\n"));
            for q in [0.5, 0.9, 0.99] {
                out.push_str(&format!("{p}{{quantile=\"{q}\"}} {}\n", h.quantile(q)));
            }
            out.push_str(&format!("{p}_sum {}\n{p}_count {}\n", h.sum, h.count));
        }
        out
    }

    /// JSON form consumed by `--metrics-out` and the ci.sh SLO gate.
    /// Histograms keep their non-empty buckets as `[index, count]` pairs so
    /// offline tooling can recompute any quantile.
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (name, v) in &self.counters {
            counters.insert(name.clone(), Json::Num(*v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (name, v) in &self.gauges {
            gauges.insert(name.clone(), Json::Num(*v));
        }
        let mut hists = BTreeMap::new();
        for (name, h) in &self.histograms {
            let mut obj = BTreeMap::new();
            obj.insert("count".into(), Json::Num(h.count as f64));
            obj.insert("sum".into(), Json::Num(h.sum as f64));
            obj.insert("p50".into(), Json::Num(h.quantile(0.5) as f64));
            obj.insert("p90".into(), Json::Num(h.quantile(0.9) as f64));
            obj.insert("p99".into(), Json::Num(h.quantile(0.99) as f64));
            obj.insert("max".into(), Json::Num(h.max_bound() as f64));
            let buckets: Vec<Json> = h
                .buckets
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(idx, &c)| Json::Arr(vec![Json::Num(idx as f64), Json::Num(c as f64)]))
                .collect();
            obj.insert("buckets".into(), Json::Arr(buckets));
            hists.insert(name.clone(), Json::Obj(obj));
        }
        let mut root = BTreeMap::new();
        root.insert("counters".into(), Json::Obj(counters));
        root.insert("gauges".into(), Json::Obj(gauges));
        root.insert("histograms".into(), Json::Obj(hists));
        if let Some(t) = &self.tenant {
            root.insert("tenant".into(), Json::str(t));
        }
        Json::Obj(root)
    }
}

// ------------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_scale_is_a_partition() {
        // Buckets tile [0, 2^40) without gaps or overlaps, and the index
        // map agrees with the bounds at every boundary.
        assert_eq!(bucket_lower(0), 0);
        for idx in 0..HIST_BUCKETS {
            let (lo, hi) = (bucket_lower(idx), bucket_upper(idx));
            assert!(lo <= hi, "bucket {idx}");
            assert_eq!(bucket_index(lo), idx, "lower bound of bucket {idx}");
            assert_eq!(bucket_index(hi), idx, "upper bound of bucket {idx}");
            if idx + 1 < HIST_BUCKETS {
                assert_eq!(bucket_lower(idx + 1), hi + 1, "no gap after bucket {idx}");
            }
        }
        assert_eq!(bucket_upper(HIST_BUCKETS - 1), (1u64 << (MAX_EXP + 1)) - 1);
        // Saturation: anything beyond the cap lands in the top bucket.
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn small_values_are_exact_and_large_values_bounded() {
        for v in 0..LINEAR_MAX {
            let idx = bucket_index(v);
            assert_eq!(bucket_lower(idx), v);
            assert_eq!(bucket_upper(idx), v);
        }
        // Relative error of the upper-bound estimate is ≤ 1/16 above the
        // linear range.
        for v in [16u64, 100, 999, 12345, 1 << 20, (1 << 30) + 7] {
            let hi = bucket_upper(bucket_index(v));
            assert!(hi >= v);
            assert!((hi - v) as f64 <= v as f64 / 16.0 + 1.0, "v={v} hi={hi}");
        }
    }

    #[test]
    fn quantiles_are_conservative_and_tight() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        assert_eq!(s.sum, 500_500);
        for (q, exact) in [(0.5, 500u64), (0.9, 900), (0.99, 990)] {
            let est = s.quantile(q);
            assert!(est >= exact, "q={q}: est {est} < exact {exact}");
            assert!(
                est as f64 <= exact as f64 * (1.0 + 1.0 / 16.0) + 1.0,
                "q={q}: est {est} too far above exact {exact}"
            );
        }
        assert_eq!(s.quantile(0.0), s.quantile(1.0 / 1000.0));
        assert!(s.max_bound() >= 1000);
        assert!((s.mean() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.quantile(0.5), 0);
        assert_eq!(s.max_bound(), 0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn concurrent_recording_totals_are_exact() {
        let reg = Registry::new();
        let c = reg.counter("t.events");
        let h = reg.histogram("t.lat");
        let threads = 8;
        let per = 10_000u64;
        std::thread::scope(|s| {
            for t in 0..threads {
                let (c, h) = (c.clone(), h.clone());
                s.spawn(move || {
                    for i in 0..per {
                        c.inc();
                        h.record(t * per + i);
                    }
                });
            }
        });
        assert_eq!(c.get(), threads * per);
        let snap = reg.snapshot();
        let hs = snap.histogram("t.lat").unwrap();
        assert_eq!(hs.count, threads * per);
        assert_eq!(hs.buckets.iter().sum::<u64>(), threads * per);
    }

    #[test]
    fn registry_returns_same_instrument_for_same_name() {
        let reg = Registry::new();
        let a = reg.counter("x");
        let b = reg.counter("x");
        a.add(3);
        assert_eq!(b.get(), 3);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn snapshot_serializes_to_prometheus_and_json() {
        let reg = Registry::new();
        reg.counter("cache.hits").add(7);
        reg.gauge("server.wall_s").set(1.5);
        reg.histogram("server.latency_us").record(120);
        let snap = reg.snapshot();
        let text = snap.to_prometheus();
        assert!(text.contains("resmoe_cache_hits 7"), "{text}");
        assert!(text.contains("resmoe_server_wall_s 1.5"), "{text}");
        assert!(text.contains("resmoe_server_latency_us_count 1"), "{text}");
        assert!(text.contains("quantile=\"0.99\""), "{text}");
        // JSON round-trips through the in-tree parser.
        let parsed = Json::parse(&snap.to_json().to_string()).unwrap();
        let hits = parsed.get("counters").and_then(|c| c.get("cache.hits"));
        assert_eq!(hits.and_then(|j| j.as_f64()), Some(7.0));
        let p99 = parsed
            .get("histograms")
            .and_then(|h| h.get("server.latency_us"))
            .and_then(|h| h.get("p99"))
            .and_then(|j| j.as_f64())
            .unwrap();
        assert!(p99 >= 120.0 && p99 <= 128.0, "p99={p99}");
    }
}
