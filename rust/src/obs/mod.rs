//! Serving-path observability: lock-free metrics + per-request tracing.
//!
//! Two halves, one invariant:
//!
//! * [`registry`] — atomic counters/gauges/log-scale histograms behind a
//!   per-engine [`Registry`], snapshotable from any thread as Prometheus
//!   text or JSON. Recording is a few relaxed atomic adds and never takes
//!   a lock, so it cannot extend the cache's metadata critical sections.
//! * [`trace`] — thread-local stage spans (queue wait, forward, route,
//!   serve decision, shard fetch/decode/CRC, restore, singleflight wait)
//!   emitted as JSONL behind the `RESMOE_TRACE` env switch.
//!
//! The invariant: **observation never feeds back into serving decisions.**
//! Every bit-for-bit parity theorem (batched==serial, store==monolithic,
//! concurrent==serial, SIMD==scalar, tracing-on==tracing-off) survives
//! instrumentation by construction.

pub mod registry;
pub mod trace;

pub use registry::{
    bucket_index, bucket_lower, bucket_upper, Counter, Gauge, Histogram, HistogramSnapshot,
    MetricsSnapshot, Registry, HIST_BUCKETS, HIST_SUB,
};
pub use trace::{Span, SpanGuard};
