//! Sharded on-disk expert artifact store (`RMES`) — the layer between
//! checkpoint and cache that completes the paper's space-efficiency story
//! end-to-end: inference no longer needs every expert's parameters resident
//! OR on the load path.
//!
//! A packed artifact holds the expert-stripped backbone (RMW1 bytes), one
//! shared barycenter shard per compressed layer, and one residual shard per
//! expert — each independently zstd-compressed and CRC-32-checked, located
//! by a JSON index, so any single expert is readable without touching the
//! rest of the file ([`format`]). [`pack`] converts checkpoints / compressed
//! models into artifacts; [`prefetch`] decodes router-predicted shards on
//! the worker pool ahead of demand. The serving side lives in
//! `coordinator::cache` ([`crate::coordinator::ExpertCache::from_store`])
//! and `coordinator::server` (`Engine::from_store`), with `resmoe pack` /
//! `resmoe serve-packed` as the CLI entry points.

pub mod format;
pub mod pack;
pub mod prefetch;

pub use format::{
    ExpertShardInfo, ExpertStore, LayerEntry, ShardInfo, StoreIndex, StoreWriter,
    MIN_STORE_VERSION, STORE_MAGIC, STORE_VERSION,
};
pub use pack::{
    pack_checkpoint, pack_checkpoint_with, pack_compressed_model, pack_compressed_model_with,
    pack_model, pack_model_with, quantize_layer, summarize, PackSummary, QuantizeMode,
};
pub use prefetch::Prefetcher;
