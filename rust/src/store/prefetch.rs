//! Async expert prefetch: decode router-predicted residual shards on the
//! `util/threads` worker pool *ahead of demand*, so a store-backed engine
//! hides artifact I/O + decompression behind compute.
//!
//! Flow: the serving hook observes which slots a block routed to and calls
//! [`Prefetcher::request`] with predictions for a later block. The request
//! plans against the cache under its lock (recording prefetch hit/miss
//! metrics, deduplicating against resident state), then fans the actual
//! fetch + CRC check + decode out as detached pool jobs — the cache lock is
//! NOT held while a shard is read — and each finished shard is handed back
//! through `ExpertCache::insert_prefetched`, which never displaces
//! demand-proven residents.

use super::format::ExpertStore;
use crate::coordinator::cache::ExpertCache;
use crate::util::threads::spawn_detached;
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub struct Prefetcher {
    cache: Arc<Mutex<ExpertCache>>,
    store: Arc<ExpertStore>,
    /// (block, expert index) fetches currently running on the pool.
    inflight: Arc<Mutex<HashSet<(usize, usize)>>>,
    /// Decoded shards discarded because the cache mutex was contended at
    /// insert time (the jobs may not block on it — see `request`). Flushed
    /// into `CacheMetrics::prefetch_dropped` on the next planning pass so
    /// the effectiveness numbers stay honest.
    contended_drops: Arc<AtomicU64>,
}

/// Removes its key from the inflight set on drop — runs even when the
/// fetch job panics (spawn_detached catches the unwind AFTER locals drop),
/// so `quiesce` can never spin on a leaked entry. Poison-tolerant: a
/// panicked peer must not wedge the bookkeeping.
struct InflightGuard {
    inflight: Arc<Mutex<HashSet<(usize, usize)>>>,
    key: (usize, usize),
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        let mut g = match self.inflight.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        g.remove(&self.key);
    }
}

impl Prefetcher {
    pub fn new(cache: Arc<Mutex<ExpertCache>>, store: Arc<ExpertStore>) -> Prefetcher {
        Prefetcher {
            cache,
            store,
            inflight: Arc::new(Mutex::new(HashSet::new())),
            contended_drops: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Request async paging of predicted `(block, slot)` keys. Returns the
    /// number of fetches scheduled (0 when everything was already resident
    /// or in flight — in-flight keys count as prefetch hits, not as a
    /// second miss).
    pub fn request(&self, keys: &[(usize, usize)]) -> usize {
        // Lock order: inflight → cache. The fetch jobs never hold the cache
        // lock while taking inflight (the guard drops after the job's cache
        // block), so this cannot deadlock. Planning and the inflight
        // reservation happen in ONE critical section: two concurrent
        // requests predicting the same key must record one miss and one
        // fetch, not two misses and one fetch.
        let targets = {
            let mut infl = self.inflight.lock().unwrap();
            let mut cache = self.cache.lock().unwrap();
            // Account shards that finished but could not be inserted since
            // the last pass (cache mutex contended at insert time).
            cache.metrics.prefetch_dropped += self.contended_drops.swap(0, Ordering::Relaxed);
            let planned = cache.plan_prefetch(keys, &infl);
            for key in &planned {
                infl.insert(*key);
            }
            planned
        };
        let scheduled = targets.len();
        for (block, eidx) in targets {
            let cache = Arc::clone(&self.cache);
            let store = Arc::clone(&self.store);
            let guard =
                InflightGuard { inflight: Arc::clone(&self.inflight), key: (block, eidx) };
            let contended = Arc::clone(&self.contended_drops);
            spawn_detached(move || {
                let _guard = guard;
                // Fetch + verify + decode WITHOUT the cache lock.
                let result = store.load_expert(block, eidx);
                // try_lock, never lock: this closure runs on the shared
                // worker pool, and a serve holding the cache mutex may
                // itself be blocked on pool capacity (restore matmuls run
                // under the lock). A pool worker parked on that mutex
                // would complete the cycle and deadlock the server, so on
                // contention the prefetched shard is dropped — counted via
                // `contended_drops`; the demand path fetches it if it was
                // really needed.
                match cache.try_lock() {
                    Ok(mut cache) => match result {
                        Ok(expert) => cache.insert_prefetched(block, eidx, expert),
                        // A failed prefetch is not fatal: the demand path
                        // will retry and surface the error if it persists.
                        Err(_) => cache.metrics.prefetch_dropped += 1,
                    },
                    Err(_) => {
                        contended.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
        scheduled
    }

    /// Wait until no fetches are in flight (shutdown / deterministic tests),
    /// then flush any contended-drop counts into the cache metrics so a
    /// metrics read right after quiesce sees the complete story.
    pub fn quiesce(&self) {
        while !self.inflight.lock().unwrap().is_empty() {
            std::thread::sleep(Duration::from_micros(50));
        }
        let drops = self.contended_drops.swap(0, Ordering::Relaxed);
        if drops > 0 {
            self.cache.lock().unwrap().metrics.prefetch_dropped += drops;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::compress::ResMoE;
    use crate::moe::{ExpertArch, Model, ModelConfig, MoeLayer};
    use crate::store::pack_compressed_model;
    use crate::util::Rng;

    fn store_cache(seed: u64) -> (Arc<Mutex<ExpertCache>>, Arc<ExpertStore>) {
        let mut rng = Rng::new(seed);
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 8;
        cfg.d_inner = 16;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        let model = Model::random(&cfg, &mut rng);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &l, 0.25, seed);
        let dir = std::env::temp_dir().join("resmoe-prefetch-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("pf-{seed}.rmes"));
        pack_compressed_model(&model, &[(1, cl)], 0.25, &path).unwrap();
        let store = Arc::new(ExpertStore::open(&path).unwrap());
        let cache =
            Arc::new(Mutex::new(ExpertCache::from_store(store.clone(), usize::MAX).unwrap()));
        (cache, store)
    }

    #[test]
    fn prefetcher_pages_shards_in_background() {
        let (cache, store) = store_cache(40);
        let pf = Prefetcher::new(cache.clone(), store);
        let scheduled = pf.request(&[(1, 0), (1, 2), (7, 0)]);
        assert_eq!(scheduled, 2, "unknown block dropped, two fetches scheduled");
        pf.quiesce();
        let mut guard = cache.lock().unwrap();
        assert_eq!(guard.resident_shards(), 2);
        assert_eq!(guard.metrics.prefetch_misses, 2);
        // Demand access hits the prefetched shard without a new fetch.
        let fetches = guard.metrics.shard_fetches;
        guard.get(1, 0);
        assert_eq!(guard.metrics.shard_fetches, fetches);
        assert!(guard.metrics.prefetch_useful >= 1);
    }

    #[test]
    fn repeated_requests_do_not_double_fetch() {
        let (cache, store) = store_cache(41);
        let pf = Prefetcher::new(cache.clone(), store);
        pf.request(&[(1, 1)]);
        pf.quiesce();
        // Resident now: further requests are prefetch hits, zero scheduled.
        assert_eq!(pf.request(&[(1, 1)]), 0);
        pf.quiesce();
        let guard = cache.lock().unwrap();
        assert_eq!(guard.resident_shards(), 1);
        assert_eq!(guard.metrics.shard_fetches, 1);
        assert_eq!(guard.metrics.prefetch_hits, 1);
    }
}
