//! Async expert prefetch: decode router-predicted residual shards on the
//! `util/threads` worker pool *ahead of demand*, so a store-backed engine
//! hides artifact I/O + decompression behind compute.
//!
//! Flow: the serving hook observes which slots a block routed to and calls
//! [`Prefetcher::request`] with predictions for a later block. The request
//! plans against the cache (recording prefetch hit/miss metrics,
//! deduplicating against resident state, the prefetcher's own in-flight
//! set, AND live demand fetches), then fans the actual fetch + CRC check +
//! decode out as detached pool jobs — no cache lock is held while a shard
//! is read — and each finished shard is handed back through
//! [`crate::coordinator::ExpertCache::insert_prefetched`], which never
//! displaces demand-proven residents.
//!
//! Since the cache's metadata critical sections are map operations only
//! (every fetch/decode/restore runs unlocked — see `coordinator/cache.rs`),
//! the publish step takes the metadata lock *properly*: a pool worker
//! parking there for a few map ops cannot deadlock against a serve, so
//! contended prefetch results are no longer thrown away the way the old
//! `try_lock`-and-drop scheme had to.

use super::format::ExpertStore;
use crate::coordinator::cache::ExpertCache;
use crate::obs::trace;
use crate::util::threads::spawn_detached;
use std::collections::HashSet;
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub struct Prefetcher {
    cache: Arc<ExpertCache>,
    store: Arc<ExpertStore>,
    /// (block, expert index) fetches currently running on the pool.
    inflight: Arc<Mutex<HashSet<(usize, usize)>>>,
}

/// Removes its key from the inflight set on drop — runs even when the
/// fetch job panics (spawn_detached catches the unwind AFTER locals drop),
/// so `quiesce` can never spin on a leaked entry. Poison-tolerant: a
/// panicked peer must not wedge the bookkeeping.
struct InflightGuard {
    inflight: Arc<Mutex<HashSet<(usize, usize)>>>,
    key: (usize, usize),
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        let mut g = match self.inflight.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        g.remove(&self.key);
    }
}

impl Prefetcher {
    pub fn new(cache: Arc<ExpertCache>, store: Arc<ExpertStore>) -> Prefetcher {
        Prefetcher { cache, store, inflight: Arc::new(Mutex::new(HashSet::new())) }
    }

    /// Request async paging of predicted `(block, slot)` keys. Returns the
    /// number of fetches scheduled (0 when everything was already resident
    /// or in flight — in-flight keys count as prefetch hits, not as a
    /// second miss).
    pub fn request(&self, keys: &[(usize, usize)]) -> usize {
        // Lock order: inflight → cache metadata (inside plan_prefetch).
        // The fetch jobs take the cache metadata lock and release it BEFORE
        // their InflightGuard drops (takes inflight), so the two locks are
        // never nested in the opposite order. Planning and the inflight
        // reservation happen in ONE critical section: two concurrent
        // requests predicting the same key must record one miss and one
        // fetch, not two misses and one fetch.
        let targets = {
            let _plan_span = trace::span("prefetch.plan");
            let mut infl = self.inflight.lock().unwrap();
            let planned = self.cache.plan_prefetch(keys, &infl);
            for key in &planned {
                infl.insert(*key);
            }
            planned
        };
        let scheduled = targets.len();
        for (block, eidx) in targets {
            let cache = Arc::clone(&self.cache);
            let store = Arc::clone(&self.store);
            let guard =
                InflightGuard { inflight: Arc::clone(&self.inflight), key: (block, eidx) };
            spawn_detached(move || {
                let _guard = guard;
                // Fetch + verify + decode with no cache lock anywhere near.
                match store.load_expert(block, eidx) {
                    // Publish under the metadata lock — a short map insert.
                    // Blocking here is safe: no serve holds that lock
                    // across heavy work anymore, so the worker parks for
                    // nanoseconds instead of dropping the decoded shard.
                    Ok(expert) => cache.insert_prefetched(block, eidx, expert),
                    // A failed prefetch is not fatal and never poisons the
                    // demand path: the InflightGuard drop releases the key,
                    // no cache state was touched, and a router that still
                    // wants this shard will demand-fetch it (with its own
                    // retry/quarantine handling) and surface the error if
                    // it persists.
                    Err(_) => cache.note_prefetch_error(),
                }
            });
        }
        scheduled
    }

    /// Wait until no fetches are in flight (shutdown / deterministic
    /// tests).
    pub fn quiesce(&self) {
        while !self.inflight.lock().unwrap().is_empty() {
            std::thread::sleep(Duration::from_micros(50));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::compress::ResMoE;
    use crate::moe::{ExpertArch, Model, ModelConfig, MoeLayer};
    use crate::store::pack_compressed_model;
    use crate::util::Rng;

    fn store_cache(seed: u64) -> (Arc<ExpertCache>, Arc<ExpertStore>) {
        let mut rng = Rng::new(seed);
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 8;
        cfg.d_inner = 16;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        let model = Model::random(&cfg, &mut rng);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &l, 0.25, seed);
        let dir = std::env::temp_dir().join("resmoe-prefetch-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("pf-{seed}.rmes"));
        pack_compressed_model(&model, &[(1, cl)], 0.25, &path).unwrap();
        let store = Arc::new(ExpertStore::open(&path).unwrap());
        let cache = Arc::new(ExpertCache::from_store(store.clone(), usize::MAX).unwrap());
        (cache, store)
    }

    #[test]
    fn prefetcher_pages_shards_in_background() {
        let (cache, store) = store_cache(40);
        let pf = Prefetcher::new(cache.clone(), store);
        let scheduled = pf.request(&[(1, 0), (1, 2), (7, 0)]);
        assert_eq!(scheduled, 2, "unknown block dropped, two fetches scheduled");
        pf.quiesce();
        assert_eq!(cache.resident_shards(), 2);
        assert_eq!(cache.metrics().prefetch_misses, 2);
        // Demand access hits the prefetched shard without a new fetch.
        let fetches = cache.metrics().shard_fetches;
        cache.get(1, 0);
        assert_eq!(cache.metrics().shard_fetches, fetches);
        assert!(cache.metrics().prefetch_useful >= 1);
    }

    #[test]
    fn repeated_requests_do_not_double_fetch() {
        let (cache, store) = store_cache(41);
        let pf = Prefetcher::new(cache.clone(), store);
        pf.request(&[(1, 1)]);
        pf.quiesce();
        // Resident now: further requests are prefetch hits, zero scheduled.
        assert_eq!(pf.request(&[(1, 1)]), 0);
        pf.quiesce();
        let m = cache.metrics();
        assert_eq!(cache.resident_shards(), 1);
        assert_eq!(m.shard_fetches, 1);
        assert_eq!(m.prefetch_hits, 1);
    }

    #[test]
    fn contended_prefetches_are_not_dropped_under_concurrent_serves() {
        // Regression for the old try_lock-and-drop publish: pool jobs
        // racing a stream of demand serves used to lose their decoded
        // shards to mutex contention (`prefetch_dropped`). With the short
        // metadata critical section the publish parks briefly and always
        // lands. Demand traffic (slots 0/1) is disjoint from the
        // prefetched keys (2/3) and the budget is unbounded, so the ONLY
        // way a prefetch could drop here is contention — assert none.
        let (cache, store) = store_cache(42);
        let pf = Prefetcher::new(cache.clone(), store);
        std::thread::scope(|s| {
            for t in 0..4usize {
                let cache = &cache;
                s.spawn(move || {
                    for i in 0..50usize {
                        let slot = (t + i) % 2;
                        cache.try_serve(1, slot, 1).unwrap();
                    }
                });
            }
            for _ in 0..25 {
                pf.request(&[(1, 2), (1, 3)]);
            }
        });
        pf.quiesce();
        let m = cache.metrics();
        assert_eq!(m.prefetch_dropped, 0, "no contended drops: {m:?}");
        assert_eq!(cache.resident_shards(), 4, "both prefetched keys resident");
        assert!(m.prefetch_misses >= 2);
    }
}
