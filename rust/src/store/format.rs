//! The `RMES` sharded expert-artifact container.
//!
//! Layout:
//! ```text
//! magic   b"RMES"
//! u32 LE  format version (2)
//! u64 LE  index offset (patched by the writer on finish)
//! shard bytes (each shard independently zstd-compressed)
//! index:  u32 LE length + JSON
//! ```
//!
//! Version history: v1 stored f32 residual kinds only (`dense`/`csr`/
//! `svd`); v2 adds the int8 residual shard kinds (`q8-dense`/`q8-csr`/
//! `q8-svd`) plus a per-expert `qerr` index field (the advertised
//! dequantization error bound). The container layout is unchanged, so v1
//! files read back cleanly; a file CLAIMING v1 while containing quantized
//! kinds is rejected as malformed.
//!
//! The JSON index records, for every shard, its absolute file offset, its
//! on-disk (compressed) and raw (decoded) byte sizes, and a CRC-32 of the
//! on-disk bytes — so any single expert residual is readable and
//! verifiable **without touching any other shard**. One backbone shard
//! (the expert-stripped model as RMW1 bytes), one center shard per
//! compressed layer (the barycenter `W_ω`), one meta shard per layer
//! (expert map + alignments), and one residual shard per stored expert.
//!
//! Corruption policy: a shard whose CRC, decoded length, or payload
//! structure disagrees with the index is an error — never silently served.

use crate::compress::{
    decode_matrix_shard, encode_matrix_shard, CompressedExpert, CompressedLayer,
};
use crate::moe::model_io::{model_from_bytes, model_to_bytes};
use crate::moe::{ExpertArch, Model, ModelConfig};
use crate::obs::trace;
use crate::util::bytes::{ByteReader, PutLe};
use crate::util::crc32::crc32;
use crate::util::fault;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub const STORE_MAGIC: &[u8; 4] = b"RMES";
/// Current writer version. Readers accept `1..=STORE_VERSION`.
pub const STORE_VERSION: u32 = 2;
/// Oldest version this reader still accepts.
pub const MIN_STORE_VERSION: u32 = 1;
/// Byte offset where shard data starts (magic + version + index offset).
const DATA_START: u64 = 4 + 4 + 8;
/// zstd level passed to the vendored coder (accepted for API parity).
const ZSTD_LEVEL: i32 = 3;

/// Location + integrity data of one shard.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardInfo {
    /// Absolute file offset of the compressed shard bytes.
    pub offset: u64,
    /// On-disk (compressed) size.
    pub bytes: u64,
    /// Decoded payload size.
    pub raw_bytes: u64,
    /// CRC-32 of the on-disk bytes.
    pub crc32: u32,
}

impl ShardInfo {
    fn to_json(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("offset", Json::num(self.offset as f64)),
            ("bytes", Json::num(self.bytes as f64)),
            ("raw", Json::num(self.raw_bytes as f64)),
            ("crc", Json::num(self.crc32 as f64)),
        ]
    }

    fn from_json(j: &Json) -> Result<ShardInfo> {
        let field = |k: &str| {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("shard entry missing numeric '{k}'"))
        };
        Ok(ShardInfo {
            offset: field("offset")? as u64,
            bytes: field("bytes")? as u64,
            raw_bytes: field("raw")? as u64,
            crc32: field("crc")? as u32,
        })
    }
}

/// One residual shard: location plus the residual kind recorded for
/// index-only tooling (`dense` / `csr` / `svd` / `q8-*`).
#[derive(Debug, Clone, PartialEq)]
pub struct ExpertShardInfo {
    pub shard: ShardInfo,
    pub kind: String,
    /// Advertised per-element dequantization error bound for `q8-*` kinds
    /// (0.0 for exact f32 shards; v2 index field `qerr`, absent in v1).
    pub quant_err: f32,
}

/// Index entry for one compressed layer.
#[derive(Debug, Clone)]
pub struct LayerEntry {
    pub block: usize,
    pub method: String,
    pub arch: ExpertArch,
    pub d_model: usize,
    /// Retention rate the layer was compressed at (informational).
    pub rate: f64,
    /// Design-matrix shape `(pI, D)` shared by every expert of the layer —
    /// lets the cache size a restored expert without fetching its shard.
    pub design_rows: usize,
    pub design_cols: usize,
    /// Shared barycenter shard (`None` for direct methods without a center).
    pub center: Option<ShardInfo>,
    /// Expert map + alignments shard.
    pub meta: ShardInfo,
    /// One residual shard per stored expert, in `experts` order.
    pub experts: Vec<ExpertShardInfo>,
}

/// Parsed store index.
#[derive(Debug, Clone)]
pub struct StoreIndex {
    pub version: u32,
    pub config: ModelConfig,
    pub backbone: ShardInfo,
    pub layers: Vec<LayerEntry>,
}

// ------------------------------------------------------------------ writer

/// Streaming writer: shards are appended as they are produced; the index
/// is written (and the header offset patched) by [`StoreWriter::finish`].
pub struct StoreWriter {
    file: std::io::BufWriter<std::fs::File>,
    path: PathBuf,
    offset: u64,
    config: Option<ModelConfig>,
    backbone: Option<ShardInfo>,
    layers: Vec<LayerEntry>,
}

impl StoreWriter {
    pub fn create(path: &Path) -> Result<StoreWriter> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut file = std::io::BufWriter::new(f);
        file.write_all(STORE_MAGIC)?;
        file.write_all(&STORE_VERSION.to_le_bytes())?;
        file.write_all(&0u64.to_le_bytes())?; // index offset placeholder
        Ok(StoreWriter {
            file,
            path: path.to_path_buf(),
            offset: DATA_START,
            config: None,
            backbone: None,
            layers: Vec::new(),
        })
    }

    /// Compress + checksum + append one shard.
    fn write_shard(&mut self, raw: &[u8]) -> Result<ShardInfo> {
        let compressed = zstd::encode_all(raw, ZSTD_LEVEL).context("zstd encode shard")?;
        let info = ShardInfo {
            offset: self.offset,
            bytes: compressed.len() as u64,
            raw_bytes: raw.len() as u64,
            crc32: crc32(&compressed),
        };
        self.file.write_all(&compressed)?;
        self.offset += compressed.len() as u64;
        Ok(info)
    }

    /// Store the expert-stripped backbone (RMW1 bytes in one shard). Must
    /// be called exactly once; the model's config becomes the store config.
    pub fn put_backbone(&mut self, backbone: &Model) -> Result<()> {
        if self.backbone.is_some() {
            bail!("backbone already written");
        }
        let raw = model_to_bytes(backbone);
        let info = self.write_shard(&raw)?;
        self.backbone = Some(info);
        self.config = Some(backbone.cfg.clone());
        Ok(())
    }

    /// Store one compressed layer: center shard (if any), meta shard, one
    /// residual shard per stored expert.
    pub fn put_layer(&mut self, block: usize, layer: &CompressedLayer, rate: f64) -> Result<()> {
        if self.layers.iter().any(|l| l.block == block) {
            bail!("block {block} already written");
        }
        if layer.experts.is_empty() {
            bail!("block {block}: layer has no stored experts");
        }
        let center = match &layer.base {
            Some(base) => Some(self.write_shard(&encode_matrix_shard(base))?),
            None => None,
        };
        let meta = self.write_shard(&encode_layer_meta(layer))?;
        let mut experts = Vec::with_capacity(layer.experts.len());
        for e in &layer.experts {
            let shard = self.write_shard(&e.encode_shard())?;
            experts.push(ExpertShardInfo {
                shard,
                kind: e.residual.kind_name().to_string(),
                quant_err: e.quant_error_bound(),
            });
        }
        let (design_rows, design_cols) = layer.experts[0].residual.design_shape();
        self.layers.push(LayerEntry {
            block,
            method: layer.method.clone(),
            arch: layer.arch,
            d_model: layer.d_model,
            rate,
            design_rows,
            design_cols,
            center,
            meta,
            experts,
        });
        Ok(())
    }

    /// Write the JSON index, patch the header offset, and sync.
    pub fn finish(mut self) -> Result<()> {
        let backbone = self.backbone.take().ok_or_else(|| anyhow!("no backbone written"))?;
        let config = self.config.take().expect("config set with backbone");
        self.layers.sort_by_key(|l| l.block);
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                let experts: Vec<Json> = l
                    .experts
                    .iter()
                    .map(|e| {
                        let mut fields = e.shard.to_json();
                        fields.push(("kind", Json::str(&e.kind)));
                        fields.push(("qerr", Json::num(e.quant_err as f64)));
                        Json::obj(fields)
                    })
                    .collect();
                Json::obj(vec![
                    ("block", Json::num(l.block as f64)),
                    ("method", Json::str(&l.method)),
                    ("arch", Json::str(l.arch.name())),
                    ("d_model", Json::num(l.d_model as f64)),
                    ("rate", Json::num(l.rate)),
                    ("design_rows", Json::num(l.design_rows as f64)),
                    ("design_cols", Json::num(l.design_cols as f64)),
                    (
                        "center",
                        match &l.center {
                            Some(c) => Json::obj(c.to_json()),
                            None => Json::Null,
                        },
                    ),
                    ("meta", Json::obj(l.meta.to_json())),
                    ("experts", Json::Arr(experts)),
                ])
            })
            .collect();
        let index = Json::obj(vec![
            ("version", Json::num(STORE_VERSION as f64)),
            ("config", config.to_json()),
            ("backbone", Json::obj(backbone.to_json())),
            ("layers", Json::Arr(layers)),
        ])
        .to_string();
        let index_offset = self.offset;
        self.file.write_all(&(index.len() as u32).to_le_bytes())?;
        self.file.write_all(index.as_bytes())?;
        self.file.flush()?;
        let mut f = self.file.into_inner().map_err(|e| anyhow!("flush: {e}"))?;
        f.seek(SeekFrom::Start(8))?;
        f.write_all(&index_offset.to_le_bytes())?;
        f.sync_all().with_context(|| format!("sync {}", self.path.display()))?;
        Ok(())
    }
}

// -------------------------------------------------------- layer meta shard

fn encode_layer_meta(layer: &CompressedLayer) -> Vec<u8> {
    let mut out = Vec::new();
    out.put_u32(layer.expert_map.len() as u32);
    for &e in &layer.expert_map {
        out.put_u32(e as u32);
    }
    out.put_u32(layer.aligns.len() as u32);
    for align in &layer.aligns {
        out.put_u32(align.len() as u32);
        for &v in align {
            out.put_u32(v as u32);
        }
    }
    out
}

fn decode_layer_meta(bytes: &[u8]) -> Result<(Vec<usize>, Vec<Vec<usize>>)> {
    let mut r = ByteReader::new(bytes);
    let n_slots = r.len()?;
    let expert_map: Vec<usize> = r.u32s(n_slots)?.into_iter().map(|v| v as usize).collect();
    let n_aligns = r.len()?;
    let mut aligns = Vec::with_capacity(n_aligns);
    for _ in 0..n_aligns {
        let len = r.len()?;
        aligns.push(r.u32s(len)?.into_iter().map(|v| v as usize).collect());
    }
    r.expect_done()?;
    Ok((expert_map, aligns))
}

// ------------------------------------------------------------------ reader

/// Random-access reader over an `RMES` artifact. Opening parses only the
/// header and JSON index; every shard is fetched, CRC-checked, and decoded
/// on demand (the demand-paging substrate of the serving cache).
pub struct ExpertStore {
    path: PathBuf,
    file: Mutex<std::fs::File>,
    index: StoreIndex,
    by_block: HashMap<usize, usize>,
    file_bytes: u64,
    /// Compressed bytes fetched so far (observability: a demand-paged
    /// serving session should read far less than `file_bytes`).
    bytes_read: AtomicU64,
}

impl ExpertStore {
    pub fn open(path: &Path) -> Result<ExpertStore> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let file_bytes = f.metadata()?.len();
        let mut head = [0u8; DATA_START as usize];
        f.read_exact(&mut head)
            .map_err(|_| anyhow!("{}: truncated header", path.display()))?;
        if &head[..4] != STORE_MAGIC {
            bail!("{}: bad magic (not an RMES artifact)", path.display());
        }
        let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
        if !(MIN_STORE_VERSION..=STORE_VERSION).contains(&version) {
            bail!("{}: unsupported store version {version}", path.display());
        }
        let index_offset = u64::from_le_bytes(head[8..16].try_into().unwrap());
        // Checked arithmetic throughout: the index/header fields are
        // untrusted (not covered by the per-shard CRCs), and an overflowed
        // range check must fail loudly, not wrap into a vacuous pass.
        if index_offset < DATA_START
            || index_offset.checked_add(4).map_or(true, |end| end > file_bytes)
        {
            bail!("{}: index offset {index_offset} out of range", path.display());
        }
        f.seek(SeekFrom::Start(index_offset))?;
        let mut len_buf = [0u8; 4];
        f.read_exact(&mut len_buf)?;
        let index_len = u32::from_le_bytes(len_buf) as u64;
        if (index_offset + 4).checked_add(index_len).map_or(true, |end| end > file_bytes) {
            bail!("{}: truncated index", path.display());
        }
        let mut index_bytes = vec![0u8; index_len as usize];
        f.read_exact(&mut index_bytes)?;
        let index = parse_index(std::str::from_utf8(&index_bytes)?, file_bytes)
            .with_context(|| format!("{}: bad index", path.display()))?;
        if index.version != version {
            bail!(
                "{}: header version {version} disagrees with index version {}",
                path.display(),
                index.version
            );
        }
        // The int8 shard kinds were introduced in v2; a v1 file carrying
        // them was written by nothing we ever shipped — treat as corrupt
        // rather than guessing at its payload layout.
        if version < 2 {
            for l in &index.layers {
                if let Some(e) = l.experts.iter().find(|e| e.kind.starts_with("q8-")) {
                    bail!(
                        "{}: v{version} store contains quantized shard kind '{}' (block {})",
                        path.display(),
                        e.kind,
                        l.block
                    );
                }
            }
        }
        let by_block = index
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| (l.block, i))
            .collect();
        Ok(ExpertStore {
            path: path.to_path_buf(),
            file: Mutex::new(f),
            index,
            by_block,
            file_bytes,
            bytes_read: AtomicU64::new(0),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn config(&self) -> &ModelConfig {
        &self.index.config
    }

    pub fn index(&self) -> &StoreIndex {
        &self.index
    }

    /// Total artifact size on disk.
    pub fn file_bytes(&self) -> u64 {
        self.file_bytes
    }

    /// Compressed bytes fetched since open (all shard reads).
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read.load(Ordering::Relaxed)
    }

    /// Blocks with stored layers, ascending.
    pub fn blocks(&self) -> Vec<usize> {
        self.index.layers.iter().map(|l| l.block).collect()
    }

    pub fn layer_entry(&self, block: usize) -> Option<&LayerEntry> {
        self.by_block.get(&block).map(|&i| &self.index.layers[i])
    }

    /// Decoded (in-memory) bytes of every residual shard — what a cache
    /// budget must exceed to hold all experts resident at once.
    pub fn total_expert_raw_bytes(&self) -> u64 {
        self.index
            .layers
            .iter()
            .flat_map(|l| l.experts.iter())
            .map(|e| e.shard.raw_bytes)
            .sum()
    }

    /// Read + verify + decompress one shard. `site`/`block`/`slot` name the
    /// deterministic failpoint target (`util/fault.rs`); with `RESMOE_FAULTS`
    /// unset the consultation is a single relaxed atomic load.
    fn fetch_shard(
        &self,
        info: &ShardInfo,
        what: &str,
        site: &'static str,
        block: i64,
        slot: i64,
    ) -> Result<Vec<u8>> {
        let injected = fault::check(site, block, slot);
        if let Some(fault::Fault::Latency(us)) = injected {
            std::thread::sleep(std::time::Duration::from_micros(us));
        }
        if injected == Some(fault::Fault::Transient) {
            bail!("{what}: injected transient read error");
        }
        if info
            .offset
            .checked_add(info.bytes)
            .map_or(true, |end| end > self.file_bytes)
        {
            bail!("{what}: shard range {}+{} beyond file end", info.offset, info.bytes);
        }
        let mut compressed = vec![0u8; info.bytes as usize];
        {
            let _read_span = trace::span("store.read");
            let mut f = self.file.lock().unwrap();
            f.seek(SeekFrom::Start(info.offset))?;
            f.read_exact(&mut compressed)
                .with_context(|| format!("{what}: short read"))?;
        }
        if injected == Some(fault::Fault::Truncate) {
            bail!("{what}: short read (injected truncation)");
        }
        if injected == Some(fault::Fault::Corrupt) {
            // Flip one payload byte so the REAL integrity check below trips —
            // the injection exercises the production CRC path, not a mock.
            if let Some(b) = compressed.first_mut() {
                *b ^= 0xFF;
            }
        }
        self.bytes_read.fetch_add(info.bytes, Ordering::Relaxed);
        let got_crc = {
            let _crc_span = trace::span("store.crc");
            crc32(&compressed)
        };
        if got_crc != info.crc32 {
            bail!(
                "{what}: checksum mismatch (stored {:08x}, computed {got_crc:08x}) — refusing to serve corrupt shard",
                info.crc32
            );
        }
        let raw = {
            let _decode_span = trace::span("store.decode");
            zstd::decode_all(&compressed[..])
                .with_context(|| format!("{what}: shard decompression failed"))?
        };
        if raw.len() as u64 != info.raw_bytes {
            bail!("{what}: decoded {} bytes, index says {}", raw.len(), info.raw_bytes);
        }
        Ok(raw)
    }

    /// Load the expert-stripped backbone model.
    pub fn load_backbone(&self) -> Result<Model> {
        let raw = self.fetch_shard(&self.index.backbone, "backbone", "store.meta", -1, -1)?;
        model_from_bytes(&raw)
    }

    /// Load a layer WITHOUT its experts: center + routing metadata. This is
    /// the always-resident part (center-sized); residual shards page in via
    /// [`ExpertStore::load_expert`].
    pub fn load_layer_skeleton(&self, block: usize) -> Result<CompressedLayer> {
        let entry = self
            .layer_entry(block)
            .ok_or_else(|| anyhow!("no stored layer for block {block}"))?;
        let base = match &entry.center {
            Some(info) => Some(decode_matrix_shard(&self.fetch_shard(
                info,
                &format!("block {block} center"),
                "store.meta",
                block as i64,
                -1,
            )?)?),
            None => None,
        };
        let (expert_map, aligns) = decode_layer_meta(&self.fetch_shard(
            &entry.meta,
            &format!("block {block} meta"),
            "store.meta",
            block as i64,
            -1,
        )?)?;
        if expert_map.iter().any(|&e| e >= entry.experts.len()) {
            bail!("block {block}: expert map references a missing shard");
        }
        Ok(CompressedLayer {
            method: entry.method.clone(),
            arch: entry.arch,
            d_model: entry.d_model,
            base,
            experts: Vec::new(),
            expert_map,
            aligns,
        })
    }

    /// Demand-fetch ONE expert's residual shard (by stored-expert index,
    /// i.e. `expert_map[slot]`).
    pub fn load_expert(&self, block: usize, expert_idx: usize) -> Result<CompressedExpert> {
        let entry = self
            .layer_entry(block)
            .ok_or_else(|| anyhow!("no stored layer for block {block}"))?;
        let info = entry
            .experts
            .get(expert_idx)
            .ok_or_else(|| anyhow!("block {block}: no expert shard {expert_idx}"))?;
        let raw = self.fetch_shard(
            &info.shard,
            &format!("block {block} expert {expert_idx}"),
            "store.read",
            block as i64,
            expert_idx as i64,
        )?;
        CompressedExpert::decode_shard(&raw)
            .with_context(|| format!("block {block} expert {expert_idx}: bad shard payload"))
    }

    /// Load a full [`CompressedLayer`] (skeleton + every expert) — the
    /// offline path used by tests and round-trip tooling; serving never
    /// needs it.
    pub fn load_layer_full(&self, block: usize) -> Result<CompressedLayer> {
        let mut layer = self.load_layer_skeleton(block)?;
        let n = self.layer_entry(block).expect("entry exists").experts.len();
        layer.experts = (0..n)
            .map(|i| self.load_expert(block, i))
            .collect::<Result<Vec<_>>>()?;
        Ok(layer)
    }
}

fn parse_index(src: &str, file_bytes: u64) -> Result<StoreIndex> {
    let j = Json::parse(src).map_err(|e| anyhow!("index json: {e}"))?;
    let version = j
        .get("version")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow!("index missing version"))? as u32;
    let config = ModelConfig::from_json(
        j.get("config").ok_or_else(|| anyhow!("index missing config"))?,
    )?;
    let backbone =
        ShardInfo::from_json(j.get("backbone").ok_or_else(|| anyhow!("index missing backbone"))?)?;
    let mut layers = Vec::new();
    for lj in j
        .get("layers")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("index missing layers"))?
    {
        let usize_field = |k: &str| {
            lj.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("layer entry missing '{k}'"))
        };
        let arch_name = lj
            .get("arch")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("layer entry missing arch"))?;
        let center = match lj.get("center") {
            None | Some(Json::Null) => None,
            Some(c) => Some(ShardInfo::from_json(c)?),
        };
        let mut experts = Vec::new();
        for ej in lj
            .get("experts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("layer entry missing experts"))?
        {
            experts.push(ExpertShardInfo {
                shard: ShardInfo::from_json(ej)?,
                kind: ej
                    .get("kind")
                    .and_then(|v| v.as_str())
                    .unwrap_or("unknown")
                    .to_string(),
                // v1 indices have no qerr field: exact f32 shards, bound 0.
                quant_err: ej.get("qerr").and_then(|v| v.as_f64()).unwrap_or(0.0) as f32,
            });
        }
        let entry = LayerEntry {
            block: usize_field("block")?,
            method: lj
                .get("method")
                .and_then(|v| v.as_str())
                .unwrap_or("unknown")
                .to_string(),
            arch: ExpertArch::from_name(arch_name)
                .ok_or_else(|| anyhow!("bad arch '{arch_name}'"))?,
            d_model: usize_field("d_model")?,
            rate: lj.get("rate").and_then(|v| v.as_f64()).unwrap_or(0.0),
            design_rows: usize_field("design_rows")?,
            design_cols: usize_field("design_cols")?,
            center,
            meta: ShardInfo::from_json(
                lj.get("meta").ok_or_else(|| anyhow!("layer entry missing meta"))?,
            )?,
            experts,
        };
        for info in entry
            .experts
            .iter()
            .map(|e| &e.shard)
            .chain(entry.center.iter())
            .chain(std::iter::once(&entry.meta))
        {
            if info
                .offset
                .checked_add(info.bytes)
                .map_or(true, |end| end > file_bytes)
            {
                bail!("block {}: shard beyond file end", entry.block);
            }
        }
        layers.push(entry);
    }
    Ok(StoreIndex { version, config, backbone, layers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::compress::ResMoE;
    use crate::moe::{ExpertArch, MoeLayer};
    use crate::util::Rng;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("resmoe-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn tiny_model() -> Model {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        let mut rng = Rng::new(1);
        Model::random(&cfg, &mut rng)
    }

    fn write_store(path: &Path, seed: u64) -> (Model, Vec<(usize, CompressedLayer)>) {
        let model = tiny_model();
        let mut rng = Rng::new(seed);
        let layer = MoeLayer::random(ExpertArch::Relu, 16, 32, 4, 1, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &layer, 0.3, seed);
        let cl_svd = quick_compress(&ResMoE::svd(), &layer, 0.3, seed + 1);
        let mut w = StoreWriter::create(path).unwrap();
        w.put_backbone(&model.clone().strip_experts(&[1])).unwrap();
        w.put_layer(1, &cl, 0.3).unwrap();
        w.put_layer(3, &cl_svd, 0.3).unwrap();
        w.finish().unwrap();
        (model, vec![(1, cl), (3, cl_svd)])
    }

    #[test]
    fn roundtrips_layers_and_backbone() {
        let path = tmp("roundtrip.rmes");
        let (model, layers) = write_store(&path, 5);
        let store = ExpertStore::open(&path).unwrap();
        assert_eq!(store.blocks(), vec![1, 3]);
        assert_eq!(store.config().name, model.cfg.name);
        let backbone = store.load_backbone().unwrap();
        assert!(backbone.n_params() < model.n_params());
        for (block, want) in &layers {
            let got = store.load_layer_full(*block).unwrap();
            assert_eq!(&got, want, "block {block} must round-trip bit-exactly");
        }
    }

    #[test]
    fn single_expert_fetch_reads_only_its_shard() {
        let path = tmp("paged.rmes");
        let (_, layers) = write_store(&path, 6);
        let store = ExpertStore::open(&path).unwrap();
        let before = store.bytes_read();
        let e = store.load_expert(1, 2).unwrap();
        assert_eq!(e, layers[0].1.experts[2]);
        let read = store.bytes_read() - before;
        let entry = store.layer_entry(1).unwrap();
        assert_eq!(read, entry.experts[2].shard.bytes, "exactly one shard read");
        assert!(read < store.file_bytes() / 4, "single fetch must not scan the file");
    }

    #[test]
    fn corrupt_shard_is_rejected_with_checksum_error() {
        let path = tmp("corrupt.rmes");
        let (_, _) = write_store(&path, 7);
        let store = ExpertStore::open(&path).unwrap();
        let info = store.layer_entry(1).unwrap().experts[1].shard.clone();
        drop(store);
        // Flip one bit in the middle of expert (1,1)'s shard.
        let mut bytes = std::fs::read(&path).unwrap();
        let pos = (info.offset + info.bytes / 2) as usize;
        bytes[pos] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let store = ExpertStore::open(&path).unwrap();
        let err = store.load_expert(1, 1).unwrap_err().to_string();
        assert!(err.contains("checksum"), "err: {err}");
        // Untouched shards still load.
        assert!(store.load_expert(1, 0).is_ok());
        assert!(store.load_expert(3, 1).is_ok());
    }

    #[test]
    fn truncated_file_is_rejected() {
        let path = tmp("trunc.rmes");
        write_store(&path, 8);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 40]).unwrap();
        assert!(ExpertStore::open(&path).is_err());
        std::fs::write(&path, b"RMES").unwrap();
        assert!(ExpertStore::open(&path).is_err());
        std::fs::write(&path, b"NOPE1234").unwrap();
        assert!(ExpertStore::open(&path).is_err());
    }

    /// Clone of a compressed layer with every residual dropped to int8.
    fn quantize_cl(cl: &CompressedLayer) -> CompressedLayer {
        let experts = cl
            .experts
            .iter()
            .map(|e| CompressedExpert {
                residual: e.residual.quantized(),
                b2: e.b2.clone(),
                accounted_params: e.accounted_params,
            })
            .collect();
        CompressedLayer { experts, ..cl.clone() }
    }

    /// Rewrite a freshly-written v2 file as a v1 file: header version word
    /// plus the index's top-level `"version":2` (last occurrence — the
    /// sorted top-level object puts it at the very end of the file).
    fn patch_to_v1(path: &Path, patch_index: bool) {
        let mut bytes = std::fs::read(path).unwrap();
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        if patch_index {
            let pat = b"\"version\":2";
            let pos = bytes
                .windows(pat.len())
                .rposition(|w| w == pat)
                .expect("index version field");
            bytes[pos + pat.len() - 1] = b'1';
        }
        std::fs::write(path, &bytes).unwrap();
    }

    #[test]
    fn v1_files_read_back_cleanly() {
        let path = tmp("v1-readback.rmes");
        let (_, layers) = write_store(&path, 40);
        patch_to_v1(&path, true);
        let store = ExpertStore::open(&path).unwrap();
        assert_eq!(store.index().version, 1);
        for (block, want) in &layers {
            assert_eq!(
                &store.load_layer_full(*block).unwrap(),
                want,
                "block {block} must round-trip from a v1 container"
            );
        }
    }

    #[test]
    fn header_index_version_mismatch_is_rejected() {
        let path = tmp("vmismatch.rmes");
        write_store(&path, 43);
        patch_to_v1(&path, false); // header says 1, index still says 2
        let err = ExpertStore::open(&path).unwrap_err().to_string();
        assert!(err.contains("disagrees"), "err: {err}");
    }

    #[test]
    fn unknown_future_version_is_rejected() {
        let path = tmp("vfuture.rmes");
        write_store(&path, 44);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&(STORE_VERSION + 1).to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = ExpertStore::open(&path).unwrap_err().to_string();
        assert!(err.contains("unsupported store version"), "err: {err}");
    }

    #[test]
    fn quantized_shards_roundtrip_with_qerr_in_index() {
        let path = tmp("quant.rmes");
        let model = tiny_model();
        let mut rng = Rng::new(41);
        let layer = MoeLayer::random(ExpertArch::Relu, 16, 32, 4, 1, true, false, &mut rng);
        let cl = quantize_cl(&quick_compress(&ResMoE::up(), &layer, 0.3, 41));
        let cl_svd = quantize_cl(&quick_compress(&ResMoE::svd(), &layer, 0.3, 42));
        let mut w = StoreWriter::create(&path).unwrap();
        w.put_backbone(&model.clone().strip_experts(&[1])).unwrap();
        w.put_layer(1, &cl, 0.3).unwrap();
        w.put_layer(3, &cl_svd, 0.3).unwrap();
        w.finish().unwrap();
        let store = ExpertStore::open(&path).unwrap();
        assert_eq!(store.index().version, STORE_VERSION);
        let entry = store.layer_entry(1).unwrap();
        assert!(
            entry.experts.iter().all(|e| e.kind == "q8-csr" && e.quant_err > 0.0),
            "index must carry the quantized kind + error bound"
        );
        assert!(store
            .layer_entry(3)
            .unwrap()
            .experts
            .iter()
            .all(|e| e.kind == "q8-svd" && e.quant_err > 0.0));
        assert_eq!(&store.load_layer_full(1).unwrap(), &cl);
        assert_eq!(&store.load_layer_full(3).unwrap(), &cl_svd);
        // Corruption policy applies to quantized shards identically.
        let info = entry.experts[0].shard.clone();
        drop(store);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[(info.offset + info.bytes / 2) as usize] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let store = ExpertStore::open(&path).unwrap();
        let err = store.load_expert(1, 0).unwrap_err().to_string();
        assert!(err.contains("checksum"), "err: {err}");
    }

    #[test]
    fn v1_claiming_quantized_kinds_is_rejected() {
        let path = tmp("v1-quant.rmes");
        let model = tiny_model();
        let mut rng = Rng::new(45);
        let layer = MoeLayer::random(ExpertArch::Relu, 16, 32, 4, 1, true, false, &mut rng);
        let cl = quantize_cl(&quick_compress(&ResMoE::up(), &layer, 0.3, 45));
        let mut w = StoreWriter::create(&path).unwrap();
        w.put_backbone(&model.clone().strip_experts(&[1])).unwrap();
        w.put_layer(1, &cl, 0.3).unwrap();
        w.finish().unwrap();
        patch_to_v1(&path, true);
        let err = ExpertStore::open(&path).unwrap_err().to_string();
        assert!(err.contains("quantized shard kind"), "err: {err}");
    }

    #[test]
    fn writer_rejects_misuse() {
        let path = tmp("misuse.rmes");
        let model = tiny_model();
        let mut rng = Rng::new(9);
        let layer = MoeLayer::random(ExpertArch::Relu, 16, 32, 4, 1, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &layer, 0.3, 9);
        let mut w = StoreWriter::create(&path).unwrap();
        assert!(w.put_backbone(&model).is_ok());
        assert!(w.put_backbone(&model).is_err(), "double backbone");
        assert!(w.put_layer(1, &cl, 0.3).is_ok());
        assert!(w.put_layer(1, &cl, 0.3).is_err(), "duplicate block");
        w.finish().unwrap();
        // Missing backbone fails at finish.
        let path2 = tmp("misuse2.rmes");
        let w2 = StoreWriter::create(&path2).unwrap();
        assert!(w2.finish().is_err());
    }
}
