//! Packing: turn a model + compressed layers (or an RMW1/RMWZ checkpoint)
//! into an `RMES` artifact — the offline half of demand-paged serving.

use super::format::{ExpertStore, StoreWriter};
use crate::compress::{
    compress_model, CompressedExpert, CompressedLayer, CompressionReport, Compressor,
};
use crate::moe::model_io::load_model;
use crate::moe::Model;
use crate::util::Rng;
use anyhow::Result;
use std::path::{Path, PathBuf};

/// How residual shards are stored by a pack (`--quantize` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QuantizeMode {
    /// Exact f32 shards (v1-compatible payloads).
    #[default]
    None,
    /// Int8 symmetric per-row quantization of residual values; barycenter,
    /// biases, and singular values stay f32.
    Int8,
}

impl QuantizeMode {
    /// Parse a `--quantize` CLI value.
    pub fn parse(s: &str) -> Option<QuantizeMode> {
        match s {
            "none" | "f32" => Some(QuantizeMode::None),
            "int8" | "q8" => Some(QuantizeMode::Int8),
            _ => None,
        }
    }
}

/// What a pack produced, read back from the finished artifact's index (so
/// the summary doubles as an open/validate pass).
#[derive(Debug, Clone)]
pub struct PackSummary {
    pub path: PathBuf,
    pub file_bytes: u64,
    pub n_layers: usize,
    pub n_expert_shards: usize,
    /// On-disk (compressed) bytes of all residual shards.
    pub expert_disk_bytes: u64,
    /// Decoded bytes of all residual shards (the full-resident cache cost).
    pub expert_raw_bytes: u64,
    /// On-disk bytes of the expert-stripped backbone shard.
    pub backbone_disk_bytes: u64,
    /// Residual shards stored in the int8 tier (`q8-*` kinds).
    pub quantized_shards: usize,
    /// Largest advertised per-element dequantization error bound across
    /// all residual shards (0.0 when nothing is quantized).
    pub max_quant_err: f32,
}

/// Open a finished artifact and summarize its index.
pub fn summarize(path: &Path) -> Result<PackSummary> {
    let store = ExpertStore::open(path)?;
    let idx = store.index();
    Ok(PackSummary {
        path: path.to_path_buf(),
        file_bytes: store.file_bytes(),
        n_layers: idx.layers.len(),
        n_expert_shards: idx.layers.iter().map(|l| l.experts.len()).sum(),
        expert_disk_bytes: idx
            .layers
            .iter()
            .flat_map(|l| l.experts.iter())
            .map(|e| e.shard.bytes)
            .sum(),
        expert_raw_bytes: store.total_expert_raw_bytes(),
        backbone_disk_bytes: idx.backbone.bytes,
        quantized_shards: idx
            .layers
            .iter()
            .flat_map(|l| l.experts.iter())
            .filter(|e| e.kind.starts_with("q8-"))
            .count(),
        max_quant_err: idx
            .layers
            .iter()
            .flat_map(|l| l.experts.iter())
            .map(|e| e.quant_err)
            .fold(0.0f32, f32::max),
    })
}

/// Clone of a compressed layer with every residual dropped to the int8
/// tier (idempotent; center and biases untouched).
pub fn quantize_layer(cl: &CompressedLayer) -> CompressedLayer {
    let experts = cl
        .experts
        .iter()
        .map(|e| CompressedExpert {
            residual: e.residual.quantized(),
            b2: e.b2.clone(),
            accounted_params: e.accounted_params,
        })
        .collect();
    CompressedLayer { experts, ..cl.clone() }
}

/// Pack an already-compressed model: backbone = `model` with the compressed
/// blocks' experts stripped; one center/meta/residual shard set per layer.
pub fn pack_compressed_model(
    model: &Model,
    layers: &[(usize, CompressedLayer)],
    rate: f64,
    out: &Path,
) -> Result<PackSummary> {
    pack_compressed_model_with(model, layers, rate, QuantizeMode::None, out)
}

/// [`pack_compressed_model`] with a residual quantization mode.
pub fn pack_compressed_model_with(
    model: &Model,
    layers: &[(usize, CompressedLayer)],
    rate: f64,
    quantize: QuantizeMode,
    out: &Path,
) -> Result<PackSummary> {
    let blocks: Vec<usize> = layers.iter().map(|(b, _)| *b).collect();
    let backbone = model.clone().strip_experts(&blocks);
    let quantized: Vec<(usize, CompressedLayer)>;
    let layers: &[(usize, CompressedLayer)] = match quantize {
        QuantizeMode::None => layers,
        QuantizeMode::Int8 => {
            quantized = layers.iter().map(|(b, cl)| (*b, quantize_layer(cl))).collect();
            &quantized
        }
    };
    let mut w = StoreWriter::create(out)?;
    w.put_backbone(&backbone)?;
    for (block, cl) in layers {
        w.put_layer(*block, cl, rate)?;
    }
    w.finish()?;
    summarize(out)
}

/// The checkpoint converter: load an RMW1/RMWZ checkpoint, compress its top
/// MoE layers with `comp` at retention `rate`, and pack the result.
pub fn pack_checkpoint(
    ckpt: &Path,
    comp: &dyn Compressor,
    rate: f64,
    top_layers: usize,
    calib: Option<&[u32]>,
    seed: u64,
    out: &Path,
) -> Result<(PackSummary, CompressionReport)> {
    pack_checkpoint_with(ckpt, comp, rate, top_layers, calib, seed, QuantizeMode::None, out)
}

/// [`pack_checkpoint`] with a residual quantization mode.
#[allow(clippy::too_many_arguments)]
pub fn pack_checkpoint_with(
    ckpt: &Path,
    comp: &dyn Compressor,
    rate: f64,
    top_layers: usize,
    calib: Option<&[u32]>,
    seed: u64,
    quantize: QuantizeMode,
    out: &Path,
) -> Result<(PackSummary, CompressionReport)> {
    let model = load_model(ckpt)?;
    pack_model_with(&model, comp, rate, top_layers, calib, seed, quantize, out)
}

/// [`pack_checkpoint`] for a model already in memory.
pub fn pack_model(
    model: &Model,
    comp: &dyn Compressor,
    rate: f64,
    top_layers: usize,
    calib: Option<&[u32]>,
    seed: u64,
    out: &Path,
) -> Result<(PackSummary, CompressionReport)> {
    pack_model_with(model, comp, rate, top_layers, calib, seed, QuantizeMode::None, out)
}

/// [`pack_model`] with a residual quantization mode. Compression (and its
/// report) run on f32 residuals; quantization happens at shard-write time,
/// so the recorded approximation errors are the f32 method's and `qerr`
/// carries the additional int8 bound per shard.
#[allow(clippy::too_many_arguments)]
pub fn pack_model_with(
    model: &Model,
    comp: &dyn Compressor,
    rate: f64,
    top_layers: usize,
    calib: Option<&[u32]>,
    seed: u64,
    quantize: QuantizeMode,
    out: &Path,
) -> Result<(PackSummary, CompressionReport)> {
    let mut rng = Rng::new(seed);
    let cm = compress_model(model, comp, rate, top_layers, calib, &mut rng);
    let summary = pack_compressed_model_with(model, &cm.layers, rate, quantize, out)?;
    Ok((summary, cm.report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::ResMoE;
    use crate::moe::model_io::save_model_compressed;
    use crate::moe::ModelConfig;

    fn tiny_model(seed: u64) -> Model {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 4;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        Model::random(&cfg, &mut rng)
    }

    #[test]
    fn quantized_pack_shrinks_and_carries_error_bounds() {
        use crate::compress::ResidualRepr;
        use crate::moe::{ExpertArch, MoeLayer};
        use crate::tensor::Matrix;
        let dir = std::env::temp_dir().join("resmoe-pack-test");
        std::fs::create_dir_all(&dir).unwrap();
        let out_f = dir.join("dense-f32.rmes");
        let out_q = dir.join("dense-int8.rmes");
        let model = tiny_model(5);
        // Dense residual layer (base + dense Δ): the config the 0.35× byte
        // criterion is defined over (CSR keeps full index overhead and only
        // reaches ~0.55×; SVD depends on rank — both still shrink).
        let mut rng = Rng::new(6);
        let layer = MoeLayer::random(ExpertArch::Relu, 16, 32, 4, 1, true, false, &mut rng);
        let dms: Vec<Matrix> = layer.experts.iter().map(|e| e.design_matrix()).collect();
        let base = Matrix::mean_of(&dms.iter().collect::<Vec<_>>());
        let experts: Vec<CompressedExpert> = layer
            .experts
            .iter()
            .zip(&dms)
            .map(|(e, dm)| {
                let resid = dm.sub(&base);
                CompressedExpert {
                    accounted_params: resid.n_params(),
                    residual: ResidualRepr::Dense(resid),
                    b2: e.b2.clone(),
                }
            })
            .collect();
        let cl = CompressedLayer {
            method: "avg+dense".into(),
            arch: ExpertArch::Relu,
            d_model: 16,
            base: Some(base),
            experts,
            expert_map: CompressedLayer::identity_map(4),
            aligns: CompressedLayer::identity_aligns(4, 32),
        };
        let s_f =
            pack_compressed_model(&model, &[(1, cl.clone())], 0.25, &out_f).unwrap();
        let s_q = pack_compressed_model_with(
            &model,
            &[(1, cl.clone())],
            0.25,
            QuantizeMode::Int8,
            &out_q,
        )
        .unwrap();
        assert_eq!(s_f.quantized_shards, 0);
        assert_eq!(s_f.max_quant_err, 0.0);
        assert_eq!(s_q.quantized_shards, s_q.n_expert_shards);
        assert!(s_q.max_quant_err > 0.0, "quantized pack must advertise a bound");
        assert!(
            (s_q.expert_raw_bytes as f64) <= 0.35 * s_f.expert_raw_bytes as f64,
            "int8 shards {} vs f32 {} exceed 0.35×",
            s_q.expert_raw_bytes,
            s_f.expert_raw_bytes
        );
        // The artifact loads back exactly the quantized clone, within the
        // advertised bound of the f32 residual.
        let store = ExpertStore::open(&out_q).unwrap();
        let got = store.load_layer_full(1).unwrap();
        assert_eq!(got, quantize_layer(&cl));
        for (qe, fe) in got.experts.iter().zip(&cl.experts) {
            assert!(qe.is_quantized());
            let bound = qe.quant_error_bound();
            let a = fe.residual.to_dense();
            let b = qe.residual.to_dense();
            let worst = a
                .data
                .iter()
                .zip(&b.data)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(worst <= bound, "worst {worst} > advertised {bound}");
        }
        // Idempotence: packing an already-quantized layer with Int8 mode
        // changes nothing.
        let out_q2 = dir.join("dense-int8-again.rmes");
        let s_q2 = pack_compressed_model_with(
            &model,
            &[(1, quantize_layer(&cl))],
            0.25,
            QuantizeMode::Int8,
            &out_q2,
        )
        .unwrap();
        assert_eq!(s_q2.expert_raw_bytes, s_q.expert_raw_bytes);
    }

    #[test]
    fn checkpoint_to_artifact_roundtrip() {
        let dir = std::env::temp_dir().join("resmoe-pack-test");
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = dir.join("in.rmwz");
        let out = dir.join("out.rmes");
        let model = tiny_model(3);
        save_model_compressed(&model, &ckpt, 3).unwrap();
        let (summary, report) =
            pack_checkpoint(&ckpt, &ResMoE::up(), 0.25, 2, None, 0, &out).unwrap();
        assert_eq!(summary.n_layers, 2);
        assert_eq!(summary.n_expert_shards, 8); // 4 experts × 2 layers
        assert_eq!(report.layers.len(), 2);
        assert!(summary.expert_disk_bytes > 0);
        assert!(summary.expert_raw_bytes >= summary.expert_disk_bytes / 4);
        // The artifact opens and its stored layers match a fresh compression
        // with the same seed (pack must be deterministic given the seed).
        let store = ExpertStore::open(&out).unwrap();
        let mut rng = Rng::new(0);
        let cm = compress_model(&model, &ResMoE::up(), 0.25, 2, None, &mut rng);
        for (block, cl) in &cm.layers {
            assert_eq!(&store.load_layer_full(*block).unwrap(), cl);
        }
        // Backbone kept routers but no experts on compressed blocks.
        let backbone = store.load_backbone().unwrap();
        assert!(backbone.n_params() < model.n_params());
    }
}
