//! Packing: turn a model + compressed layers (or an RMW1/RMWZ checkpoint)
//! into an `RMES` artifact — the offline half of demand-paged serving.

use super::format::{ExpertStore, StoreWriter};
use crate::compress::{compress_model, CompressedLayer, CompressionReport, Compressor};
use crate::moe::model_io::load_model;
use crate::moe::Model;
use crate::util::Rng;
use anyhow::Result;
use std::path::{Path, PathBuf};

/// What a pack produced, read back from the finished artifact's index (so
/// the summary doubles as an open/validate pass).
#[derive(Debug, Clone)]
pub struct PackSummary {
    pub path: PathBuf,
    pub file_bytes: u64,
    pub n_layers: usize,
    pub n_expert_shards: usize,
    /// On-disk (compressed) bytes of all residual shards.
    pub expert_disk_bytes: u64,
    /// Decoded bytes of all residual shards (the full-resident cache cost).
    pub expert_raw_bytes: u64,
    /// On-disk bytes of the expert-stripped backbone shard.
    pub backbone_disk_bytes: u64,
}

/// Open a finished artifact and summarize its index.
pub fn summarize(path: &Path) -> Result<PackSummary> {
    let store = ExpertStore::open(path)?;
    let idx = store.index();
    Ok(PackSummary {
        path: path.to_path_buf(),
        file_bytes: store.file_bytes(),
        n_layers: idx.layers.len(),
        n_expert_shards: idx.layers.iter().map(|l| l.experts.len()).sum(),
        expert_disk_bytes: idx
            .layers
            .iter()
            .flat_map(|l| l.experts.iter())
            .map(|e| e.shard.bytes)
            .sum(),
        expert_raw_bytes: store.total_expert_raw_bytes(),
        backbone_disk_bytes: idx.backbone.bytes,
    })
}

/// Pack an already-compressed model: backbone = `model` with the compressed
/// blocks' experts stripped; one center/meta/residual shard set per layer.
pub fn pack_compressed_model(
    model: &Model,
    layers: &[(usize, CompressedLayer)],
    rate: f64,
    out: &Path,
) -> Result<PackSummary> {
    let blocks: Vec<usize> = layers.iter().map(|(b, _)| *b).collect();
    let backbone = model.clone().strip_experts(&blocks);
    let mut w = StoreWriter::create(out)?;
    w.put_backbone(&backbone)?;
    for (block, cl) in layers {
        w.put_layer(*block, cl, rate)?;
    }
    w.finish()?;
    summarize(out)
}

/// The checkpoint converter: load an RMW1/RMWZ checkpoint, compress its top
/// MoE layers with `comp` at retention `rate`, and pack the result.
pub fn pack_checkpoint(
    ckpt: &Path,
    comp: &dyn Compressor,
    rate: f64,
    top_layers: usize,
    calib: Option<&[u32]>,
    seed: u64,
    out: &Path,
) -> Result<(PackSummary, CompressionReport)> {
    let model = load_model(ckpt)?;
    pack_model(&model, comp, rate, top_layers, calib, seed, out)
}

/// [`pack_checkpoint`] for a model already in memory.
pub fn pack_model(
    model: &Model,
    comp: &dyn Compressor,
    rate: f64,
    top_layers: usize,
    calib: Option<&[u32]>,
    seed: u64,
    out: &Path,
) -> Result<(PackSummary, CompressionReport)> {
    let mut rng = Rng::new(seed);
    let cm = compress_model(model, comp, rate, top_layers, calib, &mut rng);
    let summary = pack_compressed_model(model, &cm.layers, rate, out)?;
    Ok((summary, cm.report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::ResMoE;
    use crate::moe::model_io::save_model_compressed;
    use crate::moe::ModelConfig;

    fn tiny_model(seed: u64) -> Model {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 4;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        Model::random(&cfg, &mut rng)
    }

    #[test]
    fn checkpoint_to_artifact_roundtrip() {
        let dir = std::env::temp_dir().join("resmoe-pack-test");
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = dir.join("in.rmwz");
        let out = dir.join("out.rmes");
        let model = tiny_model(3);
        save_model_compressed(&model, &ckpt, 3).unwrap();
        let (summary, report) =
            pack_checkpoint(&ckpt, &ResMoE::up(), 0.25, 2, None, 0, &out).unwrap();
        assert_eq!(summary.n_layers, 2);
        assert_eq!(summary.n_expert_shards, 8); // 4 experts × 2 layers
        assert_eq!(report.layers.len(), 2);
        assert!(summary.expert_disk_bytes > 0);
        assert!(summary.expert_raw_bytes >= summary.expert_disk_bytes / 4);
        // The artifact opens and its stored layers match a fresh compression
        // with the same seed (pack must be deterministic given the seed).
        let store = ExpertStore::open(&out).unwrap();
        let mut rng = Rng::new(0);
        let cm = compress_model(&model, &ResMoE::up(), 0.25, 2, None, &mut rng);
        for (block, cl) in &cm.layers {
            assert_eq!(&store.load_layer_full(*block).unwrap(), cl);
        }
        // Backbone kept routers but no experts on compressed blocks.
        let backbone = store.load_backbone().unwrap();
        assert!(backbone.n_params() < model.n_params());
    }
}
