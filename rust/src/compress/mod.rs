//! Compression framework: the [`Compressor`] trait every method implements
//! (ResMoE and all baselines), compressed-layer formats/restoration, and
//! whole-model application on the top MoE layers (the paper's protocol:
//! top 24 of Mixtral's 32 layers, top 8 MoE layers of Switch).

pub mod adaptive;
pub mod formats;
pub mod parallel;
pub mod prune;
pub mod resmoe;
pub mod svd_compress;
pub mod wanda;

pub use formats::{
    center_shared_act, decode_matrix_shard, encode_matrix_shard, fused_forward_expert,
    CompressedExpert, CompressedLayer, FusedExpert, FusedLayer, FusedPiece, FusedSlot,
    QuantizedRepr, ResidualRepr, SharedAct,
};
pub use resmoe::{CenterKind, ResMoE, ResidualKind};

use crate::moe::{Ffn, Model, MoeLayer, RouterStats};
use crate::tensor::Matrix;
use crate::util::Rng;

/// Shared inputs for a layer compression.
pub struct CompressCtx<'a> {
    /// Fraction of expert parameters RETAINED (the paper's main setting is
    /// 0.25 — a 75 % reduction).
    pub rate: f64,
    pub rng: &'a mut Rng,
    /// Layer-input calibration activations (B × p) for data-dependent
    /// methods (Wanda; M-SMoE's activation statistics).
    pub calib: Option<&'a Matrix>,
    /// Router usage statistics for routing-aware baselines (expert pruning,
    /// M-SMoE grouping).
    pub stats: Option<&'a RouterStats>,
}

impl<'a> CompressCtx<'a> {
    pub fn new(rate: f64, rng: &'a mut Rng) -> CompressCtx<'a> {
        CompressCtx { rate, rng, calib: None, stats: None }
    }
}

/// A one-shot, layer-local MoE compression method.
pub trait Compressor {
    fn name(&self) -> String;
    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer;
}

/// Per-layer outcome of a model compression.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub block: usize,
    pub approx_error: f64,
    pub params_before: usize,
    pub params_after: usize,
    pub bytes_before: usize,
    pub bytes_after: usize,
}

/// Whole-model compression result.
pub struct CompressedModel {
    /// The model with compressed layers *restored* in place (offline-eval
    /// path; identical function to lazy restoration).
    pub model: Model,
    /// The compressed representations, for serving / memory accounting.
    pub layers: Vec<(usize, CompressedLayer)>,
    pub report: CompressionReport,
}

#[derive(Debug, Clone)]
pub struct CompressionReport {
    pub method: String,
    pub rate: f64,
    pub layers: Vec<LayerReport>,
}

impl CompressionReport {
    /// Mean Table-1 approximation error across compressed layers.
    pub fn mean_approx_error(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.approx_error).sum::<f64>() / self.layers.len() as f64
    }

    pub fn total_params_before(&self) -> usize {
        self.layers.iter().map(|l| l.params_before).sum()
    }

    pub fn total_params_after(&self) -> usize {
        self.layers.iter().map(|l| l.params_after).sum()
    }

    pub fn total_bytes_after(&self) -> usize {
        self.layers.iter().map(|l| l.bytes_after).sum()
    }

    pub fn total_bytes_before(&self) -> usize {
        self.layers.iter().map(|l| l.bytes_before).sum()
    }
}

/// Compress the **top `top_layers` MoE blocks** of `model` with `comp` at
/// retention `rate`, following the paper's protocol. `calib_tokens`
/// provides the calibration sequence for data-dependent methods.
pub fn compress_model(
    model: &Model,
    comp: &dyn Compressor,
    rate: f64,
    top_layers: usize,
    calib_tokens: Option<&[u32]>,
    rng: &mut Rng,
) -> CompressedModel {
    let moe_blocks = model.moe_blocks();
    let selected: Vec<usize> = moe_blocks
        .iter()
        .copied()
        .rev()
        .take(top_layers)
        .collect::<Vec<_>>()
        .into_iter()
        .rev()
        .collect();
    // Calibration pass (once) for Wanda / stats-aware methods.
    let (ffn_inputs, stats) = match calib_tokens {
        Some(tokens) => {
            let inputs = model.collect_ffn_inputs(tokens);
            let mut st = model.fresh_stats();
            model.hidden_states(tokens, Some(&mut st));
            (Some(inputs), Some(st))
        }
        None => (None, None),
    };
    let mut out = model.clone();
    let mut layers = Vec::new();
    let mut reports = Vec::new();
    for &bi in &selected {
        let Ffn::Moe(layer) = &model.blocks[bi].ffn else {
            continue;
        };
        let mut ctx = CompressCtx::new(rate, rng);
        let calib_mat = ffn_inputs.as_ref().map(|v| &v[bi]);
        ctx.calib = calib_mat;
        let block_stats = stats.as_ref().map(|s| &s[bi]);
        ctx.stats = block_stats;
        let cl = comp.compress(layer, &mut ctx);
        let params_before = layer.expert_params();
        let bytes_before = params_before * 4;
        reports.push(LayerReport {
            block: bi,
            approx_error: cl.approx_error(layer),
            params_before,
            params_after: cl.n_params_stored(),
            bytes_before,
            bytes_after: cl.memory_bytes(),
        });
        out.blocks[bi].ffn = Ffn::Moe(cl.to_layer(layer));
        layers.push((bi, cl));
    }
    CompressedModel {
        model: out,
        layers,
        report: CompressionReport { method: comp.name(), rate, layers: reports },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ModelConfig;

    fn tiny_model(seed: u64) -> (Model, Rng) {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 4;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        let m = Model::random(&cfg, &mut rng);
        (m, rng)
    }

    #[test]
    fn compresses_only_top_moe_layers() {
        let (m, mut rng) = tiny_model(1);
        // moe blocks are 1 and 3; top 1 → block 3 only.
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        assert_eq!(cm.layers.len(), 1);
        assert_eq!(cm.layers[0].0, 3);
        // Block 1 untouched.
        let Ffn::Moe(orig) = &m.blocks[1].ffn else { panic!() };
        let Ffn::Moe(new) = &cm.model.blocks[1].ffn else { panic!() };
        assert_eq!(orig.experts[0].w1, new.experts[0].w1);
    }

    #[test]
    fn report_params_consistent() {
        let (m, mut rng) = tiny_model(2);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        assert_eq!(cm.report.layers.len(), 2);
        assert!(cm.report.total_params_after() < cm.report.total_params_before());
        assert!(cm.report.mean_approx_error() > 0.0);
        assert!(cm.report.total_bytes_after() < cm.report.total_bytes_before());
    }

    #[test]
    fn model_still_functions_after_compression() {
        let (m, mut rng) = tiny_model(3);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        let tokens: Vec<u32> = (0..16).map(|i| i % 32).collect();
        let logits = cm.model.forward(&tokens);
        assert!(logits.data.iter().all(|v| v.is_finite()));
        // Output differs from the original (lossy) but not wildly.
        let orig = m.forward(&tokens);
        let rel = logits.sq_dist(&orig) / orig.frob_norm_sq();
        assert!(rel > 0.0 && rel < 1.0, "rel={rel}");
    }

    #[test]
    fn calibration_tokens_flow_to_compressor() {
        let (m, mut rng) = tiny_model(4);
        let calib: Vec<u32> = (0..24).map(|i| (i * 7) % 32).collect();
        let cm = compress_model(&m, &wanda::Wanda, 0.25, 2, Some(&calib), &mut rng);
        assert_eq!(cm.layers.len(), 2);
        assert!(cm.report.mean_approx_error().is_finite());
    }
}
