//! Wanda pruning (Sun et al. 2024): score `|W_ij| · ||X_j||` where `||X_j||`
//! is the L2 norm of input feature `j` over a calibration batch, pruning
//! per output row. The paper runs Wanda on C4 in the zero-shot setting; we
//! use a synthetic-corpus calibration batch (same code path).

use super::formats::{CompressedExpert, CompressedLayer, ResidualRepr};
use super::{CompressCtx, Compressor};
use crate::moe::{ExpertArch, MoeLayer};
use crate::tensor::{sparse::IndexWidth, Csr, Matrix};

/// Column L2 norms over a batch of activations (B × d) → d norms.
fn feature_norms(x: &Matrix) -> Vec<f32> {
    let mut out = vec![0.0f64; x.cols];
    for r in 0..x.rows {
        for (c, &v) in x.row(r).iter().enumerate() {
            out[c] += (v as f64) * (v as f64);
        }
    }
    out.iter().map(|v| v.sqrt() as f32).collect()
}

/// Wanda-prune one matrix `W` (out × in) with feature norms `xn` (len in),
/// keeping `keep_per_row` entries per output row.
pub fn wanda_prune(w: &Matrix, xn: &[f32], keep_per_row: usize) -> Matrix {
    assert_eq!(w.cols, xn.len());
    let mut out = w.clone();
    for r in 0..w.rows {
        let row = w.row(r);
        let mut idx: Vec<usize> = (0..w.cols).collect();
        idx.sort_by(|&a, &b| {
            let sa = row[a].abs() * xn[a];
            let sb = row[b].abs() * xn[b];
            sb.partial_cmp(&sa).unwrap()
        });
        let dst = out.row_mut(r);
        for &c in idx.iter().skip(keep_per_row) {
            dst[c] = 0.0;
        }
    }
    out
}

/// The Wanda baseline compressor. Requires `ctx.calib` (layer-input
/// activations); falls back to plain magnitude pruning (all-ones norms) if
/// absent.
pub struct Wanda;

impl Compressor for Wanda {
    fn name(&self) -> String {
        "wanda".into()
    }

    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer {
        let n = layer.n_experts();
        let p = layer.experts[0].d_model();
        let pi = layer.experts[0].d_inner();
        let x_norms: Vec<f32> = match ctx.calib {
            Some(x) => feature_norms(x),
            None => vec![1.0; p],
        };
        let keep = |cols: usize| ((ctx.rate * cols as f64).round() as usize).clamp(1, cols);
        let experts = layer
            .experts
            .iter()
            .map(|e| {
                // Inner-activation norms for W2's inputs come from the
                // calibration batch pushed through this expert's first layer.
                let h_norms: Vec<f32> = match ctx.calib {
                    Some(x) => {
                        let mut h = x.matmul_nt(&e.w1);
                        for r in 0..h.rows {
                            let row = h.row_mut(r);
                            for (c, v) in row.iter_mut().enumerate() {
                                *v += e.b1[c];
                            }
                        }
                        match e.arch {
                            ExpertArch::Relu => {
                                for v in h.data.iter_mut() {
                                    *v = v.max(0.0);
                                }
                            }
                            ExpertArch::SwiGlu => {
                                let w3 = e.w3.as_ref().unwrap();
                                let g = x.matmul_nt(w3);
                                for (hv, gv) in h.data.iter_mut().zip(&g.data) {
                                    *hv = crate::moe::expert::silu(*hv) * gv;
                                }
                            }
                        }
                        feature_norms(&h)
                    }
                    None => vec![1.0; pi],
                };
                let w1p = wanda_prune(&e.w1, &x_norms, keep(p));
                let w3p = e.w3.as_ref().map(|w3| wanda_prune(w3, &x_norms, keep(p)));
                let w2p = wanda_prune(&e.w2, &h_norms, keep(pi));
                let pruned = crate::moe::ExpertWeights {
                    arch: e.arch,
                    w1: w1p,
                    b1: e.b1.clone(),
                    w3: w3p,
                    b3: e.b3.clone(),
                    w2: w2p,
                    b2: e.b2.clone(),
                }
                .design_matrix();
                let csr = Csr::from_dense(&pruned, IndexWidth::narrowest_for(pruned.cols));
                CompressedExpert {
                    accounted_params: csr.nnz(),
                    residual: ResidualRepr::SparseCsr(csr),
                    b2: e.b2.clone(),
                }
            })
            .collect();
        CompressedLayer {
            method: self.name(),
            arch: layer.experts[0].arch,
            d_model: p,
            base: None,
            experts,
            expert_map: CompressedLayer::identity_map(n),
            aligns: CompressedLayer::identity_aligns(n, pi),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn feature_norms_known() {
        let x = Matrix::from_vec(2, 2, vec![3.0, 1.0, 4.0, 0.0]);
        let n = feature_norms(&x);
        assert!((n[0] - 5.0).abs() < 1e-6);
        assert!((n[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn wanda_keeps_high_activation_columns() {
        // Equal weights; activation norm decides which columns survive.
        let w = Matrix::from_vec(1, 4, vec![1.0, 1.0, 1.0, 1.0]);
        let xn = vec![0.1, 5.0, 0.2, 4.0];
        let pruned = wanda_prune(&w, &xn, 2);
        assert_eq!(pruned.data, vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn per_row_budget() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(6, 10, 1.0, &mut rng);
        let xn: Vec<f32> = (0..10).map(|_| rng.uniform_in(0.1, 2.0)).collect();
        let pruned = wanda_prune(&w, &xn, 3);
        for r in 0..6 {
            assert_eq!(pruned.row(r).iter().filter(|v| **v != 0.0).count(), 3);
        }
    }

    #[test]
    fn compressor_respects_rate_roughly() {
        let mut rng = Rng::new(2);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 1, false, false, &mut rng);
        let calib = Matrix::randn(32, 8, 1.0, &mut rng);
        let mut ctx = CompressCtx::new(0.25, &mut rng);
        ctx.calib = Some(&calib);
        let cl = Wanda.compress(&l, &mut ctx);
        let stored = cl.n_params_stored() as f64 / l.expert_params() as f64;
        assert!((stored - 0.25).abs() < 0.05, "stored={stored}");
        let restored = cl.to_layer(&l);
        let x = Matrix::randn(3, 8, 1.0, &mut rng);
        assert!(restored.forward(&x, None).data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn calibration_changes_the_mask() {
        let mut rng = Rng::new(3);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 2, 1, false, false, &mut rng);
        // Calibration with feature 0 dominant vs feature 7 dominant.
        let mut c1 = Matrix::zeros(16, 8);
        let mut c2 = Matrix::zeros(16, 8);
        for r in 0..16 {
            *c1.at_mut(r, 0) = 10.0;
            *c1.at_mut(r, 1) = 0.1;
            *c2.at_mut(r, 7) = 10.0;
            *c2.at_mut(r, 1) = 0.1;
        }
        let mut rng2 = Rng::new(4);
        let mut ctx1 = CompressCtx::new(0.25, &mut rng2);
        ctx1.calib = Some(&c1);
        let cl1 = Wanda.compress(&l, &mut ctx1);
        let mut rng3 = Rng::new(5);
        let mut ctx2 = CompressCtx::new(0.25, &mut rng3);
        ctx2.calib = Some(&c2);
        let cl2 = Wanda.compress(&l, &mut ctx2);
        let d1 = cl1.restore_design(0);
        let d2 = cl2.restore_design(0);
        assert!(d1.sq_dist(&d2) > 1e-6, "masks should differ with calibration");
    }
}
