//! Per-layer adaptive compression rates — the paper's §6 future-work item
//! ("exploration of adopting different compression rates for each layer").
//!
//! Given a global parameter budget, allocate per-layer retention rates in
//! proportion to each layer's *compression sensitivity*: the approximation
//! error a probe compression at the uniform rate would incur, normalized by
//! the layer's weight energy. Layers whose residuals are hard to sparsify
//! keep more parameters; easy layers give budget back. A water-filling pass
//! keeps the total exactly on budget.

use super::{compress_model, CompressCtx, CompressedModel, CompressionReport, Compressor, LayerReport};
use crate::moe::{Ffn, Model};
use crate::util::Rng;

/// Sensitivity-weighted per-layer rate allocation.
#[derive(Debug, Clone)]
pub struct RatePlan {
    /// (block index, retention rate), aligned with the compressed layers.
    pub rates: Vec<(usize, f64)>,
}

/// Probe each candidate layer at `global_rate` and allocate rates so that
/// `Σ rate_l · params_l = global_rate · Σ params_l`, with per-layer rates
/// clamped to `[min_rate, max_rate]`.
pub fn plan_rates(
    model: &Model,
    comp: &dyn Compressor,
    blocks: &[usize],
    global_rate: f64,
    min_rate: f64,
    max_rate: f64,
    rng: &mut Rng,
) -> RatePlan {
    assert!(min_rate <= global_rate && global_rate <= max_rate);
    // Probe sensitivities.
    let mut sens = Vec::with_capacity(blocks.len());
    let mut params = Vec::with_capacity(blocks.len());
    for &bi in blocks {
        let Ffn::Moe(layer) = &model.blocks[bi].ffn else {
            sens.push(0.0);
            params.push(0);
            continue;
        };
        let mut ctx = CompressCtx::new(global_rate, rng);
        let cl = comp.compress(layer, &mut ctx);
        let energy: f64 = layer
            .experts
            .iter()
            .map(|e| e.design_matrix().frob_norm_sq())
            .sum::<f64>()
            / layer.experts[0].d_inner() as f64;
        sens.push(cl.approx_error(layer) / energy.max(1e-12));
        params.push(layer.expert_params());
    }
    let total_params: usize = params.iter().sum();
    let budget = global_rate * total_params as f64;
    // Initial proportional-to-sensitivity allocation around the mean.
    let mean_sens = sens.iter().sum::<f64>() / sens.len().max(1) as f64;
    let mut rates: Vec<f64> = sens
        .iter()
        .map(|&s| {
            let tilt = if mean_sens > 0.0 { s / mean_sens } else { 1.0 };
            (global_rate * tilt).clamp(min_rate, max_rate)
        })
        .collect();
    // Water-filling: scale unclamped layers until the budget matches.
    for _ in 0..32 {
        let spent: f64 = rates.iter().zip(&params).map(|(r, &p)| r * p as f64).sum();
        let err = budget - spent;
        if err.abs() < 1e-6 * budget.max(1.0) {
            break;
        }
        let adjustable: f64 = rates
            .iter()
            .zip(&params)
            .filter(|(r, _)| **r > min_rate + 1e-9 && **r < max_rate - 1e-9)
            .map(|(_, &p)| p as f64)
            .sum();
        if adjustable == 0.0 {
            // Fall back to adjusting everything proportionally.
            let scale = budget / spent.max(1e-12);
            for r in rates.iter_mut() {
                *r = (*r * scale).clamp(min_rate, max_rate);
            }
            break;
        }
        let delta = err / adjustable;
        for (r, &_p) in rates.iter_mut().zip(&params) {
            if *r > min_rate + 1e-9 && *r < max_rate - 1e-9 {
                *r = (*r + delta).clamp(min_rate, max_rate);
            }
        }
    }
    RatePlan { rates: blocks.iter().copied().zip(rates).collect() }
}

/// Compress with per-layer rates from a [`RatePlan`].
pub fn compress_model_adaptive(
    model: &Model,
    comp: &dyn Compressor,
    plan: &RatePlan,
    calib_tokens: Option<&[u32]>,
    rng: &mut Rng,
) -> CompressedModel {
    let (ffn_inputs, stats) = match calib_tokens {
        Some(tokens) => {
            let inputs = model.collect_ffn_inputs(tokens);
            let mut st = model.fresh_stats();
            model.hidden_states(tokens, Some(&mut st));
            (Some(inputs), Some(st))
        }
        None => (None, None),
    };
    let mut out = model.clone();
    let mut layers = Vec::new();
    let mut reports = Vec::new();
    for &(bi, rate) in &plan.rates {
        let Ffn::Moe(layer) = &model.blocks[bi].ffn else { continue };
        let mut ctx = CompressCtx::new(rate, rng);
        ctx.calib = ffn_inputs.as_ref().map(|v| &v[bi]);
        ctx.stats = stats.as_ref().map(|s| &s[bi]);
        let cl = comp.compress(layer, &mut ctx);
        let params_before = layer.expert_params();
        reports.push(LayerReport {
            block: bi,
            approx_error: cl.approx_error(layer),
            params_before,
            params_after: cl.n_params_stored(),
            bytes_before: params_before * 4,
            bytes_after: cl.memory_bytes(),
        });
        out.blocks[bi].ffn = Ffn::Moe(cl.to_layer(layer));
        layers.push((bi, cl));
    }
    CompressedModel {
        model: out,
        layers,
        report: CompressionReport {
            method: format!("{}+adaptive", comp.name()),
            rate: plan.rates.iter().map(|(_, r)| r).sum::<f64>() / plan.rates.len().max(1) as f64,
            layers: reports,
        },
    }
}

/// Convenience: plan + compress in one call, budget-matched to
/// `compress_model(model, comp, global_rate, ...)`.
pub fn compress_model_with_budget(
    model: &Model,
    comp: &dyn Compressor,
    global_rate: f64,
    top_layers: usize,
    calib_tokens: Option<&[u32]>,
    rng: &mut Rng,
) -> CompressedModel {
    let moe_blocks = model.moe_blocks();
    let blocks: Vec<usize> = moe_blocks
        .iter()
        .copied()
        .rev()
        .take(top_layers)
        .collect::<Vec<_>>()
        .into_iter()
        .rev()
        .collect();
    let plan = plan_rates(model, comp, &blocks, global_rate, global_rate * 0.4, (global_rate * 2.0).min(0.95), rng);
    compress_model_adaptive(model, comp, &plan, calib_tokens, rng)
}

/// Uniform-rate baseline with identical reporting (ablation helper).
pub fn compress_model_uniform(
    model: &Model,
    comp: &dyn Compressor,
    rate: f64,
    top_layers: usize,
    rng: &mut Rng,
) -> CompressedModel {
    compress_model(model, comp, rate, top_layers, None, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::ResMoE;
    use crate::moe::ModelConfig;

    fn model_with_uneven_layers(seed: u64) -> Model {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 4;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        let mut m = Model::random(&cfg, &mut rng);
        // Make block 1's experts near-identical (easy to compress) and
        // block 3's heterogeneous (hard).
        if let Ffn::Moe(l) = &mut m.blocks[1].ffn {
            let base = l.experts[0].clone();
            for e in l.experts.iter_mut() {
                *e = base.perturbed(0.001, &mut rng);
            }
        }
        if let Ffn::Moe(l) = &mut m.blocks[3].ffn {
            for (i, e) in l.experts.iter_mut().enumerate() {
                for v in e.w1.data.iter_mut() {
                    *v *= 1.0 + i as f32;
                }
            }
        }
        m
    }

    #[test]
    fn plan_meets_global_budget() {
        let m = model_with_uneven_layers(1);
        let mut rng = Rng::new(2);
        let blocks = m.moe_blocks();
        let plan = plan_rates(&m, &ResMoE::up(), &blocks, 0.25, 0.1, 0.5, &mut rng);
        let params: Vec<usize> = blocks
            .iter()
            .map(|&b| {
                let Ffn::Moe(l) = &m.blocks[b].ffn else { panic!() };
                l.expert_params()
            })
            .collect();
        let spent: f64 = plan
            .rates
            .iter()
            .zip(&params)
            .map(|(&(_, r), &p)| r * p as f64)
            .sum();
        let budget = 0.25 * params.iter().sum::<usize>() as f64;
        assert!(
            (spent - budget).abs() < 0.02 * budget,
            "spent {spent} vs budget {budget}"
        );
    }

    #[test]
    fn hard_layer_gets_more_budget() {
        let m = model_with_uneven_layers(3);
        let mut rng = Rng::new(4);
        let blocks = m.moe_blocks();
        let plan = plan_rates(&m, &ResMoE::up(), &blocks, 0.25, 0.1, 0.5, &mut rng);
        let easy = plan.rates.iter().find(|(b, _)| *b == 1).unwrap().1;
        let hard = plan.rates.iter().find(|(b, _)| *b == 3).unwrap().1;
        assert!(hard > easy, "hard layer rate {hard} <= easy {easy}");
    }

    #[test]
    fn adaptive_no_worse_than_uniform_at_same_budget() {
        let m = model_with_uneven_layers(5);
        let mut rng = Rng::new(6);
        let adaptive = compress_model_with_budget(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        let uniform = compress_model_uniform(&m, &ResMoE::up(), 0.25, 2, &mut Rng::new(6));
        // Compare total weighted error.
        let total = |cm: &CompressedModel| -> f64 {
            cm.report.layers.iter().map(|l| l.approx_error).sum()
        };
        assert!(
            total(&adaptive) <= total(&uniform) * 1.05,
            "adaptive {} vs uniform {}",
            total(&adaptive),
            total(&uniform)
        );
        // And the budgets actually match (within the sparse-format slack).
        let pa = adaptive.report.total_params_after() as f64;
        let pu = uniform.report.total_params_after() as f64;
        assert!((pa - pu).abs() < 0.08 * pu, "adaptive {pa} vs uniform {pu} params");
    }

    #[test]
    fn report_marks_method_adaptive() {
        let m = model_with_uneven_layers(7);
        let mut rng = Rng::new(8);
        let cm = compress_model_with_budget(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        assert!(cm.report.method.contains("adaptive"));
        assert_eq!(cm.layers.len(), 2);
    }
}
