//! Parallelism adaptability (paper App. B.1):
//!
//! * **Expert parallelism** — "assign different center experts to each GPU,
//!   allowing each center expert to handle the experts on its respective
//!   GPU": experts are partitioned into shards, each shard gets its OWN
//!   barycenter + residuals. More centers ⇒ tighter residuals per shard at
//!   the cost of extra center storage — [`ShardedResMoE`] implements and
//!   measures this trade.
//! * **Tensor parallelism** — Eq. (3) writes the expert as a sum of
//!   bottleneck-1 sub-MLPs, so center + residual can be partitioned along
//!   the `pI` axis into chunks whose partial outputs sum to the full
//!   result (Megatron-style). [`tensor_shards`]/[`tensor_parallel_forward`]
//!   implement the split and verify the partial-sum identity.

use super::formats::{CompressedExpert, CompressedLayer, ResidualRepr};
use super::prune::magnitude_prune_joint;
use super::{CompressCtx, Compressor};
use crate::moe::{ExpertWeights, MoeLayer};
use crate::ot::free_support_barycenter;
use crate::tensor::{sparse::IndexWidth, Csr, Matrix};
use crate::util::threads::parallel_map;
use crate::util::Rng;

/// ResMoE with `n_shards` independent centers (App. B.1 expert
/// parallelism). Shard `s` owns router slots `{k : k mod n_shards == s}`,
/// mirroring the usual round-robin expert placement.
pub struct ShardedResMoE {
    pub n_shards: usize,
}

impl Compressor for ShardedResMoE {
    fn name(&self) -> String {
        format!("resmoe-up-ep{}", self.n_shards)
    }

    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer {
        let n = layer.n_experts();
        let pi = layer.experts[0].d_inner();
        let p = layer.experts[0].d_model();
        let shards = self.n_shards.clamp(1, n);
        let dms: Vec<Matrix> = layer.experts.iter().map(|e| e.design_matrix()).collect();
        // Per-shard barycenters + aligned residuals. Shards are independent
        // solves, so they fan out over the persistent worker pool; each gets
        // a child rng seeded from the ctx stream. The 1-shard case keeps
        // using ctx.rng directly so it stays bit-identical to plain ResMoE.
        let shard_members: Vec<Vec<usize>> =
            (0..shards).map(|s| (0..n).filter(|k| k % shards == s).collect()).collect();
        let barycenters = if shards == 1 {
            let refs: Vec<&Matrix> = shard_members[0].iter().map(|&k| &dms[k]).collect();
            vec![free_support_barycenter(&refs, &Default::default(), ctx.rng)]
        } else {
            let jobs: Vec<(&Vec<usize>, u64)> = shard_members
                .iter()
                .map(|m| (m, ctx.rng.next_u64()))
                .collect();
            parallel_map(jobs, |(members, seed)| {
                let refs: Vec<&Matrix> = members.iter().map(|&k| &dms[k]).collect();
                free_support_barycenter(&refs, &Default::default(), &mut Rng::new(seed))
            })
        };
        let mut aligns: Vec<Vec<usize>> = vec![(0..pi).collect(); n];
        let mut residuals: Vec<Option<Matrix>> = vec![None; n];
        let mut centers: Vec<Matrix> = Vec::with_capacity(shards);
        let mut shard_of = vec![0usize; n];
        for (s, (members, bc)) in shard_members.iter().zip(barycenters).enumerate() {
            for (&k, perm) in members.iter().zip(&bc.perms) {
                residuals[k] = Some(dms[k].permute_rows(perm).sub(&bc.support));
                aligns[k] = perm.clone();
                shard_of[k] = s;
            }
            centers.push(bc.support);
        }
        // Joint magnitude prune across ALL residuals at the retention rate.
        let mut resid: Vec<Matrix> = residuals.into_iter().map(|r| r.unwrap()).collect();
        let total: usize = resid.iter().map(|r| r.n_params()).sum();
        let keep = (ctx.rate * total as f64).round() as usize;
        let mut refs: Vec<&mut Matrix> = resid.iter_mut().collect();
        magnitude_prune_joint(&mut refs, keep);
        // Fold each shard's center into the stored residual (the generic
        // CompressedLayer supports one `base`; per-shard bases are expressed
        // by storing `center_s − base0` dense? No — store per-expert dense
        // restored = center_s + Δ_k with accounted params = nnz + amortized
        // center share).
        //
        // We keep exactness instead: base = None, each expert's repr is the
        // SPARSE residual paired with its shard center via a dense add at
        // restore time. To stay within the shared format we materialize
        // restored = center + residual as the Dense repr, but account only
        // nnz + center/|shard| parameters, which is what a sharded
        // deployment stores.
        let per_shard_count = |s: usize| (0..n).filter(|k| k % shards == s).count();
        let experts = layer
            .experts
            .iter()
            .enumerate()
            .zip(resid)
            .map(|((k, e), delta)| {
                let s = shard_of[k];
                let mut restored = centers[s].clone();
                restored.add_assign(&delta);
                let csr = Csr::from_dense(&delta, IndexWidth::narrowest_for(delta.cols));
                let center_share =
                    (centers[s].n_params() as f64 / per_shard_count(s) as f64).ceil() as usize;
                CompressedExpert {
                    accounted_params: csr.nnz() + center_share,
                    residual: ResidualRepr::Dense(restored),
                    b2: e.b2.clone(),
                }
            })
            .collect();
        CompressedLayer {
            method: self.name(),
            arch: layer.experts[0].arch,
            d_model: p,
            base: None,
            experts,
            expert_map: CompressedLayer::identity_map(n),
            aligns,
        }
    }
}

/// One tensor-parallel shard of an expert: rows `[lo, hi)` of the sub-MLP
/// axis (W1/b1/W3/b3 rows, W2 columns). Eq. (3) guarantees the full output
/// is the SUM of shard outputs plus a single b2.
#[derive(Debug, Clone)]
pub struct TensorShard {
    pub lo: usize,
    pub hi: usize,
    pub expert: ExpertWeights,
}

/// Split an expert into `n` row-range shards (b2 kept on shard 0 only).
pub fn tensor_shards(e: &ExpertWeights, n: usize) -> Vec<TensorShard> {
    let pi = e.d_inner();
    let n = n.clamp(1, pi);
    let chunk = pi.div_ceil(n);
    let mut out = Vec::with_capacity(n);
    let mut lo = 0usize;
    while lo < pi {
        let hi = (lo + chunk).min(pi);
        let slice_rows = |m: &Matrix| m.slice_rows(lo, hi);
        let shard = ExpertWeights {
            arch: e.arch,
            w1: slice_rows(&e.w1),
            b1: e.b1[lo..hi].to_vec(),
            w3: e.w3.as_ref().map(slice_rows),
            b3: e.b3.as_ref().map(|b| b[lo..hi].to_vec()),
            w2: e.w2.slice_cols(lo, hi),
            b2: if lo == 0 { e.b2.clone() } else { vec![0.0; e.d_model()] },
        };
        out.push(TensorShard { lo, hi, expert: shard });
        lo = hi;
    }
    out
}

/// Megatron-style forward: each shard computes its partial output, partials
/// are all-reduced (summed).
pub fn tensor_parallel_forward(shards: &[TensorShard], x: &Matrix) -> Matrix {
    let mut acc: Option<Matrix> = None;
    for s in shards {
        let y = s.expert.forward(x);
        match &mut acc {
            Some(a) => a.add_assign(&y),
            None => acc = Some(y),
        }
    }
    acc.expect("at least one shard")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::compress::resmoe::ResMoE;
    use crate::moe::ExpertArch;
    use crate::util::Rng;

    fn upcycled_layer(seed: u64, n_experts: usize) -> MoeLayer {
        let mut rng = Rng::new(seed);
        MoeLayer::random(ExpertArch::Relu, 8, 16, n_experts, 2, true, false, &mut rng)
    }

    #[test]
    fn sharded_is_exact_at_full_rate() {
        let l = upcycled_layer(1, 8);
        for shards in [1, 2, 4] {
            let cl = quick_compress(&ShardedResMoE { n_shards: shards }, &l, 1.0, 1);
            assert!(
                cl.approx_error(&l) < 1e-9,
                "shards={shards}: err {}",
                cl.approx_error(&l)
            );
        }
    }

    #[test]
    fn more_centers_tighter_residuals() {
        // App. B.1's hypothesis: per-shard centers capture "more diverse
        // patterns" — at a fixed residual budget, more centers must not
        // increase the error on heterogeneous experts.
        let mut rng = Rng::new(2);
        // Two distinct families of experts (bimodal).
        let base_a = ExpertWeights::random(ExpertArch::Relu, 8, 16, &mut rng);
        let base_b = ExpertWeights::random(ExpertArch::Relu, 8, 16, &mut rng);
        let experts: Vec<ExpertWeights> = (0..8)
            .map(|k| {
                // Interleave families so round-robin shards s=2 separate them.
                if k % 2 == 0 {
                    base_a.perturbed(0.02, &mut rng)
                } else {
                    base_b.perturbed(0.02, &mut rng)
                }
            })
            .collect();
        let l = MoeLayer {
            router: crate::moe::Router::random(8, 8, 2, &mut rng),
            experts,
            shared_expert: None,
        };
        let e1 = quick_compress(&ShardedResMoE { n_shards: 1 }, &l, 0.25, 3).approx_error(&l);
        let e2 = quick_compress(&ShardedResMoE { n_shards: 2 }, &l, 0.25, 3).approx_error(&l);
        assert!(e2 < e1 * 0.8, "2 shards {e2} should beat 1 shard {e1} on bimodal experts");
    }

    #[test]
    fn sharded_accounts_center_share() {
        let l = upcycled_layer(3, 8);
        let one = quick_compress(&ShardedResMoE { n_shards: 1 }, &l, 0.25, 4);
        let four = quick_compress(&ShardedResMoE { n_shards: 4 }, &l, 0.25, 4);
        // More shards store more center parameters at the same residual
        // budget.
        assert!(four.n_params_stored() > one.n_params_stored());
    }

    #[test]
    fn sharded_matches_single_center_resmoe_at_one_shard() {
        let l = upcycled_layer(4, 4);
        let sharded = quick_compress(&ShardedResMoE { n_shards: 1 }, &l, 0.25, 5);
        let plain = quick_compress(&ResMoE::up(), &l, 0.25, 5);
        assert!(
            (sharded.approx_error(&l) - plain.approx_error(&l)).abs() < 1e-9,
            "{} vs {}",
            sharded.approx_error(&l),
            plain.approx_error(&l)
        );
    }

    #[test]
    fn tensor_parallel_partial_sums_are_exact() {
        // The Eq.-(3) identity behind App. B.1's tensor-parallel claim.
        let mut rng = Rng::new(6);
        for arch in [ExpertArch::Relu, ExpertArch::SwiGlu] {
            let e = ExpertWeights::random(arch, 8, 13, &mut rng);
            let x = Matrix::randn(5, 8, 1.0, &mut rng);
            let want = e.forward(&x);
            for n in [1, 2, 3, 5, 13] {
                let shards = tensor_shards(&e, n);
                let got = tensor_parallel_forward(&shards, &x);
                assert!(
                    got.sq_dist(&want) < 1e-8,
                    "arch {arch:?} n={n}: {}",
                    got.sq_dist(&want)
                );
            }
        }
    }

    #[test]
    fn tensor_shards_partition_rows() {
        let mut rng = Rng::new(7);
        let e = ExpertWeights::random(ExpertArch::Relu, 8, 16, &mut rng);
        let shards = tensor_shards(&e, 3);
        assert_eq!(shards[0].lo, 0);
        assert_eq!(shards.last().unwrap().hi, 16);
        let covered: usize = shards.iter().map(|s| s.hi - s.lo).sum();
        assert_eq!(covered, 16);
        // b2 only on shard 0.
        assert!(shards[1].expert.b2.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn restored_sharded_layer_functions() {
        let l = upcycled_layer(8, 8);
        let cl = quick_compress(&ShardedResMoE { n_shards: 2 }, &l, 0.3, 9);
        let restored = cl.to_layer(&l);
        let mut rng = Rng::new(10);
        let x = Matrix::randn(4, 8, 1.0, &mut rng);
        assert!(restored.forward(&x, None).data.iter().all(|v| v.is_finite()));
    }
}
