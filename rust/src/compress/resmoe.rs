//! The ResMoE pipeline (paper Alg. 1): extract a shared *center expert*
//! from the layer's experts, compress only the **residuals** between each
//! (aligned) expert and the center, and restore `Ŵ_k = W_ω + Δ_k` at
//! inference (Alg. 2).
//!
//! The center is the free-support Wasserstein barycenter of the experts'
//! design-matrix distributions (§4.2, Prop. 4.1); the ablations of Table 4
//! swap it for the naive average or a Git-Re-Basin-style greedy center.

use super::formats::{CompressedExpert, CompressedLayer, ResidualRepr};
use super::prune::magnitude_prune_joint;
use super::svd_compress::svd_at_rate;
use super::{CompressCtx, Compressor};
use crate::moe::MoeLayer;
use crate::ot::{free_support_barycenter, hungarian, BarycenterConfig};
use crate::tensor::{sparse::IndexWidth, Csr, Matrix};
use crate::util::Rng;

/// Which shared center to extract (Table 4 ablation axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CenterKind {
    /// Free-support Wasserstein barycenter with exact permutation alignment
    /// — the paper's method.
    Barycenter,
    /// Element-wise average, no alignment ("Avg" row of Table 4).
    Average,
    /// Greedy layer-wise alignment à la Git Re-Basin: permutations computed
    /// from the FIRST linear layer only, then averaged ("Git" row). The
    /// paper argues this layer-by-layer view is the key limitation.
    GitReBasin,
}

/// How residuals are compressed (Table 1–3's (UP)/(SVD) suffix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResidualKind {
    /// Unstructured magnitude pruning with a joint threshold across all
    /// residual matrices in the layer.
    PruneConcat,
    /// Per-expert threshold.
    PruneSep,
    /// Truncated SVD at the App.-A.4 rank.
    Svd,
}

/// Compute a Git-Re-Basin-style greedy center: align every cloud to a
/// running center using only the first `w1_cols` columns (the first-layer
/// weights), then average. `iters` refinement passes.
pub fn git_rebasin_center(
    dms: &[Matrix],
    w1_cols: usize,
    iters: usize,
) -> (Matrix, Vec<Vec<usize>>) {
    let n = dms.len();
    let pi = dms[0].rows;
    let mut center = dms[0].clone();
    let mut perms: Vec<Vec<usize>> = vec![(0..pi).collect(); n];
    for _ in 0..iters {
        for (k, dm) in dms.iter().enumerate() {
            // Cost restricted to the first-layer block — the layer-by-layer
            // shortcut that ignores W2 coupling.
            let c_sub = center.slice_cols(0, w1_cols);
            let d_sub = dm.slice_cols(0, w1_cols);
            let cost = crate::ot::cost::sq_euclidean(&c_sub, &d_sub);
            perms[k] = hungarian::solve(&cost).row_to_col;
        }
        let aligned: Vec<Matrix> = dms
            .iter()
            .zip(&perms)
            .map(|(dm, p)| dm.permute_rows(p))
            .collect();
        center = Matrix::mean_of(&aligned.iter().collect::<Vec<_>>());
    }
    (center, perms)
}

/// The ResMoE compressor (and its Table-4 center ablations).
pub struct ResMoE {
    pub center: CenterKind,
    pub residual: ResidualKind,
    pub bc_config: BarycenterConfig,
}

impl ResMoE {
    /// ResMoE (UP) — the paper's headline configuration.
    pub fn up() -> ResMoE {
        ResMoE {
            center: CenterKind::Barycenter,
            residual: ResidualKind::PruneConcat,
            bc_config: BarycenterConfig::default(),
        }
    }

    /// ResMoE (SVD).
    pub fn svd() -> ResMoE {
        ResMoE {
            center: CenterKind::Barycenter,
            residual: ResidualKind::Svd,
            bc_config: BarycenterConfig::default(),
        }
    }

    pub fn with_center(center: CenterKind, residual: ResidualKind) -> ResMoE {
        ResMoE { center, residual, bc_config: BarycenterConfig::default() }
    }

    /// Extract the center and per-expert alignments.
    fn extract_center(&self, dms: &[Matrix], w1_cols: usize, rng: &mut Rng) -> (Matrix, Vec<Vec<usize>>) {
        let pi = dms[0].rows;
        match self.center {
            CenterKind::Barycenter => {
                let refs: Vec<&Matrix> = dms.iter().collect();
                let bc = free_support_barycenter(&refs, &self.bc_config, rng);
                (bc.support, bc.perms)
            }
            CenterKind::Average => {
                let center = Matrix::mean_of(&dms.iter().collect::<Vec<_>>());
                (center, vec![(0..pi).collect(); dms.len()])
            }
            CenterKind::GitReBasin => git_rebasin_center(dms, w1_cols, 2),
        }
    }
}

impl Compressor for ResMoE {
    fn name(&self) -> String {
        let c = match self.center {
            CenterKind::Barycenter => "wb",
            CenterKind::Average => "avg",
            CenterKind::GitReBasin => "git",
        };
        let r = match self.residual {
            ResidualKind::PruneConcat => "up",
            ResidualKind::PruneSep => "up-sep",
            ResidualKind::Svd => "svd",
        };
        if self.center == CenterKind::Barycenter {
            format!("resmoe-{r}")
        } else {
            format!("resmoe-{c}+{r}")
        }
    }

    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer {
        let n = layer.n_experts();
        let p = layer.experts[0].d_model();
        let dms: Vec<Matrix> = layer.experts.iter().map(|e| e.design_matrix()).collect();
        let w1_cols = p + 1;
        let (center, perms) = self.extract_center(&dms, w1_cols, ctx.rng);
        // Residuals of the ALIGNED experts (Δ_k ≈ T_k W_k − W_ω).
        let mut residuals: Vec<Matrix> = dms
            .iter()
            .zip(&perms)
            .map(|(dm, perm)| dm.permute_rows(perm).sub(&center))
            .collect();
        // Compress residuals at the retention rate. Per App. A.3, the
        // center's storage overhead is not charged against the rate (it
        // amortizes over experts and is reported separately in Table 10).
        match self.residual {
            ResidualKind::PruneConcat => {
                let total: usize = residuals.iter().map(|r| r.n_params()).sum();
                let keep = (ctx.rate * total as f64).round() as usize;
                let mut refs: Vec<&mut Matrix> = residuals.iter_mut().collect();
                magnitude_prune_joint(&mut refs, keep);
            }
            ResidualKind::PruneSep => {
                for r in residuals.iter_mut() {
                    let keep = (ctx.rate * r.n_params() as f64).round() as usize;
                    magnitude_prune_joint(&mut [r], keep);
                }
            }
            ResidualKind::Svd => {}
        }
        let experts = layer
            .experts
            .iter()
            .zip(residuals.into_iter())
            .map(|(e, resid)| {
                let (repr, accounted) = match self.residual {
                    ResidualKind::PruneConcat | ResidualKind::PruneSep => {
                        let csr = Csr::from_dense(&resid, IndexWidth::narrowest_for(resid.cols));
                        let nnz = csr.nnz();
                        (ResidualRepr::SparseCsr(csr), nnz)
                    }
                    ResidualKind::Svd => {
                        let svd = svd_at_rate(&resid, ctx.rate);
                        let params = svd.n_params();
                        (ResidualRepr::LowRank(svd), params)
                    }
                };
                CompressedExpert {
                    residual: repr,
                    b2: e.b2.clone(),
                    accounted_params: accounted,
                }
            })
            .collect();
        CompressedLayer {
            method: self.name(),
            arch: layer.experts[0].arch,
            d_model: p,
            base: Some(center),
            experts,
            expert_map: CompressedLayer::identity_map(n),
            aligns: perms,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::prune::UnstructuredPruning;
    use crate::compress::svd_compress::SvdCompression;
    use crate::moe::ExpertArch;

    fn upcycled_layer(seed: u64) -> (MoeLayer, Rng) {
        let mut rng = Rng::new(seed);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 1, true, false, &mut rng);
        (l, rng)
    }

    fn independent_layer(seed: u64) -> (MoeLayer, Rng) {
        let mut rng = Rng::new(seed);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 1, false, false, &mut rng);
        (l, rng)
    }

    #[test]
    fn lossless_at_full_rate() {
        let (l, mut rng) = upcycled_layer(1);
        let mut ctx = CompressCtx::new(1.0, &mut rng);
        let cl = ResMoE::up().compress(&l, &mut ctx);
        assert!(cl.approx_error(&l) < 1e-9, "err={}", cl.approx_error(&l));
        // Function preserved: restored layer output matches the original.
        let restored = cl.to_layer(&l);
        let x = Matrix::randn(5, 8, 1.0, &mut rng);
        assert!(l.forward(&x, None).sq_dist(&restored.forward(&x, None)) < 1e-6);
    }

    #[test]
    fn resmoe_up_beats_plain_up() {
        // The paper's core claim (Tables 1 & 4): pruning residuals against
        // the barycenter beats pruning the raw weights, markedly so for
        // upcycled (Mixtral-like) experts.
        let (l, mut rng) = upcycled_layer(2);
        let mut ctx = CompressCtx::new(0.25, &mut rng);
        let e_resmoe = ResMoE::up().compress(&l, &mut ctx).approx_error(&l);
        let e_up = UnstructuredPruning { concat: true }.compress(&l, &mut ctx).approx_error(&l);
        assert!(
            e_resmoe < e_up,
            "resmoe-up {e_resmoe} should beat up {e_up}"
        );
    }

    #[test]
    fn resmoe_svd_beats_plain_svd() {
        let (l, mut rng) = upcycled_layer(3);
        let mut ctx = CompressCtx::new(0.25, &mut rng);
        let e_resmoe = ResMoE::svd().compress(&l, &mut ctx).approx_error(&l);
        let e_svd = SvdCompression { concat: true }.compress(&l, &mut ctx).approx_error(&l);
        assert!(e_resmoe < e_svd, "resmoe-svd {e_resmoe} vs svd {e_svd}");
    }

    #[test]
    fn barycenter_center_beats_average_on_permuted_experts() {
        // Construct experts that are row-permutations of a common pattern
        // plus noise: alignment is essential, the naive average washes out.
        let mut rng = Rng::new(4);
        let base = crate::moe::ExpertWeights::random(ExpertArch::Relu, 8, 16, &mut rng);
        let experts: Vec<crate::moe::ExpertWeights> = (0..4)
            .map(|_| {
                let perm = rng.permutation(16);
                base.permuted(&perm).perturbed(0.02, &mut rng)
            })
            .collect();
        let l = MoeLayer {
            router: crate::moe::Router::random(4, 8, 1, &mut rng),
            experts,
            shared_expert: None,
        };
        let mut ctx = CompressCtx::new(0.25, &mut rng);
        let e_wb = ResMoE::up().compress(&l, &mut ctx).approx_error(&l);
        let e_avg = ResMoE::with_center(CenterKind::Average, ResidualKind::PruneConcat)
            .compress(&l, &mut ctx)
            .approx_error(&l);
        assert!(e_wb < e_avg * 0.8, "wb={e_wb} avg={e_avg}");
    }

    #[test]
    fn git_center_between_avg_and_wb_on_permuted_experts() {
        let mut rng = Rng::new(5);
        let base = crate::moe::ExpertWeights::random(ExpertArch::Relu, 8, 16, &mut rng);
        let experts: Vec<crate::moe::ExpertWeights> = (0..4)
            .map(|_| {
                let perm = rng.permutation(16);
                base.permuted(&perm).perturbed(0.05, &mut rng)
            })
            .collect();
        let l = MoeLayer {
            router: crate::moe::Router::random(4, 8, 1, &mut rng),
            experts,
            shared_expert: None,
        };
        let mut ctx = CompressCtx::new(0.25, &mut rng);
        let e_wb = ResMoE::up().compress(&l, &mut ctx).approx_error(&l);
        let e_git = ResMoE::with_center(CenterKind::GitReBasin, ResidualKind::PruneConcat)
            .compress(&l, &mut ctx)
            .approx_error(&l);
        let e_avg = ResMoE::with_center(CenterKind::Average, ResidualKind::PruneConcat)
            .compress(&l, &mut ctx)
            .approx_error(&l);
        // Git's W1-only alignment recovers some structure (better than avg)
        // but not the full coupling (worse than or equal to WB).
        assert!(e_git <= e_avg + 1e-9, "git={e_git} avg={e_avg}");
        assert!(e_wb <= e_git + 1e-9, "wb={e_wb} git={e_git}");
    }

    #[test]
    fn respects_rate_budget() {
        let (l, mut rng) = independent_layer(6);
        for residual in [ResidualKind::PruneConcat, ResidualKind::Svd] {
            let mut ctx = CompressCtx::new(0.25, &mut rng);
            let cl = ResMoE::with_center(CenterKind::Barycenter, residual).compress(&l, &mut ctx);
            // Residual budget excludes the center per App. A.3.
            let residual_params: usize =
                cl.experts.iter().map(|e| e.accounted_params).sum();
            let orig = l.expert_params() as f64;
            assert!(
                residual_params as f64 <= orig * 0.27,
                "{residual:?}: {}",
                residual_params as f64 / orig
            );
        }
    }

    #[test]
    fn alignment_is_stored_per_expert() {
        let (l, mut rng) = independent_layer(7);
        let mut ctx = CompressCtx::new(0.25, &mut rng);
        let cl = ResMoE::up().compress(&l, &mut ctx);
        for a in &cl.aligns {
            let mut sorted = a.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..16).collect::<Vec<_>>());
        }
        // Barycenter alignment should be non-trivial for at least one expert
        // (independent experts rarely align with identity).
        assert!(cl.aligns.iter().any(|a| a.iter().enumerate().any(|(i, &j)| i != j)));
    }
}
