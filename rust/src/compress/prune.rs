//! Pruning compressors: unstructured magnitude pruning (UP) in the paper's
//! "concat" and "sep" variants, and structured (neuron-wise) pruning (SP).
//!
//! Following Han et al. (2015) as the paper does, UP zeroes the smallest-
//! magnitude weights. "concat" thresholds over the concatenation of all
//! experts' matrices in the layer (preserving expert-level relationships —
//! the variant the paper finds markedly better, Table 2); "sep" thresholds
//! within each expert separately.

use super::formats::{CompressedExpert, CompressedLayer, ResidualRepr};
use super::{CompressCtx, Compressor};
use crate::moe::{ExpertWeights, MoeLayer};
use crate::tensor::{sparse::IndexWidth, Csr, Matrix};

/// Zero all but the `keep` largest-|v| entries of the matrices in `mats`
/// (joint threshold across all of them).
pub fn magnitude_prune_joint(mats: &mut [&mut Matrix], keep: usize) {
    let total: usize = mats.iter().map(|m| m.n_params()).sum();
    if keep >= total {
        return;
    }
    // Select the threshold = (total-keep)-th smallest |v|.
    let mut mags: Vec<f32> = Vec::with_capacity(total);
    for m in mats.iter() {
        mags.extend(m.data.iter().map(|v| v.abs()));
    }
    let cut_idx = total - keep; // entries strictly below threshold are dropped
    mags.select_nth_unstable_by(cut_idx.saturating_sub(1).min(total - 1), |a, b| {
        a.partial_cmp(b).unwrap()
    });
    let thresh = mags[cut_idx.saturating_sub(1).min(total - 1)];
    // Zero entries below the threshold; among ties keep first-seen until the
    // budget is met.
    let mut kept = mats
        .iter()
        .map(|m| m.data.iter().filter(|v| v.abs() > thresh).count())
        .sum::<usize>();
    for m in mats.iter_mut() {
        for v in m.data.iter_mut() {
            let a = v.abs();
            if a < thresh || a == 0.0 {
                *v = 0.0;
            } else if a == thresh {
                if kept < keep {
                    kept += 1;
                } else {
                    *v = 0.0;
                }
            }
        }
    }
}

/// Magnitude-prune a single matrix to `keep` entries.
pub fn magnitude_prune(m: &Matrix, keep: usize) -> Matrix {
    let mut out = m.clone();
    magnitude_prune_joint(&mut [&mut out], keep);
    out
}

/// Unstructured pruning baseline.
pub struct UnstructuredPruning {
    /// Joint threshold across the layer's experts (paper's "concat").
    pub concat: bool,
}

impl Compressor for UnstructuredPruning {
    fn name(&self) -> String {
        format!("up-{}", if self.concat { "concat" } else { "sep" })
    }

    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer {
        let n = layer.n_experts();
        let pi = layer.experts[0].d_inner();
        let mut dms: Vec<Matrix> = layer.experts.iter().map(|e| e.design_matrix()).collect();
        if self.concat {
            let total: usize = dms.iter().map(|m| m.n_params()).sum();
            let keep = (ctx.rate * total as f64).round() as usize;
            let mut refs: Vec<&mut Matrix> = dms.iter_mut().collect();
            magnitude_prune_joint(&mut refs, keep);
        } else {
            for dm in dms.iter_mut() {
                let keep = (ctx.rate * dm.n_params() as f64).round() as usize;
                magnitude_prune_joint(&mut [dm], keep);
            }
        }
        let experts = layer
            .experts
            .iter()
            .zip(dms)
            .map(|(e, dm)| {
                let csr = Csr::from_dense(&dm, IndexWidth::narrowest_for(dm.cols));
                CompressedExpert {
                    accounted_params: csr.nnz(),
                    residual: ResidualRepr::SparseCsr(csr),
                    b2: e.b2.clone(),
                }
            })
            .collect();
        CompressedLayer {
            method: self.name(),
            arch: layer.experts[0].arch,
            d_model: layer.experts[0].d_model(),
            base: None,
            experts,
            expert_map: CompressedLayer::identity_map(n),
            aligns: CompressedLayer::identity_aligns(n, pi),
        }
    }
}

/// Structured pruning: drop whole sub-MLPs (design-matrix rows) with the
/// smallest L2 norm, keeping `rate · pI` neurons per expert. Restored
/// experts keep full shape with zero rows (function-identical to physically
/// shrinking `pI`), but only kept rows are accounted/stored.
pub struct StructuredPruning {
    pub concat: bool,
}

impl Compressor for StructuredPruning {
    fn name(&self) -> String {
        format!("sp-{}", if self.concat { "concat" } else { "sep" })
    }

    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer {
        let n = layer.n_experts();
        let pi = layer.experts[0].d_inner();
        let dms: Vec<Matrix> = layer.experts.iter().map(|e| e.design_matrix()).collect();
        // Row norms per expert.
        let norms: Vec<Vec<f64>> = dms.iter().map(crate::tensor::linalg::row_norms).collect();
        let keep_rows_per_expert: Vec<Vec<bool>> = if self.concat {
            // Rank all (expert, row) pairs jointly.
            let keep_total = ((ctx.rate * (n * pi) as f64).round() as usize).min(n * pi);
            let mut all: Vec<(usize, usize, f64)> = Vec::with_capacity(n * pi);
            for (k, ns) in norms.iter().enumerate() {
                for (r, &v) in ns.iter().enumerate() {
                    all.push((k, r, v));
                }
            }
            all.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
            let mut keep = vec![vec![false; pi]; n];
            for &(k, r, _) in all.iter().take(keep_total) {
                keep[k][r] = true;
            }
            keep
        } else {
            let keep_k = ((ctx.rate * pi as f64).round() as usize).min(pi);
            norms
                .iter()
                .map(|ns| {
                    let mut idx: Vec<usize> = (0..pi).collect();
                    idx.sort_by(|&a, &b| ns[b].partial_cmp(&ns[a]).unwrap());
                    let mut keep = vec![false; pi];
                    for &r in idx.iter().take(keep_k) {
                        keep[r] = true;
                    }
                    keep
                })
                .collect()
        };
        let experts = layer
            .experts
            .iter()
            .zip(dms.iter().zip(&keep_rows_per_expert))
            .map(|(e, (dm, keep))| {
                let mut pruned = dm.clone();
                let mut kept_rows = 0usize;
                for r in 0..pi {
                    if keep[r] {
                        kept_rows += 1;
                    } else {
                        pruned.row_mut(r).fill(0.0);
                    }
                }
                CompressedExpert {
                    accounted_params: kept_rows * dm.cols,
                    residual: ResidualRepr::Dense(pruned),
                    b2: e.b2.clone(),
                }
            })
            .collect();
        CompressedLayer {
            method: self.name(),
            arch: layer.experts[0].arch,
            d_model: layer.experts[0].d_model(),
            base: None,
            experts,
            expert_map: CompressedLayer::identity_map(n),
            aligns: CompressedLayer::identity_aligns(n, pi),
        }
    }
}

/// Share of nonzero weights in a restored expert — test helper.
pub fn density(e: &ExpertWeights) -> f64 {
    let dm = e.design_matrix();
    dm.data.iter().filter(|v| **v != 0.0).count() as f64 / dm.n_params() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ExpertArch;
    use crate::util::Rng;

    fn layer(seed: u64) -> (MoeLayer, Rng) {
        let mut rng = Rng::new(seed);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, false, false, &mut rng);
        (l, rng)
    }

    #[test]
    fn magnitude_prune_keeps_exact_budget() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(10, 10, 1.0, &mut rng);
        let pruned = magnitude_prune(&m, 25);
        assert_eq!(pruned.data.iter().filter(|v| **v != 0.0).count(), 25);
        // The kept entries are the largest in magnitude.
        let mut mags: Vec<f32> = m.data.iter().map(|v| v.abs()).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let thresh = mags[24];
        for (orig, kept) in m.data.iter().zip(&pruned.data) {
            if orig.abs() > thresh {
                assert_eq!(orig, kept);
            }
        }
    }

    #[test]
    fn magnitude_prune_handles_ties() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        let pruned = magnitude_prune(&m, 4);
        assert_eq!(pruned.data.iter().filter(|v| **v != 0.0).count(), 4);
    }

    #[test]
    fn up_respects_rate() {
        let (l, mut rng) = layer(2);
        let mut ctx = CompressCtx::new(0.25, &mut rng);
        for concat in [true, false] {
            let cl = UnstructuredPruning { concat }.compress(&l, &mut ctx);
            let stored = cl.n_params_stored() as f64;
            let orig = l.expert_params() as f64;
            // b2 (p values/expert) is kept uncompressed, so the stored
            // fraction sits slightly above the nominal rate.
            assert!(
                (stored / orig - 0.25).abs() < 0.035,
                "concat={concat}: stored fraction {}",
                stored / orig
            );
        }
    }

    #[test]
    fn up_concat_beats_sep_on_heterogeneous_experts() {
        // Give one expert much larger weights: concat keeps more of it.
        let (mut l, mut rng) = layer(3);
        for v in l.experts[0].w1.data.iter_mut() {
            *v *= 10.0;
        }
        let mut ctx = CompressCtx::new(0.25, &mut rng);
        let e_concat = UnstructuredPruning { concat: true }.compress(&l, &mut ctx).approx_error(&l);
        let e_sep = UnstructuredPruning { concat: false }.compress(&l, &mut ctx).approx_error(&l);
        assert!(e_concat < e_sep, "concat={e_concat} sep={e_sep}");
    }

    #[test]
    fn sp_zeroes_whole_rows() {
        let (l, mut rng) = layer(4);
        let mut ctx = CompressCtx::new(0.25, &mut rng);
        let cl = StructuredPruning { concat: false }.compress(&l, &mut ctx);
        for k in 0..4 {
            let dm = cl.restore_design(k);
            let nonzero_rows = (0..dm.rows)
                .filter(|&r| dm.row(r).iter().any(|v| *v != 0.0))
                .count();
            assert_eq!(nonzero_rows, 4); // 25 % of 16
        }
        // Restored layer still runs.
        let restored = cl.to_layer(&l);
        let x = Matrix::randn(3, 8, 1.0, &mut rng);
        assert!(restored.forward(&x, None).data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn up_error_lower_than_sp() {
        // Unstructured pruning has strictly more freedom than structured.
        let (l, mut rng) = layer(5);
        let mut ctx = CompressCtx::new(0.25, &mut rng);
        let e_up = UnstructuredPruning { concat: true }.compress(&l, &mut ctx).approx_error(&l);
        let e_sp = StructuredPruning { concat: true }.compress(&l, &mut ctx).approx_error(&l);
        assert!(e_up < e_sp, "up={e_up} sp={e_sp}");
    }

    #[test]
    fn error_decreases_with_rate() {
        let (l, mut rng) = layer(6);
        let mut prev = f64::INFINITY;
        for rate in [0.1, 0.25, 0.5, 0.9] {
            let mut ctx = CompressCtx::new(rate, &mut rng);
            let e = UnstructuredPruning { concat: true }.compress(&l, &mut ctx).approx_error(&l);
            assert!(e <= prev, "rate {rate}: {e} > {prev}");
            prev = e;
        }
    }
}
