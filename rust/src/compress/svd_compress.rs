//! Truncated-SVD compressor (Denton et al. 2014 as used by the paper), in
//! "concat" (SVD of the full design matrix) and "sep" (SVD of each weight
//! matrix separately) variants, with the rank chosen per Appendix A.4 so the
//! factored parameter count matches the retention rate.

use super::formats::{CompressedExpert, CompressedLayer, ResidualRepr};
use super::{CompressCtx, Compressor};
use crate::moe::MoeLayer;
use crate::tensor::svd::jacobi_svd;
use crate::tensor::Matrix;

/// Rank such that `rows·k + k + k·cols ≤ rate · rows·cols` (App. A.4).
pub fn rank_for_rate(rows: usize, cols: usize, rate: f64) -> usize {
    let budget = rate * (rows * cols) as f64;
    let per_rank = (rows + 1 + cols) as f64;
    ((budget / per_rank).floor() as usize).max(1).min(rows.min(cols))
}

/// SVD over a matrix truncated to the rate-matched rank.
pub fn svd_at_rate(m: &Matrix, rate: f64) -> crate::tensor::Svd {
    let k = rank_for_rate(m.rows, m.cols, rate);
    jacobi_svd(m).truncate(k)
}

pub struct SvdCompression {
    /// concat: one SVD of the design matrix per expert. sep: separate SVDs
    /// of W1 / (W3) / W2 (ranks split so the budget matches).
    pub concat: bool,
}

impl Compressor for SvdCompression {
    fn name(&self) -> String {
        format!("svd-{}", if self.concat { "concat" } else { "sep" })
    }

    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer {
        let n = layer.n_experts();
        let pi = layer.experts[0].d_inner();
        let p = layer.experts[0].d_model();
        let experts = layer
            .experts
            .iter()
            .map(|e| {
                let dm = e.design_matrix();
                let (residual, accounted) = if self.concat {
                    let svd = svd_at_rate(&dm, ctx.rate);
                    let params = svd.n_params();
                    (ResidualRepr::LowRank(svd), params)
                } else {
                    // Per-matrix SVDs, reassembled into one design matrix.
                    // Column ranges: w1 [0,p), b1 [p], (w3 (p+1..2p+1], b3), w2^T tail.
                    let mut parts: Vec<(usize, usize)> = vec![(0, p)];
                    let w2_off = if e.w3.is_some() { 2 * p + 2 } else { p + 1 };
                    if e.w3.is_some() {
                        parts.push((p + 1, 2 * p + 1));
                    }
                    parts.push((w2_off, dm.cols));
                    let mut restored = dm.clone();
                    let mut accounted = 0usize;
                    for &(lo, hi) in &parts {
                        let sub = dm.slice_cols(lo, hi);
                        let svd = svd_at_rate(&sub, ctx.rate);
                        accounted += svd.n_params();
                        let rec = svd.reconstruct();
                        for r in 0..pi {
                            restored.row_mut(r)[lo..hi].copy_from_slice(rec.row(r));
                        }
                    }
                    // Bias columns stay exact and are accounted.
                    accounted += pi * (dm.cols - parts.iter().map(|(l, h)| h - l).sum::<usize>());
                    (ResidualRepr::Dense(restored), accounted)
                };
                CompressedExpert {
                    residual,
                    b2: e.b2.clone(),
                    accounted_params: accounted,
                }
            })
            .collect();
        CompressedLayer {
            method: self.name(),
            arch: layer.experts[0].arch,
            d_model: p,
            base: None,
            experts,
            expert_map: CompressedLayer::identity_map(n),
            aligns: CompressedLayer::identity_aligns(n, pi),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ExpertArch;
    use crate::util::Rng;

    #[test]
    fn rank_formula_matches_appendix_a4() {
        // Switch: pI = 4p ⇒ k ≈ s·pI/3 for the [W1|W2ᵀ] matrix of width 2p.
        let p = 64;
        let pi = 4 * p;
        let k = rank_for_rate(pi, 2 * p, 0.25);
        let expected = (0.25 * pi as f64 / 3.0) as usize;
        assert!((k as i64 - expected as i64).abs() <= 1, "k={k} expected≈{expected}");
        // Mixtral: pI = 3.5p ⇒ k ≈ (6/13)·s·pI for width 3p.
        let p = 64;
        let pi = 224;
        let k = rank_for_rate(pi, 3 * p, 0.25);
        let expected = (6.0 / 13.0 * 0.25 * pi as f64) as usize;
        assert!((k as i64 - expected as i64).abs() <= 1, "k={k} expected≈{expected}");
    }

    #[test]
    fn respects_param_budget() {
        let mut rng = Rng::new(1);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 32, 4, 1, false, false, &mut rng);
        let mut ctx = CompressCtx::new(0.25, &mut rng);
        let cl = SvdCompression { concat: true }.compress(&l, &mut ctx);
        let stored = cl.n_params_stored() as f64;
        let orig = l.expert_params() as f64;
        assert!(stored <= orig * 0.27, "stored fraction {}", stored / orig);
    }

    #[test]
    fn error_matches_optimal_rank_k_truncation() {
        // The compressor must achieve exactly the Eckart–Young optimum for
        // its chosen rank (SVD truncation is the best rank-k approximation).
        let mut rng = Rng::new(2);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 2, 1, false, false, &mut rng);
        let mut ctx = CompressCtx::new(0.5, &mut rng);
        let cl = SvdCompression { concat: true }.compress(&l, &mut ctx);
        let dm0 = l.experts[0].design_matrix();
        let k = rank_for_rate(dm0.rows, dm0.cols, 0.5);
        let optimal: f64 = l
            .experts
            .iter()
            .map(|e| crate::tensor::svd::rank_k_error_sq(&e.design_matrix(), k))
            .sum::<f64>()
            / l.experts.len() as f64
            / 16.0;
        let got = cl.approx_error(&l);
        assert!((got - optimal).abs() < 1e-3 * optimal.max(1e-9), "got={got} opt={optimal}");
    }

    #[test]
    fn sep_variant_runs_on_swiglu() {
        let mut rng = Rng::new(3);
        let l = MoeLayer::random(ExpertArch::SwiGlu, 8, 14, 2, 1, true, false, &mut rng);
        let mut ctx = CompressCtx::new(0.3, &mut rng);
        let cl = SvdCompression { concat: false }.compress(&l, &mut ctx);
        let restored = cl.to_layer(&l);
        let x = Matrix::randn(4, 8, 1.0, &mut rng);
        assert!(restored.forward(&x, None).data.iter().all(|v| v.is_finite()));
        assert!(cl.approx_error(&l).is_finite());
    }

    #[test]
    fn error_shrinks_with_rate() {
        let mut rng = Rng::new(4);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 24, 2, 1, false, false, &mut rng);
        let mut prev = f64::INFINITY;
        for rate in [0.1, 0.3, 0.6, 1.0] {
            let mut ctx = CompressCtx::new(rate, &mut rng);
            let e = SvdCompression { concat: true }.compress(&l, &mut ctx).approx_error(&l);
            assert!(e <= prev + 1e-9);
            prev = e;
        }
    }
}
