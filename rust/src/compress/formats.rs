//! Compressed layer representations and restoration (paper Alg. 1 output /
//! Alg. 2 input).
//!
//! Every compression method in this repo — ResMoE and all baselines —
//! produces a [`CompressedLayer`]: an optional shared *center* design
//! matrix, one [`CompressedExpert`] per stored expert, and a router-slot →
//! stored-expert map (non-identity only for merge baselines that reduce the
//! expert count). Restoration (`W_ω + Δ_k`) yields dense [`ExpertWeights`]
//! that drop into the original [`MoeLayer`] unchanged: the router never
//! needs to know the layer was compressed.

use crate::moe::{ExpertArch, ExpertWeights, MoeLayer};
use crate::tensor::{Csr, Matrix, Svd};

/// How one expert's stored matrix (full design matrix or residual) is kept.
#[derive(Debug, Clone)]
pub enum ResidualRepr {
    /// Dense matrix; `accounted_params` on the expert tracks how many
    /// entries the method actually pays for (e.g. structured pruning zeroes
    /// whole rows but stores only the kept ones).
    Dense(Matrix),
    /// Unstructured-pruned matrix in CSR with narrow indices (App. A.7).
    SparseCsr(Csr),
    /// Truncated-SVD factors (App. A.4).
    LowRank(Svd),
}

impl ResidualRepr {
    /// Materialize to a dense matrix.
    pub fn to_dense(&self) -> Matrix {
        match self {
            ResidualRepr::Dense(m) => m.clone(),
            ResidualRepr::SparseCsr(c) => c.to_dense(),
            ResidualRepr::LowRank(s) => s.reconstruct(),
        }
    }

    /// Add into an existing dense matrix (the restore hot path — avoids a
    /// temporary for sparse residuals).
    pub fn add_into(&self, dense: &mut Matrix) {
        match self {
            ResidualRepr::Dense(m) => dense.add_assign(m),
            ResidualRepr::SparseCsr(c) => c.add_to_dense(dense),
            ResidualRepr::LowRank(s) => dense.add_assign(&s.reconstruct()),
        }
    }

    /// Parameters the representation stores.
    pub fn n_params(&self) -> usize {
        match self {
            ResidualRepr::Dense(m) => m.n_params(),
            ResidualRepr::SparseCsr(c) => c.nnz(),
            ResidualRepr::LowRank(s) => s.n_params(),
        }
    }

    /// Bytes the representation occupies (f32 values; sparse index overhead
    /// per its configured width).
    pub fn memory_bytes(&self) -> usize {
        match self {
            ResidualRepr::Dense(m) => m.n_params() * 4,
            ResidualRepr::SparseCsr(c) => c.memory_bytes(),
            ResidualRepr::LowRank(s) => s.n_params() * 4,
        }
    }
}

/// One stored expert.
#[derive(Debug, Clone)]
pub struct CompressedExpert {
    pub residual: ResidualRepr,
    /// Output bias, kept uncompressed (p values; excluded from the design
    /// matrix per Eq. 3).
    pub b2: Vec<f32>,
    /// Parameters the method pays for (≤ `residual.n_params()` for Dense
    /// reprs with structural zeros).
    pub accounted_params: usize,
}

/// A compressed MoE layer.
#[derive(Debug, Clone)]
pub struct CompressedLayer {
    pub method: String,
    pub arch: ExpertArch,
    pub d_model: usize,
    /// Shared center design matrix `W_ω` (barycenter / average / Git
    /// Re-Basin center), `None` for direct methods.
    pub base: Option<Matrix>,
    pub experts: Vec<CompressedExpert>,
    /// Router slot `k` → index into `experts` (identity unless a merge
    /// method reduced the expert count).
    pub expert_map: Vec<usize>,
    /// Per-slot alignment `T_k`: `aligns[k][i] = j` means design row `i` of
    /// the restored expert for slot `k` corresponds to row `j` of the
    /// ORIGINAL expert `k`. Identity for permutation-free methods. Only the
    /// Table-1 error metric needs it (restored experts are function-
    /// equivalent in any row order).
    pub aligns: Vec<Vec<usize>>,
}

impl CompressedLayer {
    /// Identity expert map of size n.
    pub fn identity_map(n: usize) -> Vec<usize> {
        (0..n).collect()
    }

    /// Identity alignments: n slots × pI rows.
    pub fn identity_aligns(n: usize, pi: usize) -> Vec<Vec<usize>> {
        (0..n).map(|_| (0..pi).collect()).collect()
    }

    /// Restore the design matrix for router slot `k` (`W_ω + Δ_k`).
    pub fn restore_design(&self, slot: usize) -> Matrix {
        let e = &self.experts[self.expert_map[slot]];
        match &self.base {
            Some(base) => {
                let mut out = base.clone();
                e.residual.add_into(&mut out);
                out
            }
            None => e.residual.to_dense(),
        }
    }

    /// Restore full expert weights for router slot `k` (Alg. 2 step 1).
    pub fn restore_expert(&self, slot: usize) -> ExpertWeights {
        let e = &self.experts[self.expert_map[slot]];
        let dm = self.restore_design(slot);
        ExpertWeights::from_design_matrix(self.arch, self.d_model, &dm, e.b2.clone())
    }

    /// Materialize a full [`MoeLayer`] with every expert restored — the
    /// offline-eval path (the serving path restores lazily via the
    /// coordinator cache instead).
    pub fn to_layer(&self, original: &MoeLayer) -> MoeLayer {
        let experts = (0..self.expert_map.len())
            .map(|k| self.restore_expert(k))
            .collect();
        MoeLayer {
            router: original.router.clone(),
            experts,
            shared_expert: original.shared_expert.clone(),
        }
    }

    /// Parameters stored for the experts (center + residuals + b2), the
    /// quantity the paper's compression rate is defined over.
    pub fn n_params_stored(&self) -> usize {
        let base = self.base.as_ref().map(|b| b.n_params()).unwrap_or(0);
        base + self
            .experts
            .iter()
            .map(|e| e.accounted_params + e.b2.len())
            .sum::<usize>()
    }

    /// Bytes stored (center dense f32 + per-expert representation bytes).
    pub fn memory_bytes(&self) -> usize {
        let base = self.base.as_ref().map(|b| b.n_params() * 4).unwrap_or(0);
        base + self
            .experts
            .iter()
            .map(|e| {
                let repr = match &e.residual {
                    // Dense with structural zeros pays only for accounted
                    // entries (they are stored contiguously after packing).
                    ResidualRepr::Dense(_) => e.accounted_params * 4,
                    other => other.memory_bytes(),
                };
                repr + e.b2.len() * 4
            })
            .sum::<usize>()
    }

    /// The paper's Table-1 approximation error for this layer:
    /// `ε = 1/N Σ_k ||T_k W_k − Ŵ_k||_F²`, normalized by `pI`.
    pub fn approx_error(&self, original: &MoeLayer) -> f64 {
        let n = self.expert_map.len();
        let pi = original.experts[0].d_inner();
        let mut total = 0.0f64;
        for k in 0..n {
            let aligned = original.experts[k].design_matrix().permute_rows(&self.aligns[k]);
            let restored = self.restore_design(k);
            total += aligned.sq_dist(&restored);
        }
        total / n as f64 / pi as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{sparse::IndexWidth, svd::jacobi_svd};
    use crate::util::Rng;

    fn test_layer(rng: &mut Rng) -> MoeLayer {
        MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, false, false, rng)
    }

    fn dense_identity_compression(layer: &MoeLayer) -> CompressedLayer {
        let experts = layer
            .experts
            .iter()
            .map(|e| {
                let dm = e.design_matrix();
                CompressedExpert {
                    accounted_params: dm.n_params(),
                    residual: ResidualRepr::Dense(dm),
                    b2: e.b2.clone(),
                }
            })
            .collect();
        CompressedLayer {
            method: "identity".into(),
            arch: layer.experts[0].arch,
            d_model: 8,
            base: None,
            experts,
            expert_map: CompressedLayer::identity_map(4),
            aligns: CompressedLayer::identity_aligns(4, 16),
        }
    }

    #[test]
    fn identity_compression_is_lossless() {
        let mut rng = Rng::new(1);
        let layer = test_layer(&mut rng);
        let cl = dense_identity_compression(&layer);
        assert!(cl.approx_error(&layer) < 1e-12);
        let restored = cl.to_layer(&layer);
        let x = Matrix::randn(5, 8, 1.0, &mut rng);
        assert!(layer.forward(&x, None).sq_dist(&restored.forward(&x, None)) < 1e-10);
    }

    #[test]
    fn base_plus_residual_restores_exactly() {
        let mut rng = Rng::new(2);
        let layer = test_layer(&mut rng);
        let dms: Vec<Matrix> = layer.experts.iter().map(|e| e.design_matrix()).collect();
        let base = Matrix::mean_of(&dms.iter().collect::<Vec<_>>());
        let experts = layer
            .experts
            .iter()
            .zip(&dms)
            .map(|(e, dm)| {
                let resid = dm.sub(&base);
                CompressedExpert {
                    accounted_params: resid.n_params(),
                    residual: ResidualRepr::Dense(resid),
                    b2: e.b2.clone(),
                }
            })
            .collect();
        let cl = CompressedLayer {
            method: "avg+dense".into(),
            arch: ExpertArch::Relu,
            d_model: 8,
            base: Some(base),
            experts,
            expert_map: CompressedLayer::identity_map(4),
            aligns: CompressedLayer::identity_aligns(4, 16),
        };
        assert!(cl.approx_error(&layer) < 1e-10);
    }

    #[test]
    fn sparse_and_lowrank_reprs_roundtrip() {
        let mut rng = Rng::new(3);
        let m = Matrix::from_fn(10, 12, |_, _| {
            if rng.uniform() < 0.3 {
                rng.normal()
            } else {
                0.0
            }
        });
        let sp = ResidualRepr::SparseCsr(Csr::from_dense(&m, IndexWidth::U16));
        assert!(sp.to_dense().sq_dist(&m) < 1e-12);
        let lr = ResidualRepr::LowRank(jacobi_svd(&m));
        assert!(lr.to_dense().sq_dist(&m) < 1e-6);
        let mut acc = Matrix::zeros(10, 12);
        sp.add_into(&mut acc);
        assert!(acc.sq_dist(&m) < 1e-12);
    }

    #[test]
    fn merged_map_shares_experts() {
        let mut rng = Rng::new(4);
        let layer = test_layer(&mut rng);
        // Merge 4 experts into 2 (slots 0,1 -> 0; slots 2,3 -> 1).
        let centers = [
            Matrix::mean_of(&[
                &layer.experts[0].design_matrix(),
                &layer.experts[1].design_matrix(),
            ]),
            Matrix::mean_of(&[
                &layer.experts[2].design_matrix(),
                &layer.experts[3].design_matrix(),
            ]),
        ];
        let experts = centers
            .iter()
            .map(|c| CompressedExpert {
                accounted_params: c.n_params(),
                residual: ResidualRepr::Dense(c.clone()),
                b2: vec![0.0; 8],
            })
            .collect();
        let cl = CompressedLayer {
            method: "merge2".into(),
            arch: ExpertArch::Relu,
            d_model: 8,
            base: None,
            experts,
            expert_map: vec![0, 0, 1, 1],
            aligns: CompressedLayer::identity_aligns(4, 16),
        };
        let r0 = cl.restore_design(0);
        let r1 = cl.restore_design(1);
        assert!(r0.sq_dist(&r1) < 1e-12);
        assert!(cl.approx_error(&layer) > 0.0);
        // Stored params = 2 experts, not 4.
        assert_eq!(
            cl.n_params_stored(),
            2 * (16 * 17 + 8)
        );
    }

    #[test]
    fn memory_accounting_prefers_sparse() {
        let mut rng = Rng::new(5);
        let layer = test_layer(&mut rng);
        let dense = dense_identity_compression(&layer);
        // 25 %-density CSR version.
        let experts = layer
            .experts
            .iter()
            .map(|e| {
                let dm = e.design_matrix().map(|v| if v.abs() > 0.15 { v } else { 0.0 });
                let csr = Csr::from_dense(&dm, IndexWidth::U16);
                CompressedExpert {
                    accounted_params: csr.nnz(),
                    residual: ResidualRepr::SparseCsr(csr),
                    b2: e.b2.clone(),
                }
            })
            .collect();
        let sparse = CompressedLayer { experts, ..dense.clone() };
        assert!(sparse.memory_bytes() < dense.memory_bytes());
    }
}
