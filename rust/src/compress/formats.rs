//! Compressed layer representations, restoration (paper Alg. 1 output /
//! Alg. 2 input), and the **fused restore-free forward**.
//!
//! Every compression method in this repo — ResMoE and all baselines —
//! produces a [`CompressedLayer`]: an optional shared *center* design
//! matrix, one [`CompressedExpert`] per stored expert, and a router-slot →
//! stored-expert map (non-identity only for merge baselines that reduce the
//! expert count). Restoration (`W_ω + Δ_k`) yields dense [`ExpertWeights`]
//! that drop into the original [`MoeLayer`] unchanged: the router never
//! needs to know the layer was compressed.
//!
//! The fused path exploits the linearity of Alg. 2 instead of executing it:
//! `x @ Ŵ_kᵀ = x @ W_ωᵀ + x @ Δ_kᵀ`. The center term is identical for
//! every expert of the layer, so [`FusedLayer`] computes it ONCE per batch
//! ([`SharedAct`]) and each expert only pays a per-weight residual
//! correction at O(nnz) (CSR) or O(rank) (SVD) cost — no dense expert is
//! ever materialized. This is the serving coordinator's cache-miss fast
//! path; equivalence with restore-then-dense is property-tested in
//! `rust/tests/prop_invariants.rs`.

use crate::moe::expert::{add_bias_rows, ExpertForward};
use crate::moe::{ExpertArch, ExpertWeights, MoeLayer};
use crate::tensor::kernel;
use crate::tensor::matrix::{matmul_acc_into, matmul_nt_into};
use crate::tensor::sparse::IndexWidth;
use crate::tensor::{Csr, Matrix, QuantCsr, QuantMatrix, Svd};
use crate::util::bytes::{ByteReader, PutLe};
use anyhow::{bail, Result};
use std::sync::Arc;

/// How one expert's stored matrix (full design matrix or residual) is kept.
#[derive(Debug, Clone, PartialEq)]
pub enum ResidualRepr {
    /// Dense matrix; `accounted_params` on the expert tracks how many
    /// entries the method actually pays for (e.g. structured pruning zeroes
    /// whole rows but stores only the kept ones).
    Dense(Matrix),
    /// Unstructured-pruned matrix in CSR with narrow indices (App. A.7).
    SparseCsr(Csr),
    /// Truncated-SVD factors (App. A.4).
    LowRank(Svd),
    /// Int8-quantized variant of any of the above (PR 6): values carry
    /// per-row symmetric scales, served through dequant-fused kernels.
    Quantized(QuantizedRepr),
}

/// The int8 (symmetric, per-row scale) form of a residual. The barycenter,
/// biases, and SVD singular values stay f32 — only the bulk value arrays
/// (dense entries, CSR values, U/Vᵀ factors) drop to one byte per entry.
#[derive(Debug, Clone, PartialEq)]
pub enum QuantizedRepr {
    Dense(QuantMatrix),
    Csr(QuantCsr),
    LowRank { u: QuantMatrix, s: Vec<f32>, vt: QuantMatrix },
}

impl QuantizedRepr {
    /// Dequantize to a dense f32 matrix — the reference the dequant-fused
    /// serve kernels match bitwise.
    pub fn to_dense(&self) -> Matrix {
        match self {
            QuantizedRepr::Dense(q) => q.to_dense(),
            QuantizedRepr::Csr(qc) => qc.to_csr().to_dense(),
            QuantizedRepr::LowRank { u, s, vt } => {
                Svd { u: u.to_dense(), s: s.clone(), vt: vt.to_dense() }.reconstruct()
            }
        }
    }

    /// Stored value-entry count (same as the f32 repr it quantizes).
    pub fn n_params(&self) -> usize {
        match self {
            QuantizedRepr::Dense(q) => q.n_params(),
            QuantizedRepr::Csr(qc) => qc.n_params(),
            QuantizedRepr::LowRank { u, s, vt } => u.n_params() + s.len() + vt.n_params(),
        }
    }

    /// Bytes occupied (1 per code + per-row f32 scales + structural
    /// overhead for CSR indices / f32 singular values).
    pub fn memory_bytes(&self) -> usize {
        match self {
            QuantizedRepr::Dense(q) => q.memory_bytes(),
            QuantizedRepr::Csr(qc) => qc.memory_bytes(),
            QuantizedRepr::LowRank { u, s, vt } => {
                u.memory_bytes() + s.len() * 4 + vt.memory_bytes()
            }
        }
    }

    /// Design-matrix shape of the residual this quantizes.
    pub fn design_shape(&self) -> (usize, usize) {
        match self {
            QuantizedRepr::Dense(q) => (q.rows, q.cols),
            QuantizedRepr::Csr(qc) => (qc.rows, qc.cols),
            QuantizedRepr::LowRank { u, vt, .. } => (u.rows, vt.cols),
        }
    }

    /// Sound per-element bound on |dequantized − original f32 residual|.
    ///
    /// Dense/CSR: directly the `0.5·max_scale` bound of the value array.
    /// Low-rank: the reconstruction `Σ_t s_t·u[·,t]·vt[t,·]` compounds the
    /// factor errors; with `δu = 0.5·max_r scale_u[r]`, `δv_t =
    /// 0.5·scale_vt[t]`, and per-component maxima taken over the
    /// *dequantized* factors inflated by their own δ (≥ the original
    /// maxima), every element's error is within
    /// `Σ_t |s_t|·(umax_t·δv_t + δu·(vmax_t + δv_t))`, accumulated in f64.
    pub fn abs_error_bound(&self) -> f32 {
        match self {
            QuantizedRepr::Dense(q) => q.abs_error_bound(),
            QuantizedRepr::Csr(qc) => qc.abs_error_bound(),
            QuantizedRepr::LowRank { u, s, vt } => {
                let r = s.len();
                let du = 0.5 * u.scales.iter().cloned().fold(0.0f32, f32::max);
                let mut total = 0.0f64;
                for t in 0..r {
                    let dv = 0.5 * vt.scales[t];
                    let umax = (0..u.rows)
                        .map(|i| (u.data[i * u.cols + t].unsigned_abs() as f32) * u.scales[i])
                        .fold(0.0f32, f32::max)
                        + du;
                    let vmax = vt.data[t * vt.cols..(t + 1) * vt.cols]
                        .iter()
                        .map(|&q| (q.unsigned_abs() as f32) * vt.scales[t])
                        .fold(0.0f32, f32::max)
                        + dv;
                    total += s[t].abs() as f64
                        * (umax as f64 * dv as f64 + du as f64 * (vmax as f64 + dv as f64));
                }
                total as f32 * crate::tensor::quant::QUANT_BOUND_SLACK
            }
        }
    }
}

impl ResidualRepr {
    /// Materialize to a dense matrix.
    pub fn to_dense(&self) -> Matrix {
        match self {
            ResidualRepr::Dense(m) => m.clone(),
            ResidualRepr::SparseCsr(c) => c.to_dense(),
            ResidualRepr::LowRank(s) => s.reconstruct(),
            ResidualRepr::Quantized(q) => q.to_dense(),
        }
    }

    /// Add into an existing dense matrix (the restore hot path — avoids a
    /// temporary for sparse residuals).
    pub fn add_into(&self, dense: &mut Matrix) {
        match self {
            ResidualRepr::Dense(m) => dense.add_assign(m),
            ResidualRepr::SparseCsr(c) => c.add_to_dense(dense),
            ResidualRepr::LowRank(s) => dense.add_assign(&s.reconstruct()),
            ResidualRepr::Quantized(q) => dense.add_assign(&q.to_dense()),
        }
    }

    /// Parameters the representation stores.
    pub fn n_params(&self) -> usize {
        match self {
            ResidualRepr::Dense(m) => m.n_params(),
            ResidualRepr::SparseCsr(c) => c.nnz(),
            ResidualRepr::LowRank(s) => s.n_params(),
            ResidualRepr::Quantized(q) => q.n_params(),
        }
    }

    /// Bytes the representation occupies (f32 values; sparse index overhead
    /// per its configured width; int8 + per-row scales when quantized).
    pub fn memory_bytes(&self) -> usize {
        match self {
            ResidualRepr::Dense(m) => m.n_params() * 4,
            ResidualRepr::SparseCsr(c) => c.memory_bytes(),
            ResidualRepr::LowRank(s) => s.n_params() * 4,
            ResidualRepr::Quantized(q) => q.memory_bytes(),
        }
    }

    /// The int8 form of this representation (idempotent). Dense entries,
    /// CSR values, and SVD U/Vᵀ factors quantize; singular values stay f32.
    pub fn quantized(&self) -> ResidualRepr {
        match self {
            ResidualRepr::Dense(m) => {
                ResidualRepr::Quantized(QuantizedRepr::Dense(QuantMatrix::quantize(m)))
            }
            ResidualRepr::SparseCsr(c) => {
                ResidualRepr::Quantized(QuantizedRepr::Csr(QuantCsr::quantize(c)))
            }
            ResidualRepr::LowRank(s) => ResidualRepr::Quantized(QuantizedRepr::LowRank {
                u: QuantMatrix::quantize(&s.u),
                s: s.s.clone(),
                vt: QuantMatrix::quantize(&s.vt),
            }),
            q @ ResidualRepr::Quantized(_) => q.clone(),
        }
    }
}

// ------------------------------------------------- shard wire format
// Binary payloads for the `store` artifact (`RMES`): one matrix per center
// shard, one `CompressedExpert` per residual shard. Little-endian, self-
// delimiting, exact f32 bit round-trip (checked by `expect_done` on decode
// and the pack/load property tests).

fn encode_matrix(m: &Matrix, out: &mut Vec<u8>) {
    out.put_u32(m.rows as u32);
    out.put_u32(m.cols as u32);
    out.put_f32s(&m.data);
}

fn decode_matrix(r: &mut ByteReader) -> Result<Matrix> {
    let rows = r.len()?;
    let cols = r.len()?;
    let n = rows.checked_mul(cols).ok_or_else(|| anyhow::anyhow!("matrix dims overflow"))?;
    Ok(Matrix::from_vec(rows, cols, r.f32s(n)?))
}

/// Wire-encode one bare matrix (the store's center shard payload).
pub fn encode_matrix_shard(m: &Matrix) -> Vec<u8> {
    let mut out = Vec::new();
    encode_matrix(m, &mut out);
    out
}

/// Decode a payload produced by [`encode_matrix_shard`].
pub fn decode_matrix_shard(bytes: &[u8]) -> Result<Matrix> {
    let mut r = ByteReader::new(bytes);
    let m = decode_matrix(&mut r)?;
    r.expect_done()?;
    Ok(m)
}

fn index_width_tag(w: IndexWidth) -> u8 {
    match w {
        IndexWidth::U16 => 2,
        IndexWidth::U32 => 4,
        IndexWidth::U64 => 8,
    }
}

fn index_width_from_tag(t: u8) -> Result<IndexWidth> {
    match t {
        2 => Ok(IndexWidth::U16),
        4 => Ok(IndexWidth::U32),
        8 => Ok(IndexWidth::U64),
        other => bail!("bad sparse index-width tag {other}"),
    }
}

fn encode_qmatrix(q: &QuantMatrix, out: &mut Vec<u8>) {
    out.put_u32(q.rows as u32);
    out.put_u32(q.cols as u32);
    out.put_f32s(&q.scales);
    out.put_i8s(&q.data);
}

fn decode_qmatrix(r: &mut ByteReader) -> Result<QuantMatrix> {
    let rows = r.len()?;
    let cols = r.len()?;
    let n = rows.checked_mul(cols).ok_or_else(|| anyhow::anyhow!("qmatrix dims overflow"))?;
    let scales = r.f32s(rows)?;
    let data = r.i8s(n)?;
    Ok(QuantMatrix { rows, cols, data, scales })
}

impl ResidualRepr {
    /// Stable residual-kind name used in the store's JSON index.
    pub fn kind_name(&self) -> &'static str {
        match self {
            ResidualRepr::Dense(_) => "dense",
            ResidualRepr::SparseCsr(_) => "csr",
            ResidualRepr::LowRank(_) => "svd",
            ResidualRepr::Quantized(QuantizedRepr::Dense(_)) => "q8-dense",
            ResidualRepr::Quantized(QuantizedRepr::Csr(_)) => "q8-csr",
            ResidualRepr::Quantized(QuantizedRepr::LowRank { .. }) => "q8-svd",
        }
    }

    /// Append the wire encoding of this representation.
    pub fn encode(&self, out: &mut Vec<u8>) {
        match self {
            ResidualRepr::Dense(m) => {
                out.put_u8(0);
                encode_matrix(m, out);
            }
            ResidualRepr::SparseCsr(c) => {
                out.put_u8(1);
                out.put_u32(c.rows as u32);
                out.put_u32(c.cols as u32);
                out.put_u8(index_width_tag(c.index_width));
                out.put_u32(c.values.len() as u32);
                out.put_u32s(&c.row_ptr);
                out.put_u32s(&c.col_idx);
                out.put_f32s(&c.values);
            }
            ResidualRepr::LowRank(s) => {
                out.put_u8(2);
                out.put_u32(s.s.len() as u32);
                out.put_f32s(&s.s);
                encode_matrix(&s.u, out);
                encode_matrix(&s.vt, out);
            }
            ResidualRepr::Quantized(q) => {
                out.put_u8(3);
                match q {
                    QuantizedRepr::Dense(qm) => {
                        out.put_u8(0);
                        encode_qmatrix(qm, out);
                    }
                    QuantizedRepr::Csr(qc) => {
                        out.put_u8(1);
                        out.put_u32(qc.rows as u32);
                        out.put_u32(qc.cols as u32);
                        out.put_u8(index_width_tag(qc.index_width));
                        out.put_u32(qc.values.len() as u32);
                        out.put_u32s(&qc.row_ptr);
                        out.put_u32s(&qc.col_idx);
                        out.put_f32s(&qc.scales);
                        out.put_i8s(&qc.values);
                    }
                    QuantizedRepr::LowRank { u, s, vt } => {
                        out.put_u8(2);
                        out.put_u32(s.len() as u32);
                        out.put_f32s(s);
                        encode_qmatrix(u, out);
                        encode_qmatrix(vt, out);
                    }
                }
            }
        }
    }

    /// Decode one representation from the cursor.
    pub fn decode(r: &mut ByteReader) -> Result<ResidualRepr> {
        match r.u8()? {
            0 => Ok(ResidualRepr::Dense(decode_matrix(r)?)),
            1 => {
                let rows = r.len()?;
                let cols = r.len()?;
                let index_width = index_width_from_tag(r.u8()?)?;
                let nnz = r.len()?;
                let row_ptr = r.u32s(rows + 1)?;
                if row_ptr.first().copied() != Some(0)
                    || row_ptr.last().copied() != Some(nnz as u32)
                    || row_ptr.windows(2).any(|w| w[0] > w[1])
                {
                    bail!("csr shard: row_ptr not a monotone 0..={nnz} prefix scan");
                }
                let col_idx = r.u32s(nnz)?;
                if col_idx.iter().any(|&c| c as usize >= cols) {
                    bail!("csr shard: column index out of range (cols {cols})");
                }
                let values = r.f32s(nnz)?;
                Ok(ResidualRepr::SparseCsr(Csr { rows, cols, row_ptr, col_idx, values, index_width }))
            }
            2 => {
                let rank = r.len()?;
                let s = r.f32s(rank)?;
                let u = decode_matrix(r)?;
                let vt = decode_matrix(r)?;
                if u.cols != rank || vt.rows != rank {
                    bail!("svd shard: factor dims disagree with rank {rank}");
                }
                Ok(ResidualRepr::LowRank(Svd { u, s, vt }))
            }
            3 => match r.u8()? {
                0 => Ok(ResidualRepr::Quantized(QuantizedRepr::Dense(decode_qmatrix(r)?))),
                1 => {
                    let rows = r.len()?;
                    let cols = r.len()?;
                    let index_width = index_width_from_tag(r.u8()?)?;
                    let nnz = r.len()?;
                    let row_ptr = r.u32s(rows + 1)?;
                    if row_ptr.first().copied() != Some(0)
                        || row_ptr.last().copied() != Some(nnz as u32)
                        || row_ptr.windows(2).any(|w| w[0] > w[1])
                    {
                        bail!("q8-csr shard: row_ptr not a monotone 0..={nnz} prefix scan");
                    }
                    let col_idx = r.u32s(nnz)?;
                    if col_idx.iter().any(|&c| c as usize >= cols) {
                        bail!("q8-csr shard: column index out of range (cols {cols})");
                    }
                    let scales = r.f32s(rows)?;
                    let values = r.i8s(nnz)?;
                    Ok(ResidualRepr::Quantized(QuantizedRepr::Csr(QuantCsr {
                        rows,
                        cols,
                        row_ptr,
                        col_idx,
                        values,
                        scales,
                        index_width,
                    })))
                }
                2 => {
                    let rank = r.len()?;
                    let s = r.f32s(rank)?;
                    let u = decode_qmatrix(r)?;
                    let vt = decode_qmatrix(r)?;
                    if u.cols != rank || vt.rows != rank {
                        bail!("q8-svd shard: factor dims disagree with rank {rank}");
                    }
                    Ok(ResidualRepr::Quantized(QuantizedRepr::LowRank { u, s, vt }))
                }
                other => bail!("bad quantized residual subtag {other}"),
            },
            other => bail!("bad residual-kind tag {other}"),
        }
    }

    /// Design-matrix shape `(pI, D)` of the expert this residual restores.
    pub fn design_shape(&self) -> (usize, usize) {
        match self {
            ResidualRepr::Dense(m) => (m.rows, m.cols),
            ResidualRepr::SparseCsr(c) => (c.rows, c.cols),
            ResidualRepr::LowRank(s) => (s.u.rows, s.vt.cols),
            ResidualRepr::Quantized(q) => q.design_shape(),
        }
    }
}

/// One stored expert.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedExpert {
    pub residual: ResidualRepr,
    /// Output bias, kept uncompressed (p values; excluded from the design
    /// matrix per Eq. 3).
    pub b2: Vec<f32>,
    /// Parameters the method pays for (≤ `residual.n_params()` for Dense
    /// reprs with structural zeros).
    pub accounted_params: usize,
}

impl CompressedExpert {
    /// Wire-encode this expert as one store shard payload (residual + b2 +
    /// accounted params).
    pub fn encode_shard(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.put_u64(self.accounted_params as u64);
        out.put_u32(self.b2.len() as u32);
        out.put_f32s(&self.b2);
        self.residual.encode(&mut out);
        out
    }

    /// Decode a shard payload produced by [`CompressedExpert::encode_shard`].
    pub fn decode_shard(bytes: &[u8]) -> Result<CompressedExpert> {
        let mut r = ByteReader::new(bytes);
        let accounted_params = r.u64()? as usize;
        let nb2 = r.len()?;
        let b2 = r.f32s(nb2)?;
        let residual = ResidualRepr::decode(&mut r)?;
        r.expect_done()?;
        Ok(CompressedExpert { residual, b2, accounted_params })
    }

    /// In-memory bytes this expert occupies once decoded (the store cache's
    /// paging unit).
    pub fn memory_bytes(&self) -> usize {
        self.residual.memory_bytes() + self.b2.len() * 4
    }
}

/// A compressed MoE layer.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedLayer {
    pub method: String,
    pub arch: ExpertArch,
    pub d_model: usize,
    /// Shared center design matrix `W_ω` (barycenter / average / Git
    /// Re-Basin center), `None` for direct methods.
    pub base: Option<Matrix>,
    pub experts: Vec<CompressedExpert>,
    /// Router slot `k` → index into `experts` (identity unless a merge
    /// method reduced the expert count).
    pub expert_map: Vec<usize>,
    /// Per-slot alignment `T_k`: `aligns[k][i] = j` means design row `i` of
    /// the restored expert for slot `k` corresponds to row `j` of the
    /// ORIGINAL expert `k`. Identity for permutation-free methods. Only the
    /// Table-1 error metric needs it (restored experts are function-
    /// equivalent in any row order).
    pub aligns: Vec<Vec<usize>>,
}

impl CompressedLayer {
    /// Identity expert map of size n.
    pub fn identity_map(n: usize) -> Vec<usize> {
        (0..n).collect()
    }

    /// Identity alignments: n slots × pI rows.
    pub fn identity_aligns(n: usize, pi: usize) -> Vec<Vec<usize>> {
        (0..n).map(|_| (0..pi).collect()).collect()
    }

    /// Restore the design matrix for router slot `k` (`W_ω + Δ_k`).
    pub fn restore_design(&self, slot: usize) -> Matrix {
        self.restore_design_from(&self.experts[self.expert_map[slot]])
    }

    /// `W_ω + Δ` for an expert held OUTSIDE `self.experts` — the store-
    /// backed cache pages residual shards in on demand and restores against
    /// the resident center through this entry.
    pub fn restore_design_from(&self, e: &CompressedExpert) -> Matrix {
        match &self.base {
            Some(base) => {
                let mut out = base.clone();
                e.residual.add_into(&mut out);
                out
            }
            None => e.residual.to_dense(),
        }
    }

    /// Restore full expert weights for router slot `k` (Alg. 2 step 1).
    pub fn restore_expert(&self, slot: usize) -> ExpertWeights {
        self.restore_expert_from(&self.experts[self.expert_map[slot]])
    }

    /// Restore full expert weights from an externally-paged expert (see
    /// [`CompressedLayer::restore_design_from`]).
    pub fn restore_expert_from(&self, e: &CompressedExpert) -> ExpertWeights {
        let dm = self.restore_design_from(e);
        ExpertWeights::from_design_matrix(self.arch, self.d_model, &dm, e.b2.clone())
    }

    /// Materialize a full [`MoeLayer`] with every expert restored — the
    /// offline-eval path (the serving path restores lazily via the
    /// coordinator cache instead).
    pub fn to_layer(&self, original: &MoeLayer) -> MoeLayer {
        let experts = (0..self.expert_map.len())
            .map(|k| self.restore_expert(k))
            .collect();
        MoeLayer {
            router: original.router.clone(),
            experts,
            shared_expert: original.shared_expert.clone(),
        }
    }

    /// Parameters stored for the experts (center + residuals + b2), the
    /// quantity the paper's compression rate is defined over.
    pub fn n_params_stored(&self) -> usize {
        let base = self.base.as_ref().map(|b| b.n_params()).unwrap_or(0);
        base + self
            .experts
            .iter()
            .map(|e| e.accounted_params + e.b2.len())
            .sum::<usize>()
    }

    /// Bytes stored (center dense f32 + per-expert representation bytes).
    pub fn memory_bytes(&self) -> usize {
        let base = self.base.as_ref().map(|b| b.n_params() * 4).unwrap_or(0);
        base + self
            .experts
            .iter()
            .map(|e| {
                let repr = match &e.residual {
                    // Dense with structural zeros pays only for accounted
                    // entries (they are stored contiguously after packing).
                    ResidualRepr::Dense(_) => e.accounted_params * 4,
                    other => other.memory_bytes(),
                };
                repr + e.b2.len() * 4
            })
            .sum::<usize>()
    }

    /// Build the fused (restore-free) forward state: the center expert in
    /// dense form plus per-stored-expert residual pieces. `None` when the
    /// layer has no shared center (direct methods store full matrices, so
    /// "restoring" them is already a plain densification with nothing to
    /// share). Cheap — O(stored bytes) — and cached by the serving
    /// coordinator per block.
    pub fn fused(&self) -> Option<FusedLayer> {
        let base = self.fused_center()?;
        let experts = self.experts.iter().map(|e| e.fused(self.arch, self.d_model)).collect();
        FusedLayer { base, experts, expert_map: self.expert_map.clone() }.into()
    }

    /// Densify ONLY the center expert (`W_ω`, b2 = 0) — the store-backed
    /// cache builds this once per block and pairs it with per-expert fused
    /// pieces paged in on demand, so no full [`FusedLayer`] (which would
    /// need every residual shard) is ever materialized.
    pub fn fused_center(&self) -> Option<ExpertWeights> {
        let base_dm = self.base.as_ref()?;
        let p = self.d_model;
        Some(ExpertWeights::from_design_matrix(self.arch, p, base_dm, vec![0.0; p]))
    }

    /// Restore-free forward for router slot `slot` — convenience entry that
    /// rebuilds the fused state and the shared term for this one call. The
    /// serving path holds a [`FusedLayer`] and shares [`SharedAct`] across
    /// the layer's slots instead.
    pub fn fused_forward(&self, slot: usize, x: &Matrix) -> Option<Matrix> {
        let fl = self.fused()?;
        let shared = fl.shared_act(x);
        Some(fl.forward_slot(slot, x, &shared))
    }

    /// The paper's Table-1 approximation error for this layer:
    /// `ε = 1/N Σ_k ||T_k W_k − Ŵ_k||_F²`, normalized by `pI`.
    pub fn approx_error(&self, original: &MoeLayer) -> f64 {
        let n = self.expert_map.len();
        let pi = original.experts[0].d_inner();
        let mut total = 0.0f64;
        for k in 0..n {
            let aligned = original.experts[k].design_matrix().permute_rows(&self.aligns[k]);
            let restored = self.restore_design(k);
            total += aligned.sq_dist(&restored);
        }
        total / n as f64 / pi as f64
    }
}

// ----------------------------------------------------- fused forward path

/// One weight-block slice of a compressed residual (Δ_k restricted to a
/// design-matrix column range), kept in its structured form so the fused
/// forward applies it without densification.
#[derive(Debug, Clone)]
pub enum FusedPiece {
    /// No stored entries in this block (fully pruned residual).
    Empty,
    /// CSR slice with rebased column indices.
    Sparse(Csr),
    /// Low-rank factors: `u` (pI × r), `s` (r), `vt` (r × w) with `vt`
    /// already sliced to the block's columns. `u`/`s` are Arc-shared by all
    /// pieces of one expert — only the thin `vt` slice is per-piece.
    LowRank { u: Arc<Matrix>, s: Arc<Vec<f32>>, vt: Matrix },
    /// Dense slice (Dense residual reprs / merge baselines).
    Dense(Matrix),
    /// Int8 CSR slice, served through the dequant-fused SpMM kernels.
    QuantSparse(QuantCsr),
    /// Int8 low-rank factors (singular values stay f32); same Arc-sharing
    /// as [`FusedPiece::LowRank`].
    QuantLowRank { u: Arc<QuantMatrix>, s: Arc<Vec<f32>>, vt: QuantMatrix },
    /// Int8 dense slice, served through the dequant-fused GEMM kernels.
    QuantDense(QuantMatrix),
}

impl FusedPiece {
    fn from_csr(c: &Csr, lo: usize, hi: usize) -> FusedPiece {
        let s = c.slice_cols(lo, hi);
        if s.nnz() == 0 {
            FusedPiece::Empty
        } else {
            FusedPiece::Sparse(s)
        }
    }

    fn from_svd(svd: &Svd, u: &Arc<Matrix>, s: &Arc<Vec<f32>>, lo: usize, hi: usize) -> FusedPiece {
        if s.is_empty() {
            return FusedPiece::Empty;
        }
        FusedPiece::LowRank {
            u: Arc::clone(u),
            s: Arc::clone(s),
            vt: svd.vt.slice_cols(lo, hi),
        }
    }

    fn from_dense(m: &Matrix, lo: usize, hi: usize) -> FusedPiece {
        FusedPiece::Dense(m.slice_cols(lo, hi))
    }

    fn from_qcsr(c: &QuantCsr, lo: usize, hi: usize) -> FusedPiece {
        let s = c.slice_cols(lo, hi);
        if s.nnz() == 0 {
            FusedPiece::Empty
        } else {
            FusedPiece::QuantSparse(s)
        }
    }

    fn from_qsvd(
        vt_full: &QuantMatrix,
        u: &Arc<QuantMatrix>,
        s: &Arc<Vec<f32>>,
        lo: usize,
        hi: usize,
    ) -> FusedPiece {
        if s.is_empty() {
            return FusedPiece::Empty;
        }
        FusedPiece::QuantLowRank {
            u: Arc::clone(u),
            s: Arc::clone(s),
            vt: vt_full.slice_cols(lo, hi),
        }
    }

    /// Bytes this piece stores, with Arc-shared low-rank factors excluded
    /// (counted once at the expert level).
    fn piece_bytes(&self) -> usize {
        match self {
            FusedPiece::Empty => 0,
            FusedPiece::Sparse(c) => c.memory_bytes(),
            FusedPiece::LowRank { vt, .. } => vt.n_params() * 4,
            FusedPiece::Dense(m) => m.n_params() * 4,
            FusedPiece::QuantSparse(c) => c.memory_bytes(),
            FusedPiece::QuantLowRank { vt, .. } => vt.memory_bytes(),
            FusedPiece::QuantDense(m) => m.memory_bytes(),
        }
    }

    /// out += x @ selfᵀ — up/gate correction (x: B × w, self: pI × w,
    /// out: B × pI). Quantized pieces dequantize inside the microkernel —
    /// bitwise equal to densifying first under the same kernel kind.
    pub fn apply_nt_acc(&self, x: &Matrix, out: &mut Matrix) {
        match self {
            FusedPiece::Empty => {}
            FusedPiece::Sparse(c) => c.matmul_nt_into(x, out, true),
            FusedPiece::LowRank { u, s, vt } => {
                // x @ (U S Vt)ᵀ = ((x @ Vtᵀ) · s) @ Uᵀ — two thin matmuls.
                let mut t = x.matmul_nt(vt); // B × r
                scale_cols(&mut t, s.as_slice());
                matmul_nt_into(&t, u.as_ref(), out, true);
            }
            FusedPiece::Dense(m) => matmul_nt_into(x, m, out, true),
            FusedPiece::QuantSparse(c) => c.matmul_nt_into(x, out, true),
            FusedPiece::QuantLowRank { u, s, vt } => {
                let mut t = Matrix::zeros(x.rows, s.len());
                vt.matmul_nt_into(x, &mut t, false); // B × r
                scale_cols(&mut t, s.as_slice());
                u.matmul_nt_into(&t, out, true);
            }
            FusedPiece::QuantDense(m) => m.matmul_nt_into(x, out, true),
        }
    }

    /// out += h @ self — down-projection correction (h: B × pI,
    /// self: pI × w, out: B × w).
    pub fn apply_acc(&self, h: &Matrix, out: &mut Matrix) {
        match self {
            FusedPiece::Empty => {}
            FusedPiece::Sparse(c) => c.matmul_acc_into(h, out),
            FusedPiece::LowRank { u, s, vt } => {
                let mut t = h.matmul(u.as_ref()); // B × r
                scale_cols(&mut t, s.as_slice());
                matmul_acc_into(&t, vt, out);
            }
            FusedPiece::Dense(m) => matmul_acc_into(h, m, out),
            FusedPiece::QuantSparse(c) => c.matmul_acc_into(h, out),
            FusedPiece::QuantLowRank { u, s, vt } => {
                let mut t = Matrix::zeros(h.rows, s.len());
                u.matmul_acc_into(h, &mut t); // B × r (t starts at zero)
                scale_cols(&mut t, s.as_slice());
                vt.matmul_acc_into(&t, out);
            }
            FusedPiece::QuantDense(m) => m.matmul_acc_into(h, out),
        }
    }
}

/// Singular-value scaling of the thin low-rank intermediate — dispatched
/// through the kernel layer (exact op: identical bits on either kernel).
fn scale_cols(m: &mut Matrix, s: &[f32]) {
    kernel::scale_cols(m, s);
}

/// A compressed expert split once into per-weight residual pieces —
/// everything the restore-free forward needs, at compressed size (low-rank
/// U/s factors are shared across pieces, CSR pieces total the original
/// nnz).
#[derive(Debug, Clone)]
pub struct FusedExpert {
    /// Δ(W1): pI × p.
    pub d_up: FusedPiece,
    /// Δ(b1): pI.
    pub db1: Vec<f32>,
    /// Δ(W3) / Δ(b3) for gated experts.
    pub d_gate: Option<FusedPiece>,
    pub db3: Option<Vec<f32>>,
    /// The `dm[:, w2_off..]` block, i.e. Δ(W2ᵀ) (pI × p) — applied as
    /// `h @ piece`.
    pub d_down: FusedPiece,
    /// Full (uncompressed) output bias.
    pub b2: Vec<f32>,
}

impl FusedExpert {
    /// Bytes this split representation occupies (Arc-shared low-rank
    /// factors counted once).
    pub fn memory_bytes(&self) -> usize {
        let mut bytes = self.d_up.piece_bytes()
            + self.d_down.piece_bytes()
            + self.d_gate.as_ref().map(|p| p.piece_bytes()).unwrap_or(0)
            + (self.db1.len()
                + self.db3.as_ref().map(|v| v.len()).unwrap_or(0)
                + self.b2.len())
                * 4;
        // The shared U/s factors, once per expert.
        let shared = Self::shared_factor_bytes(&self.d_up);
        bytes += if shared > 0 { shared } else { Self::shared_factor_bytes(&self.d_down) };
        bytes
    }

    /// Bytes of the Arc-shared low-rank factors reachable from one piece.
    fn shared_factor_bytes(p: &FusedPiece) -> usize {
        match p {
            FusedPiece::LowRank { u, s, .. } => (u.n_params() + s.len()) * 4,
            FusedPiece::QuantLowRank { u, s, .. } => u.memory_bytes() + s.len() * 4,
            _ => 0,
        }
    }

    /// Whether any piece is served through the int8 dequant-fused kernels.
    pub fn is_quantized(&self) -> bool {
        fn q(p: &FusedPiece) -> bool {
            matches!(
                p,
                FusedPiece::QuantSparse(_)
                    | FusedPiece::QuantLowRank { .. }
                    | FusedPiece::QuantDense(_)
            )
        }
        q(&self.d_up) || q(&self.d_down) || self.d_gate.as_ref().is_some_and(q)
    }
}

impl CompressedExpert {
    /// Split the stored residual into the per-weight pieces of the fused
    /// forward. `p` is the model width (design-matrix column ranges follow
    /// [`ExpertWeights::design_matrix`]).
    pub fn fused(&self, arch: ExpertArch, p: usize) -> FusedExpert {
        let gated = arch == ExpertArch::SwiGlu;
        let w2_off = if gated { 2 * p + 2 } else { p + 1 };
        match &self.residual {
            ResidualRepr::SparseCsr(c) => FusedExpert {
                d_up: FusedPiece::from_csr(c, 0, p),
                db1: c.col_dense(p),
                d_gate: gated.then(|| FusedPiece::from_csr(c, p + 1, 2 * p + 1)),
                db3: gated.then(|| c.col_dense(2 * p + 1)),
                d_down: FusedPiece::from_csr(c, w2_off, c.cols),
                b2: self.b2.clone(),
            },
            ResidualRepr::LowRank(svd) => {
                // One shared copy of the U/s factors for all three pieces.
                let u = Arc::new(svd.u.clone());
                let s = Arc::new(svd.s.clone());
                FusedExpert {
                    d_up: FusedPiece::from_svd(svd, &u, &s, 0, p),
                    db1: svd_col(svd, p),
                    d_gate: gated
                        .then(|| FusedPiece::from_svd(svd, &u, &s, p + 1, 2 * p + 1)),
                    db3: gated.then(|| svd_col(svd, 2 * p + 1)),
                    d_down: FusedPiece::from_svd(svd, &u, &s, w2_off, svd.vt.cols),
                    b2: self.b2.clone(),
                }
            }
            ResidualRepr::Dense(m) => FusedExpert {
                d_up: FusedPiece::from_dense(m, 0, p),
                db1: m.col(p),
                d_gate: gated.then(|| FusedPiece::from_dense(m, p + 1, 2 * p + 1)),
                db3: gated.then(|| m.col(2 * p + 1)),
                d_down: FusedPiece::from_dense(m, w2_off, m.cols),
                b2: self.b2.clone(),
            },
            ResidualRepr::Quantized(QuantizedRepr::Dense(q)) => FusedExpert {
                d_up: FusedPiece::QuantDense(q.slice_cols(0, p)),
                db1: q.col_dense(p),
                d_gate: gated
                    .then(|| FusedPiece::QuantDense(q.slice_cols(p + 1, 2 * p + 1))),
                db3: gated.then(|| q.col_dense(2 * p + 1)),
                d_down: FusedPiece::QuantDense(q.slice_cols(w2_off, q.cols)),
                b2: self.b2.clone(),
            },
            ResidualRepr::Quantized(QuantizedRepr::Csr(c)) => FusedExpert {
                d_up: FusedPiece::from_qcsr(c, 0, p),
                db1: c.col_dense(p),
                d_gate: gated.then(|| FusedPiece::from_qcsr(c, p + 1, 2 * p + 1)),
                db3: gated.then(|| c.col_dense(2 * p + 1)),
                d_down: FusedPiece::from_qcsr(c, w2_off, c.cols),
                b2: self.b2.clone(),
            },
            ResidualRepr::Quantized(QuantizedRepr::LowRank { u, s, vt }) => {
                let ua = Arc::new(u.clone());
                let sa = Arc::new(s.clone());
                FusedExpert {
                    d_up: FusedPiece::from_qsvd(vt, &ua, &sa, 0, p),
                    db1: qsvd_col(u, s, vt, p),
                    d_gate: gated
                        .then(|| FusedPiece::from_qsvd(vt, &ua, &sa, p + 1, 2 * p + 1)),
                    db3: gated.then(|| qsvd_col(u, s, vt, 2 * p + 1)),
                    d_down: FusedPiece::from_qsvd(vt, &ua, &sa, w2_off, vt.cols),
                    b2: self.b2.clone(),
                }
            }
        }
    }

    /// Whether this expert's residual is stored in the int8 tier.
    pub fn is_quantized(&self) -> bool {
        matches!(self.residual, ResidualRepr::Quantized(_))
    }

    /// Advertised per-element dequantization error bound (0.0 for exact
    /// f32 representations) — carried into the store index as `qerr`.
    pub fn quant_error_bound(&self) -> f32 {
        match &self.residual {
            ResidualRepr::Quantized(q) => q.abs_error_bound(),
            _ => 0.0,
        }
    }
}

/// Column `c` of the reconstructed quantized low-rank matrix, with both
/// factors dequantized: `U_dq · (s ⊙ vt_dq[:, c])` — splits bias deltas out
/// of a q8-svd design matrix (cold path, runs once per fused split).
fn qsvd_col(u: &QuantMatrix, s: &[f32], vt: &QuantMatrix, c: usize) -> Vec<f32> {
    let r = s.len();
    let vtc = vt.col_dense(c);
    (0..u.rows)
        .map(|i| {
            let si = u.scales[i];
            let mut acc = 0.0f32;
            for k in 0..r {
                acc += (u.data[i * u.cols + k] as f32 * si) * s[k] * vtc[k];
            }
            acc
        })
        .collect()
}

/// Column `c` of the reconstructed low-rank matrix: `U · (s ⊙ vt[:, c])`.
fn svd_col(svd: &Svd, c: usize) -> Vec<f32> {
    let r = svd.s.len();
    (0..svd.u.rows)
        .map(|i| {
            let mut acc = 0.0f32;
            for k in 0..r {
                acc += svd.u.at(i, k) * svd.s[k] * svd.vt.at(k, c);
            }
            acc
        })
        .collect()
}

/// The per-batch shared term of the fused forward: `x @ W_ω¹ᵀ + b_ω¹` (and
/// the gate analog) — computed once per layer per batch and reused by every
/// expert the router activates.
///
/// The "batch" here may be a **row-concatenated design matrix spanning
/// several requests** (continuous batching): because the center matmul and
/// every fused piece are row-independent, a shared term built over the
/// concatenated rows and gathered per sub-batch is bit-identical to one
/// built per request — so the center term is computed once per layer per
/// *window*, amortized across every concurrent client (the per-request
/// offsets live in the dispatch groups; see `moe::group_parts` and the
/// serving coordinator's batched hook).
#[derive(Debug, Clone)]
pub struct SharedAct {
    /// B × pI pre-activation from the center's up-projection.
    pub a0: Matrix,
    /// Gate analog for SwiGLU centers.
    pub g0: Option<Matrix>,
}

impl SharedAct {
    /// Rows this shared term covers (the design batch's row count).
    pub fn rows(&self) -> usize {
        self.a0.rows
    }

    /// Rows `rows[i]` gathered into a new (len × pI) pair — aligns the
    /// batch-level shared term with an expert's routed sub-batch.
    pub fn gather(&self, rows: &[usize]) -> SharedAct {
        SharedAct {
            a0: gather_rows(&self.a0, rows),
            g0: self.g0.as_ref().map(|g| gather_rows(g, rows)),
        }
    }
}

fn gather_rows(m: &Matrix, rows: &[usize]) -> Matrix {
    let mut out = Matrix::zeros(rows.len(), m.cols);
    for (i, &r) in rows.iter().enumerate() {
        out.row_mut(i).copy_from_slice(m.row(r));
    }
    out
}

/// Fused (restore-free) forward state for one compressed layer: the center
/// `W_ω` as a dense expert (b2 = 0, shared by every slot) plus per-expert
/// residual pieces.
#[derive(Debug, Clone)]
pub struct FusedLayer {
    pub base: ExpertWeights,
    pub experts: Vec<FusedExpert>,
    pub expert_map: Vec<usize>,
}

impl FusedLayer {
    /// Bytes of this fused state beyond the always-resident
    /// [`CompressedLayer`]: the densified center expert plus the split
    /// residual pieces. The serving cache reports (but does not budget)
    /// this — see `coordinator/cache.rs`.
    pub fn memory_bytes(&self) -> usize {
        self.base.n_params() * 4
            + self.experts.iter().map(|e| e.memory_bytes()).sum::<usize>()
    }

    /// The once-per-batch center term (see [`SharedAct`]).
    pub fn shared_act(&self, x: &Matrix) -> SharedAct {
        center_shared_act(&self.base, x)
    }

    /// Forward router slot `slot` over `x` (B × p), given the shared center
    /// term for the SAME rows. Numerically equals
    /// `restore_expert(slot).forward(x)` up to f32 reassociation.
    pub fn forward_slot(&self, slot: usize, x: &Matrix, shared: &SharedAct) -> Matrix {
        fused_forward_expert(&self.base, &self.experts[self.expert_map[slot]], x, shared)
    }
}

/// The once-per-batch center term against a densified center expert —
/// [`FusedLayer::shared_act`] and the store-backed paged serve path both
/// funnel through here, so the two modes are bit-identical.
pub fn center_shared_act(base: &ExpertWeights, x: &Matrix) -> SharedAct {
    let mut a0 = x.matmul_nt(&base.w1);
    add_bias_rows(&mut a0, &base.b1);
    let g0 = base.w3.as_ref().map(|w3| {
        let mut g = x.matmul_nt(w3);
        add_bias_rows(&mut g, base.b3.as_ref().expect("gated center has b3"));
        g
    });
    SharedAct { a0, g0 }
}

/// Restore-free forward of ONE fused expert against a densified center,
/// given the shared center term for the same rows of `x`. The shared body
/// behind [`FusedLayer::forward_slot`] and the store cache's
/// `Serve::Paged` path.
pub fn fused_forward_expert(
    base: &ExpertWeights,
    e: &FusedExpert,
    x: &Matrix,
    shared: &SharedAct,
) -> Matrix {
    debug_assert_eq!(shared.a0.rows, x.rows);
    let mut h = shared.a0.clone();
    e.d_up.apply_nt_acc(x, &mut h);
    add_bias_rows(&mut h, &e.db1);
    match base.arch {
        ExpertArch::Relu => kernel::relu_inplace(&mut h),
        ExpertArch::SwiGlu => {
            let mut g = shared.g0.clone().expect("gated layer has shared gate term");
            if let Some(piece) = &e.d_gate {
                piece.apply_nt_acc(x, &mut g);
            }
            add_bias_rows(&mut g, e.db3.as_ref().expect("gated expert has db3"));
            kernel::silu_mul(&mut h, &g);
        }
    }
    // out = h @ (W_ω² + Δ²)ᵀ + b2, with the center part dense and the
    // residual part structured.
    let mut out = h.matmul_nt(&base.w2);
    e.d_down.apply_acc(&h, &mut out);
    add_bias_rows(&mut out, &e.b2);
    out
}

/// A `(FusedLayer, slot)` pair viewed as a standalone expert: computes its
/// own shared term per call. This is the [`ExpertForward`] face of the
/// fused path for offline/equivalence use; the serving hot path shares
/// [`SharedAct`] across a layer's slots instead.
pub struct FusedSlot<'a> {
    pub layer: &'a FusedLayer,
    pub slot: usize,
}

impl ExpertForward for FusedSlot<'_> {
    fn expert_forward(&self, x: &Matrix) -> Matrix {
        let shared = self.layer.shared_act(x);
        self.layer.forward_slot(self.slot, x, &shared)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{sparse::IndexWidth, svd::jacobi_svd};
    use crate::util::Rng;

    fn test_layer(rng: &mut Rng) -> MoeLayer {
        MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, false, false, rng)
    }

    fn dense_identity_compression(layer: &MoeLayer) -> CompressedLayer {
        let experts = layer
            .experts
            .iter()
            .map(|e| {
                let dm = e.design_matrix();
                CompressedExpert {
                    accounted_params: dm.n_params(),
                    residual: ResidualRepr::Dense(dm),
                    b2: e.b2.clone(),
                }
            })
            .collect();
        CompressedLayer {
            method: "identity".into(),
            arch: layer.experts[0].arch,
            d_model: 8,
            base: None,
            experts,
            expert_map: CompressedLayer::identity_map(4),
            aligns: CompressedLayer::identity_aligns(4, 16),
        }
    }

    #[test]
    fn identity_compression_is_lossless() {
        let mut rng = Rng::new(1);
        let layer = test_layer(&mut rng);
        let cl = dense_identity_compression(&layer);
        assert!(cl.approx_error(&layer) < 1e-12);
        let restored = cl.to_layer(&layer);
        let x = Matrix::randn(5, 8, 1.0, &mut rng);
        assert!(layer.forward(&x, None).sq_dist(&restored.forward(&x, None)) < 1e-10);
    }

    #[test]
    fn base_plus_residual_restores_exactly() {
        let mut rng = Rng::new(2);
        let layer = test_layer(&mut rng);
        let dms: Vec<Matrix> = layer.experts.iter().map(|e| e.design_matrix()).collect();
        let base = Matrix::mean_of(&dms.iter().collect::<Vec<_>>());
        let experts = layer
            .experts
            .iter()
            .zip(&dms)
            .map(|(e, dm)| {
                let resid = dm.sub(&base);
                CompressedExpert {
                    accounted_params: resid.n_params(),
                    residual: ResidualRepr::Dense(resid),
                    b2: e.b2.clone(),
                }
            })
            .collect();
        let cl = CompressedLayer {
            method: "avg+dense".into(),
            arch: ExpertArch::Relu,
            d_model: 8,
            base: Some(base),
            experts,
            expert_map: CompressedLayer::identity_map(4),
            aligns: CompressedLayer::identity_aligns(4, 16),
        };
        assert!(cl.approx_error(&layer) < 1e-10);
    }

    #[test]
    fn sparse_and_lowrank_reprs_roundtrip() {
        let mut rng = Rng::new(3);
        let m = Matrix::from_fn(10, 12, |_, _| {
            if rng.uniform() < 0.3 {
                rng.normal()
            } else {
                0.0
            }
        });
        let sp = ResidualRepr::SparseCsr(Csr::from_dense(&m, IndexWidth::U16));
        assert!(sp.to_dense().sq_dist(&m) < 1e-12);
        let lr = ResidualRepr::LowRank(jacobi_svd(&m));
        assert!(lr.to_dense().sq_dist(&m) < 1e-6);
        let mut acc = Matrix::zeros(10, 12);
        sp.add_into(&mut acc);
        assert!(acc.sq_dist(&m) < 1e-12);
    }

    #[test]
    fn merged_map_shares_experts() {
        let mut rng = Rng::new(4);
        let layer = test_layer(&mut rng);
        // Merge 4 experts into 2 (slots 0,1 -> 0; slots 2,3 -> 1).
        let centers = [
            Matrix::mean_of(&[
                &layer.experts[0].design_matrix(),
                &layer.experts[1].design_matrix(),
            ]),
            Matrix::mean_of(&[
                &layer.experts[2].design_matrix(),
                &layer.experts[3].design_matrix(),
            ]),
        ];
        let experts = centers
            .iter()
            .map(|c| CompressedExpert {
                accounted_params: c.n_params(),
                residual: ResidualRepr::Dense(c.clone()),
                b2: vec![0.0; 8],
            })
            .collect();
        let cl = CompressedLayer {
            method: "merge2".into(),
            arch: ExpertArch::Relu,
            d_model: 8,
            base: None,
            experts,
            expert_map: vec![0, 0, 1, 1],
            aligns: CompressedLayer::identity_aligns(4, 16),
        };
        let r0 = cl.restore_design(0);
        let r1 = cl.restore_design(1);
        assert!(r0.sq_dist(&r1) < 1e-12);
        assert!(cl.approx_error(&layer) > 0.0);
        // Stored params = 2 experts, not 4.
        assert_eq!(
            cl.n_params_stored(),
            2 * (16 * 17 + 8)
        );
    }

    #[test]
    fn fused_forward_matches_restore_for_every_repr() {
        use crate::compress::resmoe::ResMoE;
        use crate::baselines::quick_compress;
        let mut rng = Rng::new(7);
        for arch in [ExpertArch::Relu, ExpertArch::SwiGlu] {
            let layer = MoeLayer::random(arch, 8, 16, 4, 2, true, false, &mut rng);
            for comp in [ResMoE::up(), ResMoE::svd()] {
                let cl = quick_compress(&comp, &layer, 0.3, 11);
                let fl = cl.fused().expect("resmoe layers have a center");
                let x = Matrix::randn(5, 8, 1.0, &mut rng);
                let shared = fl.shared_act(&x);
                for slot in 0..4 {
                    let want = cl.restore_expert(slot).forward(&x);
                    let got = fl.forward_slot(slot, &x, &shared);
                    assert!(
                        got.sq_dist(&want) < 1e-8,
                        "{arch:?}/{}: slot {slot} dist {}",
                        cl.method,
                        got.sq_dist(&want)
                    );
                    // Convenience entry agrees too.
                    let via = cl.fused_forward(slot, &x).unwrap();
                    assert!(via.sq_dist(&want) < 1e-8);
                }
            }
        }
    }

    #[test]
    fn fused_slot_implements_expert_forward() {
        use crate::baselines::quick_compress;
        use crate::compress::resmoe::ResMoE;
        let mut rng = Rng::new(8);
        let layer = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &layer, 0.25, 3);
        let fl = cl.fused().unwrap();
        let x = Matrix::randn(3, 8, 1.0, &mut rng);
        let via_trait = FusedSlot { layer: &fl, slot: 2 }.expert_forward(&x);
        let dense = cl.restore_expert(2);
        assert!(via_trait.sq_dist(&dense.expert_forward(&x)) < 1e-8);
    }

    #[test]
    fn fused_handles_dense_residual_and_shared_gather() {
        // Dense residual repr (base + dense Δ) exercises the FusedPiece::Dense
        // arms; gather aligns the shared term with a routed sub-batch.
        let mut rng = Rng::new(9);
        let layer = test_layer(&mut rng);
        let dms: Vec<Matrix> = layer.experts.iter().map(|e| e.design_matrix()).collect();
        let base = Matrix::mean_of(&dms.iter().collect::<Vec<_>>());
        let experts = layer
            .experts
            .iter()
            .zip(&dms)
            .map(|(e, dm)| CompressedExpert {
                accounted_params: dm.n_params(),
                residual: ResidualRepr::Dense(dm.sub(&base)),
                b2: e.b2.clone(),
            })
            .collect();
        let cl = CompressedLayer {
            method: "avg+dense".into(),
            arch: ExpertArch::Relu,
            d_model: 8,
            base: Some(base),
            experts,
            expert_map: CompressedLayer::identity_map(4),
            aligns: CompressedLayer::identity_aligns(4, 16),
        };
        let fl = cl.fused().unwrap();
        let x = Matrix::randn(6, 8, 1.0, &mut rng);
        let shared = fl.shared_act(&x);
        let rows = vec![1usize, 4, 5];
        let sub = {
            let mut s = Matrix::zeros(3, 8);
            for (i, &r) in rows.iter().enumerate() {
                s.row_mut(i).copy_from_slice(x.row(r));
            }
            s
        };
        let got = fl.forward_slot(1, &sub, &shared.gather(&rows));
        let want = cl.restore_expert(1).forward(&sub);
        assert!(got.sq_dist(&want) < 1e-8);
    }

    #[test]
    fn fused_forward_over_concatenated_requests_is_bit_identical() {
        // Continuous batching's fused contract: one SharedAct over a
        // row-concatenated design matrix (several requests stacked), then
        // per-sub-batch gathers, must equal per-request shared terms and
        // forwards EXACTLY — same f32 bits, not just within tolerance.
        use crate::baselines::quick_compress;
        use crate::compress::resmoe::ResMoE;
        let mut rng = Rng::new(12);
        for arch in [ExpertArch::Relu, ExpertArch::SwiGlu] {
            let layer = MoeLayer::random(arch, 8, 16, 4, 2, true, false, &mut rng);
            for cl in [
                quick_compress(&ResMoE::up(), &layer, 0.25, 5),
                quick_compress(&ResMoE::svd(), &layer, 0.25, 5),
            ] {
                let fl = cl.fused().unwrap();
                let xa = Matrix::randn(4, 8, 1.0, &mut rng);
                let xb = Matrix::randn(3, 8, 1.0, &mut rng);
                let cat = xa.vcat(&xb);
                let shared_cat = fl.shared_act(&cat);
                assert_eq!(shared_cat.rows(), 7);
                let (sa, sb) = (fl.shared_act(&xa), fl.shared_act(&xb));
                assert_eq!(shared_cat.a0.slice_rows(0, 4).data, sa.a0.data);
                assert_eq!(shared_cat.a0.slice_rows(4, 7).data, sb.a0.data);
                for slot in 0..4 {
                    // Combined sub-batch: all of request A's rows then all
                    // of request B's (offsets 0/4/7) through one call.
                    let rows: Vec<usize> = (0..7).collect();
                    let got = fl.forward_slot(slot, &cat, &shared_cat.gather(&rows));
                    let wa = fl.forward_slot(slot, &xa, &sa);
                    let wb = fl.forward_slot(slot, &xb, &sb);
                    assert_eq!(got.slice_rows(0, 4).data, wa.data, "{arch:?} slot {slot}");
                    assert_eq!(got.slice_rows(4, 7).data, wb.data, "{arch:?} slot {slot}");
                }
            }
        }
    }

    #[test]
    fn fused_empty_residual_equals_center_forward() {
        // Rate 0 prunes every residual entry: the fused forward must reduce
        // to the center expert plus the stored b2.
        use crate::baselines::quick_compress;
        use crate::compress::resmoe::ResMoE;
        let mut rng = Rng::new(10);
        let layer = MoeLayer::random(ExpertArch::SwiGlu, 8, 12, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &layer, 0.0, 4);
        let fl = cl.fused().unwrap();
        for e in &fl.experts {
            assert!(matches!(e.d_up, FusedPiece::Empty));
        }
        let x = Matrix::randn(4, 8, 1.0, &mut rng);
        let shared = fl.shared_act(&x);
        for slot in 0..4 {
            let want = cl.restore_expert(slot).forward(&x);
            let got = fl.forward_slot(slot, &x, &shared);
            assert!(got.sq_dist(&want) < 1e-8);
        }
    }

    #[test]
    fn direct_methods_have_no_fused_path() {
        let mut rng = Rng::new(11);
        let layer = test_layer(&mut rng);
        let cl = dense_identity_compression(&layer); // base: None
        assert!(cl.fused().is_none());
        assert!(cl.fused_forward(0, &Matrix::zeros(2, 8)).is_none());
    }

    #[test]
    fn memory_accounting_prefers_sparse() {
        let mut rng = Rng::new(5);
        let layer = test_layer(&mut rng);
        let dense = dense_identity_compression(&layer);
        // 25 %-density CSR version.
        let experts = layer
            .experts
            .iter()
            .map(|e| {
                let dm = e.design_matrix().map(|v| if v.abs() > 0.15 { v } else { 0.0 });
                let csr = Csr::from_dense(&dm, IndexWidth::U16);
                CompressedExpert {
                    accounted_params: csr.nnz(),
                    residual: ResidualRepr::SparseCsr(csr),
                    b2: e.b2.clone(),
                }
            })
            .collect();
        let sparse = CompressedLayer { experts, ..dense.clone() };
        assert!(sparse.memory_bytes() < dense.memory_bytes());
    }

    #[test]
    fn expert_shard_roundtrips_every_repr_bit_exact() {
        let mut rng = Rng::new(20);
        let dense = Matrix::randn(10, 12, 1.0, &mut rng);
        let sparse = dense.map(|v| if v.abs() > 0.8 { v } else { 0.0 });
        let reprs = vec![
            ResidualRepr::Dense(dense.clone()),
            ResidualRepr::SparseCsr(Csr::from_dense(&sparse, IndexWidth::U16)),
            ResidualRepr::LowRank(jacobi_svd(&dense)),
        ];
        for residual in reprs {
            let e = CompressedExpert {
                accounted_params: residual.n_params(),
                residual,
                b2: (0..7).map(|_| rng.normal()).collect(),
            };
            let bytes = e.encode_shard();
            let back = CompressedExpert::decode_shard(&bytes).unwrap();
            assert_eq!(e, back, "shard roundtrip must be bit-exact");
        }
    }

    #[test]
    fn expert_shard_rejects_truncation_and_trailing() {
        let mut rng = Rng::new(21);
        let m = Matrix::randn(4, 6, 1.0, &mut rng);
        let e = CompressedExpert {
            accounted_params: m.n_params(),
            residual: ResidualRepr::Dense(m),
            b2: vec![1.0, 2.0],
        };
        let bytes = e.encode_shard();
        assert!(CompressedExpert::decode_shard(&bytes[..bytes.len() - 3]).is_err());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(CompressedExpert::decode_shard(&extra).is_err());
        // Unknown residual tag.
        let mut bad = bytes;
        let tag_pos = 8 + 4 + 2 * 4; // accounted u64 + b2 len + b2 values
        bad[tag_pos] = 9;
        assert!(CompressedExpert::decode_shard(&bad).is_err());
    }

    /// Clone of a compressed layer with every residual dropped to int8.
    fn quantize_layer(cl: &CompressedLayer) -> CompressedLayer {
        let experts = cl
            .experts
            .iter()
            .map(|e| CompressedExpert {
                residual: e.residual.quantized(),
                b2: e.b2.clone(),
                accounted_params: e.accounted_params,
            })
            .collect();
        CompressedLayer { experts, ..cl.clone() }
    }

    #[test]
    fn quantized_shard_roundtrips_every_kind_bit_exact() {
        let mut rng = Rng::new(30);
        let dense = Matrix::randn(10, 12, 1.0, &mut rng);
        let sparse = dense.map(|v| if v.abs() > 0.8 { v } else { 0.0 });
        let reprs = vec![
            ResidualRepr::Dense(dense.clone()).quantized(),
            ResidualRepr::SparseCsr(Csr::from_dense(&sparse, IndexWidth::U16)).quantized(),
            ResidualRepr::LowRank(jacobi_svd(&dense)).quantized(),
        ];
        for (residual, kind) in reprs.into_iter().zip(["q8-dense", "q8-csr", "q8-svd"]) {
            assert_eq!(residual.kind_name(), kind);
            // Idempotent: quantizing a quantized repr is a clone.
            assert_eq!(residual.quantized(), residual);
            let e = CompressedExpert {
                accounted_params: residual.n_params(),
                residual,
                b2: (0..7).map(|_| rng.normal()).collect(),
            };
            let bytes = e.encode_shard();
            let back = CompressedExpert::decode_shard(&bytes).unwrap();
            assert_eq!(e, back, "{kind} shard roundtrip must be bit-exact");
            assert!(CompressedExpert::decode_shard(&bytes[..bytes.len() - 2]).is_err());
        }
    }

    #[test]
    fn quantized_restore_within_advertised_bound() {
        let mut rng = Rng::new(31);
        let dense = Matrix::randn(12, 20, 0.5, &mut rng);
        let sparse = dense.map(|v| if v.abs() > 0.4 { v } else { 0.0 });
        // Dense / CSR quantize the stored values directly: compare against
        // the ORIGINAL f32 repr. The low-rank bound is against the f32
        // factors' reconstruction (quantization error only, not SVD error).
        for orig in [
            ResidualRepr::Dense(dense.clone()),
            ResidualRepr::SparseCsr(Csr::from_dense(&sparse, IndexWidth::U16)),
            ResidualRepr::LowRank(jacobi_svd(&dense)),
        ] {
            let q = orig.quantized();
            let bound = match &q {
                ResidualRepr::Quantized(qr) => qr.abs_error_bound(),
                _ => unreachable!(),
            };
            assert!(bound > 0.0 && bound.is_finite(), "bound {bound} out of range");
            let a = orig.to_dense();
            let b = q.to_dense();
            let worst = a
                .data
                .iter()
                .zip(&b.data)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(
                worst <= bound,
                "{}: worst err {worst} exceeds advertised bound {bound}",
                q.kind_name()
            );
            assert_eq!(q.design_shape(), (a.rows, a.cols));
        }
    }

    #[test]
    fn quantized_bytes_well_under_f32() {
        let mut rng = Rng::new(32);
        let dense = Matrix::randn(16, 64, 1.0, &mut rng);
        let d = ResidualRepr::Dense(dense.clone());
        let dq = d.quantized();
        assert!(
            (dq.memory_bytes() as f64) <= 0.35 * d.memory_bytes() as f64,
            "q8-dense {} vs f32 {}",
            dq.memory_bytes(),
            d.memory_bytes()
        );
        let lr = ResidualRepr::LowRank(jacobi_svd(&dense));
        let lrq = lr.quantized();
        assert!(
            (lrq.memory_bytes() as f64) <= 0.35 * lr.memory_bytes() as f64,
            "q8-svd {} vs f32 {}",
            lrq.memory_bytes(),
            lr.memory_bytes()
        );
        // CSR keeps f32-free values but pays full index overhead: smaller
        // than f32 CSR, though not 0.35× (documented caveat).
        let sparse = dense.map(|v| if v.abs() > 0.8 { v } else { 0.0 });
        let sp = ResidualRepr::SparseCsr(Csr::from_dense(&sparse, IndexWidth::U16));
        let spq = sp.quantized();
        assert!(spq.memory_bytes() < sp.memory_bytes());
    }

    #[test]
    fn quantized_fused_forward_matches_quantized_restore() {
        // The fused path over int8 pieces must agree with restore-then-
        // dense over the SAME dequantized residual to f32 reassociation
        // tolerance — quantization error cancels out of this comparison.
        use crate::baselines::quick_compress;
        use crate::compress::resmoe::ResMoE;
        let mut rng = Rng::new(33);
        for arch in [ExpertArch::Relu, ExpertArch::SwiGlu] {
            let layer = MoeLayer::random(arch, 8, 16, 4, 2, true, false, &mut rng);
            for comp in [ResMoE::up(), ResMoE::svd()] {
                let cl = quantize_layer(&quick_compress(&comp, &layer, 0.3, 11));
                assert!(cl.experts.iter().all(|e| e.is_quantized()));
                assert!(cl.experts.iter().all(|e| e.quant_error_bound() > 0.0));
                let fl = cl.fused().expect("resmoe layers have a center");
                let x = Matrix::randn(5, 8, 1.0, &mut rng);
                let shared = fl.shared_act(&x);
                for slot in 0..4 {
                    let want = cl.restore_expert(slot).forward(&x);
                    let got = fl.forward_slot(slot, &x, &shared);
                    assert!(
                        got.sq_dist(&want) < 1e-8,
                        "{arch:?}/{}: slot {slot} dist {}",
                        cl.method,
                        got.sq_dist(&want)
                    );
                }
            }
        }
    }

    #[test]
    fn quantized_rate_zero_residual_is_center_forward() {
        // Rate 0 → empty residuals; quantizing them must stay empty-safe.
        use crate::baselines::quick_compress;
        use crate::compress::resmoe::ResMoE;
        let mut rng = Rng::new(34);
        let layer = MoeLayer::random(ExpertArch::SwiGlu, 8, 12, 4, 2, true, false, &mut rng);
        let cl = quantize_layer(&quick_compress(&ResMoE::up(), &layer, 0.0, 4));
        let fl = cl.fused().unwrap();
        for e in &fl.experts {
            assert!(matches!(e.d_up, FusedPiece::Empty | FusedPiece::QuantSparse(_)));
        }
        let x = Matrix::randn(4, 8, 1.0, &mut rng);
        let shared = fl.shared_act(&x);
        for slot in 0..4 {
            let want = cl.restore_expert(slot).forward(&x);
            let got = fl.forward_slot(slot, &x, &shared);
            assert!(got.sq_dist(&want) < 1e-8);
        }
    }

    #[test]
    fn fused_center_matches_fused_layer_base() {
        use crate::baselines::quick_compress;
        use crate::compress::resmoe::ResMoE;
        let mut rng = Rng::new(22);
        let layer = MoeLayer::random(ExpertArch::SwiGlu, 8, 12, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &layer, 0.25, 5);
        let fl = cl.fused().unwrap();
        let center = cl.fused_center().unwrap();
        assert_eq!(center, fl.base);
        // The free functions agree with the layer methods bit-for-bit.
        let x = Matrix::randn(3, 8, 1.0, &mut rng);
        let sh = center_shared_act(&center, &x);
        let sh2 = fl.shared_act(&x);
        assert_eq!(sh.a0, sh2.a0);
        for slot in 0..4 {
            let via_free =
                fused_forward_expert(&center, &fl.experts[fl.expert_map[slot]], &x, &sh);
            let via_layer = fl.forward_slot(slot, &x, &sh2);
            assert_eq!(via_free, via_layer);
        }
    }
}
