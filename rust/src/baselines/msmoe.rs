//! M-SMoE — the (uncompressed) merging stage of MC-SMoE (Li et al. 2023),
//! "Merge, then Compress": group experts by **routing policy** (dominant =
//! most-used experts, members assigned by router-gate similarity), align
//! each member to its group dominant by permutation matching, and merge
//! with usage-weighted averaging.

use super::{group_by_router_similarity, group_count, merged_layer, usage_scores};
use crate::compress::{CompressCtx, CompressedLayer, Compressor};
use crate::moe::MoeLayer;
use crate::ot::{cost::sq_euclidean, hungarian};
use crate::tensor::Matrix;

pub struct MSmoe;

impl Compressor for MSmoe {
    fn name(&self) -> String {
        "m-smoe".into()
    }

    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer {
        let n = layer.n_experts();
        let pi = layer.experts[0].d_inner();
        let g = group_count(n, ctx.rate);
        let groups = group_by_router_similarity(layer, g, ctx.stats);
        let scores = usage_scores(layer, ctx.stats);
        let dms: Vec<Matrix> = layer.experts.iter().map(|e| e.design_matrix()).collect();
        let mut aligns: Vec<Vec<usize>> = vec![(0..pi).collect(); n];
        let mut centers = Vec::with_capacity(g);
        let mut b2s = Vec::with_capacity(g);
        for members in &groups {
            let dominant = members[0];
            // Align each member to the dominant expert's row order.
            let mut acc = Matrix::zeros(pi, dms[0].cols);
            let mut total_w = 0.0f64;
            let mut b2 = vec![0.0f32; layer.experts[0].d_model()];
            for &k in members {
                let perm: Vec<usize> = if k == dominant {
                    (0..pi).collect()
                } else {
                    let cost = sq_euclidean(&dms[dominant], &dms[k]);
                    hungarian::solve(&cost).row_to_col
                };
                let w = scores[k].max(1e-9);
                acc.axpy(w as f32, &dms[k].permute_rows(&perm));
                for (o, &v) in b2.iter_mut().zip(&layer.experts[k].b2) {
                    *o += w as f32 * v;
                }
                total_w += w;
                aligns[k] = perm;
            }
            centers.push(acc.scale(1.0 / total_w as f32));
            b2s.push(b2.iter().map(|v| v / total_w as f32).collect());
        }
        merged_layer(layer, "m-smoe", &groups, centers, aligns, b2s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::moe::{ExpertArch, ExpertWeights, Router};
    use crate::util::Rng;

    #[test]
    fn group_structure_and_budget() {
        let mut rng = Rng::new(1);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 8, 2, false, false, &mut rng);
        let cl = quick_compress(&MSmoe, &l, 0.25, 1);
        assert_eq!(cl.experts.len(), 2);
        let frac = cl.n_params_stored() as f64 / l.expert_params() as f64;
        assert!(frac < 0.27, "frac={frac}");
    }

    #[test]
    fn alignment_beats_meo_on_permuted_clones() {
        // Experts that are row-permutations of one another: M-SMoE's
        // permutation alignment should merge them near-losslessly while
        // MEO's unaligned average cannot.
        let mut rng = Rng::new(2);
        let base = ExpertWeights::random(ExpertArch::Relu, 8, 16, &mut rng);
        let experts: Vec<ExpertWeights> = (0..4)
            .map(|_| {
                let perm = rng.permutation(16);
                base.permuted(&perm).perturbed(0.01, &mut rng)
            })
            .collect();
        let l = MoeLayer {
            router: Router::random(4, 8, 1, &mut rng),
            experts,
            shared_expert: None,
        };
        // Merge everything into ONE group so grouping differences vanish.
        let e_msmoe = quick_compress(&MSmoe, &l, 0.125, 3).approx_error(&l);
        let e_meo = quick_compress(&crate::baselines::Meo, &l, 0.125, 3).approx_error(&l);
        assert!(
            e_msmoe < 0.5 * e_meo,
            "msmoe={e_msmoe} should be far below meo={e_meo}"
        );
    }

    #[test]
    fn aligns_are_permutations() {
        let mut rng = Rng::new(3);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 8, 2, false, false, &mut rng);
        let cl = quick_compress(&MSmoe, &l, 0.25, 4);
        for a in &cl.aligns {
            let mut s = a.clone();
            s.sort_unstable();
            assert_eq!(s, (0..16).collect::<Vec<_>>());
        }
    }
}
