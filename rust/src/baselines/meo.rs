//! MEO — "Merging Experts into One" (He et al., EMNLP 2023).
//!
//! Merges each expert group by straight parameter averaging, with no
//! permutation alignment: the computational-efficiency-first merge the
//! paper compares against (Tables 2–3).

use super::{group_by_usage_rank, group_count, mean_b2, merged_layer};
use crate::compress::{CompressCtx, CompressedLayer, Compressor};
use crate::moe::MoeLayer;
use crate::tensor::Matrix;

pub struct Meo;

impl Compressor for Meo {
    fn name(&self) -> String {
        "meo".into()
    }

    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer {
        let n = layer.n_experts();
        let pi = layer.experts[0].d_inner();
        let g = group_count(n, ctx.rate);
        let groups = group_by_usage_rank(layer, g, ctx.stats);
        let dms: Vec<Matrix> = layer.experts.iter().map(|e| e.design_matrix()).collect();
        let centers: Vec<Matrix> = groups
            .iter()
            .map(|members| {
                let refs: Vec<&Matrix> = members.iter().map(|&k| &dms[k]).collect();
                Matrix::mean_of(&refs)
            })
            .collect();
        let b2s = groups.iter().map(|m| mean_b2(layer, m)).collect();
        let aligns = CompressedLayer::identity_aligns(n, pi);
        merged_layer(layer, "meo", &groups, centers, aligns, b2s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::moe::ExpertArch;
    use crate::util::Rng;

    #[test]
    fn reduces_to_expected_group_count() {
        let mut rng = Rng::new(1);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 8, 2, false, false, &mut rng);
        let cl = quick_compress(&Meo, &l, 0.25, 1);
        assert_eq!(cl.experts.len(), 2);
        assert_eq!(cl.expert_map.len(), 8);
        // Params stored ≈ 25 % of the original experts.
        let frac = cl.n_params_stored() as f64 / l.expert_params() as f64;
        assert!((frac - 0.25).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn exact_when_experts_identical() {
        let mut rng = Rng::new(2);
        let base = crate::moe::ExpertWeights::random(ExpertArch::Relu, 8, 16, &mut rng);
        let l = MoeLayer {
            router: crate::moe::Router::random(4, 8, 1, &mut rng),
            experts: vec![base.clone(), base.clone(), base.clone(), base],
            shared_expert: None,
        };
        let cl = quick_compress(&Meo, &l, 0.25, 2);
        assert!(cl.approx_error(&l) < 1e-10);
    }

    #[test]
    fn merged_layer_still_runs() {
        let mut rng = Rng::new(3);
        let l = MoeLayer::random(ExpertArch::SwiGlu, 8, 14, 8, 2, true, false, &mut rng);
        let cl = quick_compress(&Meo, &l, 0.25, 3);
        let restored = cl.to_layer(&l);
        let x = Matrix::randn(5, 8, 1.0, &mut rng);
        assert!(restored.forward(&x, None).data.iter().all(|v| v.is_finite()));
    }
}
