//! OT Fusion (Singh & Jaggi, NeurIPS 2020) as an expert-merge baseline,
//! implemented the layer-by-layer way the original prescribes (App. B.2):
//! a free-support Wasserstein barycenter of the **first-layer weights
//! alone** produces the permutations, second layers are pre-aligned with
//! those permutations, and the fused expert is the average of the aligned
//! stacks.
//!
//! The per-layer barycenter iterations are what the paper's §5.5 measures
//! as the >4-day overhead on Mixtral (vs <1 day for ResMoE's single
//! joint-design-matrix barycenter) — `perf_hotpath` reproduces that gap in
//! relative time.

use super::{group_by_usage_rank, group_count, mean_b2, merged_layer};
use crate::compress::{CompressCtx, CompressedLayer, Compressor};
use crate::moe::MoeLayer;
use crate::ot::{free_support_barycenter, BarycenterConfig};
use crate::tensor::Matrix;

pub struct OtFusion;

impl Compressor for OtFusion {
    fn name(&self) -> String {
        "ot-fusion".into()
    }

    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer {
        let n = layer.n_experts();
        let pi = layer.experts[0].d_inner();
        let p = layer.experts[0].d_model();
        let g = group_count(n, ctx.rate);
        let groups = group_by_usage_rank(layer, g, ctx.stats);
        let dms: Vec<Matrix> = layer.experts.iter().map(|e| e.design_matrix()).collect();
        // Column ranges of the per-linear-layer blocks inside the design
        // matrix: first layer(s) = everything before W2^T.
        let w2_off = dms[0].cols - p;
        let mut aligns: Vec<Vec<usize>> = vec![(0..pi).collect(); n];
        let mut centers = Vec::with_capacity(g);
        let cfg = BarycenterConfig::default();
        for members in &groups {
            // Layer 1: barycenter over the [W1|b1(|W3|b3)] blocks only.
            let first_blocks: Vec<Matrix> =
                members.iter().map(|&k| dms[k].slice_cols(0, w2_off)).collect();
            let refs: Vec<&Matrix> = first_blocks.iter().collect();
            let bc1 = free_support_barycenter(&refs, &cfg, ctx.rng);
            // Pre-align each expert's FULL design matrix with T_k from layer
            // 1, then fuse layer 2 with a second barycenter over the aligned
            // W2^T blocks (support already aligned, so this refines within
            // the aligned frame).
            let aligned_full: Vec<Matrix> = members
                .iter()
                .zip(&bc1.perms)
                .map(|(&k, perm)| dms[k].permute_rows(perm))
                .collect();
            let second_blocks: Vec<Matrix> = aligned_full
                .iter()
                .map(|m| m.slice_cols(w2_off, m.cols))
                .collect();
            let refs2: Vec<&Matrix> = second_blocks.iter().collect();
            let bc2 = free_support_barycenter(&refs2, &cfg, ctx.rng);
            // Compose the two permutations per member.
            for ((&k, p1), p2) in members.iter().zip(&bc1.perms).zip(&bc2.perms) {
                aligns[k] = p2.iter().map(|&i| p1[i]).collect();
            }
            // Fused center: mean of fully (twice-)aligned design matrices.
            let fully_aligned: Vec<Matrix> = members
                .iter()
                .map(|&k| dms[k].permute_rows(&aligns[k]))
                .collect();
            centers.push(Matrix::mean_of(&fully_aligned.iter().collect::<Vec<_>>()));
        }
        let b2s = groups.iter().map(|m| mean_b2(layer, m)).collect();
        merged_layer(layer, "ot-fusion", &groups, centers, aligns, b2s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::moe::{ExpertArch, ExpertWeights, Router};
    use crate::util::Rng;

    #[test]
    fn structure_and_budget() {
        let mut rng = Rng::new(1);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 8, 2, false, false, &mut rng);
        let cl = quick_compress(&OtFusion, &l, 0.25, 1);
        assert_eq!(cl.experts.len(), 2);
        assert!(cl.n_params_stored() < l.expert_params() / 3);
    }

    #[test]
    fn merges_permuted_clones_well() {
        let mut rng = Rng::new(2);
        let base = ExpertWeights::random(ExpertArch::Relu, 8, 16, &mut rng);
        let experts: Vec<ExpertWeights> =
            (0..4).map(|_| base.permuted(&rng.permutation(16))).collect();
        let l = MoeLayer {
            router: Router::random(4, 8, 1, &mut rng),
            experts,
            shared_expert: None,
        };
        let cl = quick_compress(&OtFusion, &l, 0.125, 3);
        assert!(cl.approx_error(&l) < 1e-4, "err={}", cl.approx_error(&l));
    }

    #[test]
    fn aligns_are_permutations() {
        let mut rng = Rng::new(3);
        let l = MoeLayer::random(ExpertArch::SwiGlu, 8, 12, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&OtFusion, &l, 0.5, 4);
        for a in &cl.aligns {
            let mut s = a.clone();
            s.sort_unstable();
            assert_eq!(s, (0..12).collect::<Vec<_>>());
        }
    }
}
