//! MLP Fusion (Ai et al. 2025): reduce the intermediate dimension of each
//! expert by clustering its `pI` sub-MLPs into `c = rate·pI` clusters and
//! replacing each sub-MLP by its cluster centroid (`C_kᵀ W̃_k`, App. A.5).
//!
//! Storage per expert: the centroid matrix `W̃` (c × D) plus `pI` narrow
//! cluster indices (accounted in bytes, negligible in params).

use crate::compress::{CompressCtx, CompressedExpert, CompressedLayer, Compressor, ResidualRepr};
use crate::moe::MoeLayer;
use crate::tensor::Matrix;
use crate::util::Rng;

/// Plain k-means on matrix rows with k-means++ seeding.
/// Returns (centroids k×d, assignment per row).
pub fn kmeans_rows(m: &Matrix, k: usize, iters: usize, rng: &mut Rng) -> (Matrix, Vec<usize>) {
    let n = m.rows;
    let d = m.cols;
    let k = k.clamp(1, n);
    // k-means++ seeding.
    let mut centroids = Matrix::zeros(k, d);
    let first = rng.below(n);
    centroids.row_mut(0).copy_from_slice(m.row(first));
    let mut dist2 = vec![f32::INFINITY; n];
    for c in 1..k {
        for r in 0..n {
            let prev = centroids.row(c - 1);
            let dd: f32 = m.row(r).iter().zip(prev).map(|(a, b)| (a - b) * (a - b)).sum();
            dist2[r] = dist2[r].min(dd);
        }
        let next = rng.categorical(&dist2);
        centroids.row_mut(c).copy_from_slice(m.row(next));
    }
    let mut assign = vec![0usize; n];
    for _ in 0..iters {
        // Assignment.
        let mut changed = false;
        for r in 0..n {
            let row = m.row(r);
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..k {
                let dd: f32 = row
                    .iter()
                    .zip(centroids.row(c))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if dd < best_d {
                    best_d = dd;
                    best = c;
                }
            }
            if assign[r] != best {
                assign[r] = best;
                changed = true;
            }
        }
        // Update.
        let mut counts = vec![0usize; k];
        let mut sums = Matrix::zeros(k, d);
        for r in 0..n {
            counts[assign[r]] += 1;
            let dst = sums.row_mut(assign[r]);
            for (o, &v) in dst.iter_mut().zip(m.row(r)) {
                *o += v;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                let dst = centroids.row_mut(c);
                dst.copy_from_slice(sums.row(c));
                for v in dst.iter_mut() {
                    *v /= counts[c] as f32;
                }
            } else {
                // Re-seed empty cluster at a random row.
                let r = rng.below(n);
                centroids.row_mut(c).copy_from_slice(m.row(r));
            }
        }
        if !changed {
            break;
        }
    }
    (centroids, assign)
}

pub struct MlpFusion;

impl Compressor for MlpFusion {
    fn name(&self) -> String {
        "mlp-fusion".into()
    }

    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer {
        let n = layer.n_experts();
        let pi = layer.experts[0].d_inner();
        let c = ((ctx.rate * pi as f64).round() as usize).clamp(1, pi);
        let experts = layer
            .experts
            .iter()
            .map(|e| {
                let dm = e.design_matrix();
                let (centroids, assign) = kmeans_rows(&dm, c, 25, ctx.rng);
                // Reconstruction C^T W̃: every row replaced by its centroid.
                let mut restored = Matrix::zeros(pi, dm.cols);
                for (r, &a) in assign.iter().enumerate() {
                    restored.row_mut(r).copy_from_slice(centroids.row(a));
                }
                CompressedExpert {
                    accounted_params: c * dm.cols,
                    residual: ResidualRepr::Dense(restored),
                    b2: e.b2.clone(),
                }
            })
            .collect();
        CompressedLayer {
            method: self.name(),
            arch: layer.experts[0].arch,
            d_model: layer.experts[0].d_model(),
            base: None,
            experts,
            expert_map: CompressedLayer::identity_map(n),
            aligns: CompressedLayer::identity_aligns(n, pi),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::moe::ExpertArch;

    #[test]
    fn kmeans_partitions_and_converges() {
        let mut rng = Rng::new(1);
        // Two well-separated blobs.
        let m = Matrix::from_fn(20, 3, |r, _| {
            if r < 10 {
                rng.normal_scaled(0.1)
            } else {
                10.0 + rng.normal_scaled(0.1)
            }
        });
        let (centroids, assign) = kmeans_rows(&m, 2, 50, &mut rng);
        // The two halves land in different clusters.
        assert!(assign[..10].iter().all(|&a| a == assign[0]));
        assert!(assign[10..].iter().all(|&a| a == assign[10]));
        assert_ne!(assign[0], assign[10]);
        let d = (centroids.row(0)[0] - centroids.row(1)[0]).abs();
        assert!(d > 5.0);
    }

    #[test]
    fn kmeans_k_equals_n_is_lossless() {
        let mut rng = Rng::new(2);
        let m = Matrix::randn(8, 4, 1.0, &mut rng);
        let (centroids, assign) = kmeans_rows(&m, 8, 30, &mut rng);
        let mut restored = Matrix::zeros(8, 4);
        for (r, &a) in assign.iter().enumerate() {
            restored.row_mut(r).copy_from_slice(centroids.row(a));
        }
        assert!(restored.sq_dist(&m) < 1e-6);
    }

    #[test]
    fn fusion_respects_budget_and_runs() {
        let mut rng = Rng::new(3);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 1, false, false, &mut rng);
        let cl = quick_compress(&MlpFusion, &l, 0.25, 3);
        let frac = cl.n_params_stored() as f64 / l.expert_params() as f64;
        assert!((frac - 0.25).abs() < 0.05, "frac={frac}");
        let restored = cl.to_layer(&l);
        let x = Matrix::randn(3, 8, 1.0, &mut rng);
        assert!(restored.forward(&x, None).data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn error_shrinks_with_more_clusters() {
        let mut rng = Rng::new(4);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 32, 2, 1, false, false, &mut rng);
        let e_low = quick_compress(&MlpFusion, &l, 0.125, 5).approx_error(&l);
        let e_high = quick_compress(&MlpFusion, &l, 0.75, 5).approx_error(&l);
        assert!(e_high < e_low, "high-rate {e_high} vs low-rate {e_low}");
    }
}
