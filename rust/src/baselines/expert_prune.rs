//! Expert pruning (Lu et al. 2024, "Not All Experts are Equal"): drop the
//! least-used experts entirely, keeping `⌈rate·N⌉`. Tokens routed to a
//! dropped expert are redirected to the surviving expert with the most
//! similar router gate (the paper applies this baseline only to Mixtral
//! because the 25 % setting is harsher than the method's native 50 %).

use super::{group_count, usage_scores};
use crate::compress::{CompressCtx, CompressedExpert, CompressedLayer, Compressor, ResidualRepr};
use crate::moe::MoeLayer;

pub struct ExpertPruning;

impl Compressor for ExpertPruning {
    fn name(&self) -> String {
        "expert-pruning".into()
    }

    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer {
        let n = layer.n_experts();
        let pi = layer.experts[0].d_inner();
        let keep = group_count(n, ctx.rate);
        let scores = usage_scores(layer, ctx.stats);
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
        let kept: Vec<usize> = order.iter().copied().take(keep).collect();
        // Redirect dropped slots to the most gate-similar kept expert.
        let gate = &layer.router.w_g;
        let cos = |a: usize, b: usize| -> f64 {
            let (ra, rb) = (gate.row(a), gate.row(b));
            let dot: f32 = ra.iter().zip(rb).map(|(x, y)| x * y).sum();
            let na: f32 = ra.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nb: f32 = rb.iter().map(|x| x * x).sum::<f32>().sqrt();
            (dot / (na * nb + 1e-12)) as f64
        };
        let mut expert_map = vec![0usize; n];
        for k in 0..n {
            if let Some(j) = kept.iter().position(|&ke| ke == k) {
                expert_map[k] = j;
            } else {
                expert_map[k] = (0..keep)
                    .max_by(|&x, &y| cos(k, kept[x]).partial_cmp(&cos(k, kept[y])).unwrap())
                    .unwrap();
            }
        }
        let experts = kept
            .iter()
            .map(|&k| {
                let dm = layer.experts[k].design_matrix();
                CompressedExpert {
                    accounted_params: dm.n_params(),
                    residual: ResidualRepr::Dense(dm),
                    b2: layer.experts[k].b2.clone(),
                }
            })
            .collect();
        CompressedLayer {
            method: self.name(),
            arch: layer.experts[0].arch,
            d_model: layer.experts[0].d_model(),
            base: None,
            experts,
            expert_map,
            aligns: CompressedLayer::identity_aligns(n, pi),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::moe::{ExpertArch, Route, RouterStats};
    use crate::util::Rng;

    #[test]
    fn keeps_most_used_experts() {
        let mut rng = Rng::new(1);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 8, 2, false, false, &mut rng);
        let mut stats = RouterStats::new(8);
        for _ in 0..5 {
            stats.record(&Route { experts: vec![2, 6], weights: vec![0.6, 0.4] });
        }
        let mut rng2 = Rng::new(2);
        let mut ctx = CompressCtx::new(0.25, &mut rng2);
        ctx.stats = Some(&stats);
        let cl = ExpertPruning.compress(&l, &mut ctx);
        assert_eq!(cl.experts.len(), 2);
        // Kept experts restore exactly; 2 and 6 map to themselves.
        assert!(cl.restore_design(2).sq_dist(&l.experts[2].design_matrix()) < 1e-12);
        assert!(cl.restore_design(6).sq_dist(&l.experts[6].design_matrix()) < 1e-12);
    }

    #[test]
    fn dropped_slots_redirect_to_kept() {
        let mut rng = Rng::new(3);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 8, 2, false, false, &mut rng);
        let cl = quick_compress(&ExpertPruning, &l, 0.25, 3);
        assert!(cl.expert_map.iter().all(|&m| m < 2));
        let frac = cl.n_params_stored() as f64 / l.expert_params() as f64;
        assert!((frac - 0.25).abs() < 0.02);
    }

    #[test]
    fn kept_experts_have_zero_error_dropped_positive() {
        let mut rng = Rng::new(4);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 1, false, false, &mut rng);
        let cl = quick_compress(&ExpertPruning, &l, 0.5, 4);
        // Overall error positive (dropped experts approximated by others).
        assert!(cl.approx_error(&l) > 0.0);
    }
}
