//! Baseline compression methods from the paper's evaluation (§5.1):
//! expert merging (M-SMoE, MEO, Git Re-Basin, OT Fusion), expert pruning,
//! and MLP Fusion. Pruning/SVD/Wanda baselines live in [`crate::compress`]
//! since ResMoE shares their machinery.
//!
//! Merge baselines reduce the expert count from `N` to `G = ⌈rate·N⌉`
//! (8 → 2 at the paper's 25 % setting, App. A.3); the router keeps its `N`
//! slots and `expert_map` redirects merged slots to their group center.

pub mod expert_prune;
pub mod git_rebasin;
pub mod meo;
pub mod mlp_fusion;
pub mod msmoe;
pub mod otfusion;

pub use expert_prune::ExpertPruning;
pub use git_rebasin::GitReBasinMerge;
pub use meo::Meo;
pub use mlp_fusion::MlpFusion;
pub use msmoe::MSmoe;
pub use otfusion::OtFusion;

use crate::compress::{CompressCtx, CompressedExpert, CompressedLayer, ResidualRepr};
use crate::moe::{MoeLayer, RouterStats};
use crate::tensor::Matrix;

/// Number of groups for a merge method at retention `rate`.
pub fn group_count(n_experts: usize, rate: f64) -> usize {
    ((rate * n_experts as f64).round() as usize).clamp(1, n_experts)
}

/// Per-expert usage score: router stats if available, otherwise the gate
/// row norm (a data-free proxy).
pub fn usage_scores(layer: &MoeLayer, stats: Option<&RouterStats>) -> Vec<f64> {
    match stats {
        Some(s) if s.tokens > 0 => s.weight_sums.clone(),
        _ => (0..layer.n_experts())
            .map(|k| {
                layer.router.w_g.row(k).iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()
            })
            .collect(),
    }
}

/// Group experts around the `g` highest-usage "dominant" experts, assigning
/// the rest by cosine similarity of their router gate rows (M-SMoE's
/// routing-policy grouping).
pub fn group_by_router_similarity(
    layer: &MoeLayer,
    g: usize,
    stats: Option<&RouterStats>,
) -> Vec<Vec<usize>> {
    let n = layer.n_experts();
    let scores = usage_scores(layer, stats);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
    let dominants: Vec<usize> = order.iter().copied().take(g).collect();
    let gate = &layer.router.w_g;
    let cos = |a: usize, b: usize| -> f64 {
        let (ra, rb) = (gate.row(a), gate.row(b));
        let dot: f32 = ra.iter().zip(rb).map(|(x, y)| x * y).sum();
        let na: f32 = ra.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = rb.iter().map(|x| x * x).sum::<f32>().sqrt();
        (dot / (na * nb + 1e-12)) as f64
    };
    let mut groups: Vec<Vec<usize>> = dominants.iter().map(|&d| vec![d]).collect();
    for k in 0..n {
        if dominants.contains(&k) {
            continue;
        }
        let best = (0..g)
            .max_by(|&x, &y| cos(k, dominants[x]).partial_cmp(&cos(k, dominants[y])).unwrap())
            .unwrap();
        groups[best].push(k);
    }
    groups
}

/// Contiguous usage-ranked groups of (near-)equal size (MEO / Git Re-Basin
/// merge grouping).
pub fn group_by_usage_rank(
    layer: &MoeLayer,
    g: usize,
    stats: Option<&RouterStats>,
) -> Vec<Vec<usize>> {
    let n = layer.n_experts();
    let scores = usage_scores(layer, stats);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
    let size = n.div_ceil(g);
    order.chunks(size).map(|c| c.to_vec()).collect()
}

/// Assemble a merged [`CompressedLayer`] from group centers.
///
/// * `groups[j]` — member slots of group `j`.
/// * `centers[j]` — the group's merged design matrix.
/// * `aligns[k]` — per-slot permutation used by the error metric.
/// * `b2s[j]` — merged output bias.
pub fn merged_layer(
    layer: &MoeLayer,
    method: &str,
    groups: &[Vec<usize>],
    centers: Vec<Matrix>,
    aligns: Vec<Vec<usize>>,
    b2s: Vec<Vec<f32>>,
) -> CompressedLayer {
    let n = layer.n_experts();
    let mut expert_map = vec![usize::MAX; n];
    for (j, members) in groups.iter().enumerate() {
        for &k in members {
            expert_map[k] = j;
        }
    }
    assert!(expert_map.iter().all(|&m| m != usize::MAX), "ungrouped expert");
    let experts = centers
        .into_iter()
        .zip(b2s)
        .map(|(c, b2)| CompressedExpert {
            accounted_params: c.n_params(),
            residual: ResidualRepr::Dense(c),
            b2,
        })
        .collect();
    CompressedLayer {
        method: method.to_string(),
        arch: layer.experts[0].arch,
        d_model: layer.experts[0].d_model(),
        base: None,
        experts,
        expert_map,
        aligns,
    }
}

/// Mean b2 over group members.
pub fn mean_b2(layer: &MoeLayer, members: &[usize]) -> Vec<f32> {
    let p = layer.experts[0].d_model();
    let mut out = vec![0.0f32; p];
    for &k in members {
        for (o, &v) in out.iter_mut().zip(&layer.experts[k].b2) {
            *o += v;
        }
    }
    for o in out.iter_mut() {
        *o /= members.len() as f32;
    }
    out
}

/// Convenience for tests/benches: compress with a default context.
pub fn quick_compress(
    comp: &dyn crate::compress::Compressor,
    layer: &MoeLayer,
    rate: f64,
    seed: u64,
) -> CompressedLayer {
    let mut rng = crate::util::Rng::new(seed);
    let mut ctx = CompressCtx::new(rate, &mut rng);
    comp.compress(layer, &mut ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ExpertArch;
    use crate::util::Rng;

    fn layer(seed: u64) -> MoeLayer {
        let mut rng = Rng::new(seed);
        MoeLayer::random(ExpertArch::Relu, 8, 16, 8, 2, false, false, &mut rng)
    }

    #[test]
    fn group_count_matches_paper() {
        assert_eq!(group_count(8, 0.25), 2); // 8 experts → 2 at 25 %
        assert_eq!(group_count(8, 0.10), 1);
        assert_eq!(group_count(64, 0.25), 16);
    }

    #[test]
    fn router_similarity_groups_partition() {
        let l = layer(1);
        let groups = group_by_router_similarity(&l, 3, None);
        let mut all: Vec<usize> = groups.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
        assert_eq!(groups.len(), 3);
    }

    #[test]
    fn usage_rank_groups_partition() {
        let l = layer(2);
        let groups = group_by_usage_rank(&l, 2, None);
        let mut all: Vec<usize> = groups.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn stats_override_norm_proxy() {
        let l = layer(3);
        let mut stats = RouterStats::new(8);
        // Make expert 5 dominant.
        for _ in 0..10 {
            stats.record(&crate::moe::Route { experts: vec![5], weights: vec![1.0] });
        }
        let groups = group_by_router_similarity(&l, 1, Some(&stats));
        assert_eq!(groups[0][0], 5);
    }
}
