//! Git Re-Basin (Ainsworth et al., ICLR 2023) adapted as an expert-merge
//! baseline, as the paper does (§5.1: "dynamically apply it as a fusion
//! (merging) method").
//!
//! Within each group, experts are aligned to a running center by greedy
//! weight matching computed **layer-by-layer** — here, from the first
//! linear layer only — then averaged. The layer-local view (ignoring the
//! W1/W2 coupling) is precisely the limitation §4.1 argues against.

use super::{group_by_usage_rank, group_count, mean_b2, merged_layer};
use crate::compress::resmoe::git_rebasin_center;
use crate::compress::{CompressCtx, CompressedLayer, Compressor};
use crate::moe::MoeLayer;
use crate::tensor::Matrix;

pub struct GitReBasinMerge;

impl Compressor for GitReBasinMerge {
    fn name(&self) -> String {
        "git-re-basin".into()
    }

    fn compress(&self, layer: &MoeLayer, ctx: &mut CompressCtx) -> CompressedLayer {
        let n = layer.n_experts();
        let pi = layer.experts[0].d_inner();
        let p = layer.experts[0].d_model();
        let g = group_count(n, ctx.rate);
        let groups = group_by_usage_rank(layer, g, ctx.stats);
        let dms: Vec<Matrix> = layer.experts.iter().map(|e| e.design_matrix()).collect();
        let mut aligns: Vec<Vec<usize>> = vec![(0..pi).collect(); n];
        let mut centers = Vec::with_capacity(g);
        for members in &groups {
            let group_dms: Vec<Matrix> = members.iter().map(|&k| dms[k].clone()).collect();
            let (center, perms) = git_rebasin_center(&group_dms, p + 1, 2);
            for (&k, perm) in members.iter().zip(perms) {
                aligns[k] = perm;
            }
            centers.push(center);
        }
        let b2s = groups.iter().map(|m| mean_b2(layer, m)).collect();
        merged_layer(layer, "git-re-basin", &groups, centers, aligns, b2s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::moe::{ExpertArch, ExpertWeights, Router};
    use crate::util::Rng;

    #[test]
    fn structure_and_budget() {
        let mut rng = Rng::new(1);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 8, 2, false, false, &mut rng);
        let cl = quick_compress(&GitReBasinMerge, &l, 0.25, 1);
        assert_eq!(cl.experts.len(), 2);
        let frac = cl.n_params_stored() as f64 / l.expert_params() as f64;
        assert!(frac < 0.27);
    }

    #[test]
    fn recovers_pure_w1_permutations() {
        // If experts differ ONLY by permutation and W2 happens to follow W1
        // (same joint permutation), the W1-only matching suffices.
        let mut rng = Rng::new(2);
        let base = ExpertWeights::random(ExpertArch::Relu, 8, 16, &mut rng);
        let experts: Vec<ExpertWeights> =
            (0..4).map(|_| base.permuted(&rng.permutation(16))).collect();
        let l = MoeLayer {
            router: Router::random(4, 8, 1, &mut rng),
            experts,
            shared_expert: None,
        };
        let cl = quick_compress(&GitReBasinMerge, &l, 0.25, 3);
        assert!(cl.approx_error(&l) < 1e-6, "err={}", cl.approx_error(&l));
    }

    #[test]
    fn degrades_when_w1_uninformative() {
        // Make W1 carry NO matching signal (all rows identical) while W2
        // distinguishes the sub-MLPs; experts are joint permutations of a
        // base. W1-only matching is then blind to the true alignment — the
        // §4.1 layer-by-layer failure mode — while full-design-matrix
        // matching (M-SMoE) recovers it near-exactly.
        let mut rng = Rng::new(3);
        let mut base = ExpertWeights::random(ExpertArch::Relu, 8, 16, &mut rng);
        let shared_row: Vec<f32> = base.w1.row(0).to_vec();
        for r in 0..16 {
            base.w1.row_mut(r).copy_from_slice(&shared_row);
        }
        base.b1 = vec![0.0; 16];
        let experts: Vec<ExpertWeights> =
            (0..4).map(|_| base.permuted(&rng.permutation(16))).collect();
        let l = MoeLayer {
            router: Router::random(4, 8, 1, &mut rng),
            experts,
            shared_expert: None,
        };
        let e_git = quick_compress(&GitReBasinMerge, &l, 0.125, 4).approx_error(&l);
        let e_msmoe = quick_compress(&crate::baselines::MSmoe, &l, 0.125, 4).approx_error(&l);
        assert!(
            e_msmoe < 0.5 * e_git + 1e-9,
            "msmoe={e_msmoe} should be far below git={e_git}"
        );
    }
}
