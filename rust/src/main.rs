//! `resmoe` CLI — the L3 launcher.
//!
//! Subcommands:
//! * `datagen`  — export the synthetic corpus + task datasets (consumed by
//!   the build-time JAX pretrainer; rust is the data source of truth).
//! * `compress` — compress a checkpoint with a named method and report.
//! * `eval`     — PPL + zero-shot metrics for a (compressed) model.
//! * `serve`    — run the serving coordinator demo on a checkpoint.
//! * `pack`     — compress + shard a model/checkpoint into an `RMES`
//!   artifact (one barycenter shard per layer, one residual shard per
//!   expert).
//! * `serve-packed` — serve straight from an `RMES` artifact with
//!   demand-paged expert shards and async prefetch.
//! * `loadgen` — seeded scenario-diverse traffic harness over an `RMES`
//!   artifact: virtual-clock schedules, real engine batches, replayable
//!   fingerprints, `BENCH_scenarios.json` report.

use anyhow::{anyhow, Result};
use resmoe::compress::{compress_model, Compressor};
use resmoe::coordinator::ServerConfig;
use resmoe::data::export::export_datasets;
use resmoe::eval::{self, method_by_name, Assets};
use resmoe::moe::{model_io, ModelConfig};
use resmoe::store;
use resmoe::util::cli::Args;
use resmoe::util::format_bytes;
use resmoe::Rng;
use std::path::{Path, PathBuf};

fn main() {
    let args = Args::from_env(&["verbose", "fast", "pretrained-only"]);
    let result = match args.subcommand.as_deref() {
        Some("datagen") => cmd_datagen(&args),
        Some("compress") => cmd_compress(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("pack") => cmd_pack(&args),
        Some("serve-packed") => cmd_serve_packed(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("table") => cmd_table(&args),
        Some(other) => Err(anyhow!("unknown subcommand '{other}'")),
        None => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "resmoe — ResMoE (KDD'25) reproduction\n\n\
         USAGE: resmoe <subcommand> [options]\n\n\
         SUBCOMMANDS:\n\
           datagen  --out artifacts/data [--seed N]\n\
           compress --model mixtral-mini --method resmoe-up --rate 0.25 [--layers N]\n\
           eval     --model mixtral-mini [--method resmoe-up --rate 0.25]\n\
           serve    --model mixtral-mini [--requests N --batch-max N --metrics-out m.json]\n\
           pack     --model mixtral-mini [--ckpt path.rmw[z]] --method resmoe-up \
--rate 0.25 [--quantize int8] --out model.rmes\n\
           serve-packed --artifact model.rmes [--cache-mb N --requests N --metrics-out m.json]\n\
           loadgen  --artifact model.rmes [--scenario all|zipf09|zipf12|bursty|mixed|\n\
                    slow_reader|multi_tenant --seed N --vworkers N --cache-mb N --out b.json]\n\
           table    --id 1|2|3|4|5|7|10|11|12|fig4\n\n\
         (both serve demos print a final metrics snapshot; --metrics-out writes the\n\
          JSON form consumed by scripts/ci.sh SLO gates. RESMOE_TRACE=<file|stderr>\n\
          emits per-request JSONL stage traces. RESMOE_FAULTS=seed:N,spec:... injects\n\
          deterministic store faults (see util::fault); RESMOE_MAX_QUEUE /\n\
          RESMOE_DEADLINE_MS bound queue depth and per-request deadlines.)\n\
         (tables also regenerate via `cargo bench --bench table1_approx_error` etc.)"
    );
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get_or("out", "artifacts/data"));
    let seed = args.get_u64("seed", eval::DATA_SEED);
    export_datasets(&out, 256, 96, seed)?;
    println!("datagen: wrote corpus + NLU datasets to {}", out.display());
    Ok(())
}

fn parse_model(args: &Args) -> Result<ModelConfig> {
    let name = args.get_or("model", "mixtral-mini");
    ModelConfig::by_name(name).ok_or_else(|| anyhow!("unknown model '{name}'"))
}

fn method_of(args: &Args) -> Result<Box<dyn Compressor>> {
    let name = args.get_or("method", "resmoe-up");
    method_by_name(name).ok_or_else(|| anyhow!("unknown method '{name}'"))
}

fn top_layers_default(cfg: &ModelConfig) -> usize {
    // Paper protocol: top ¾ of Mixtral's layers; top 8 MoE layers of Switch.
    (cfg.moe_layer_indices().len() * 3).div_ceil(4)
}

fn cmd_compress(args: &Args) -> Result<()> {
    let cfg = parse_model(args)?;
    let assets = Assets::load(&cfg);
    if args.flag("pretrained-only") && !assets.pretrained {
        return Err(anyhow!("no pretrained checkpoint for {}", cfg.name));
    }
    let comp = method_of(args)?;
    let rate = args.get_f64("rate", 0.25);
    let layers = args.get_usize("layers", top_layers_default(&cfg));
    let calib = assets.calibration_tokens(cfg.max_seq);
    let mut rng = Rng::new(args.get_u64("seed", 0));
    let t0 = std::time::Instant::now();
    let cm = compress_model(&assets.model, comp.as_ref(), rate, layers, Some(&calib), &mut rng);
    println!(
        "compressed {} with {} at rate {rate} over {} layers in {:.2}s",
        cfg.name,
        cm.report.method,
        cm.report.layers.len(),
        t0.elapsed().as_secs_f64()
    );
    println!(
        "  approx error (Table-1 metric): {:.4}",
        cm.report.mean_approx_error()
    );
    println!(
        "  expert params: {} -> {} ({:.1} %)",
        cm.report.total_params_before(),
        cm.report.total_params_after(),
        100.0 * cm.report.total_params_after() as f64 / cm.report.total_params_before() as f64
    );
    println!(
        "  expert bytes:  {} -> {}",
        format_bytes(cm.report.total_bytes_before()),
        format_bytes(cm.report.total_bytes_after())
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = parse_model(args)?;
    let assets = Assets::load(&cfg);
    println!(
        "model {} ({})",
        cfg.name,
        if assets.pretrained {
            "pretrained checkpoint"
        } else {
            "RANDOM fallback — run `make artifacts`"
        }
    );
    let model = if let Some(method) = args.get("method") {
        let comp =
            method_by_name(method).ok_or_else(|| anyhow!("unknown method '{method}'"))?;
        let rate = args.get_f64("rate", 0.25);
        let layers = args.get_usize("layers", top_layers_default(&cfg));
        let calib = assets.calibration_tokens(cfg.max_seq);
        let mut rng = Rng::new(args.get_u64("seed", 0));
        let cm =
            compress_model(&assets.model, comp.as_ref(), rate, layers, Some(&calib), &mut rng);
        println!(
            "compressed with {method} at rate {rate}: err {:.4}",
            cm.report.mean_approx_error()
        );
        cm.model
    } else {
        assets.model.clone()
    };
    let n = args.get_usize("n", if args.flag("fast") { 50 } else { 200 });
    let ppl = eval::perplexity(&model, &assets.valid, cfg.max_seq);
    println!("  wikitext-analog PPL: {ppl:.3}");
    let lam = eval::lambada_accuracy(&model, &assets.lambada(n));
    println!("  lambada-analog ACC:  {:.2} %", lam * 100.0);
    let piqa = eval::choice_accuracy(&model, &assets.piqa(n));
    println!("  piqa-analog ACC:     {:.2} %", piqa * 100.0);
    let wino = eval::choice_accuracy(&model, &assets.winogrande(n));
    println!("  winogrande-analog:   {:.2} %", wino * 100.0);
    for task in resmoe::data::tasks::NLU_TASKS {
        if model.head(task).is_some() {
            let examples = assets.nlu_test(task, n);
            if let Some(acc) = eval::task_accuracy(&model, task, &examples) {
                println!("  {task} ACC:           {:.2} %", acc * 100.0);
            }
        }
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    use resmoe::eval::tablegen as tg;
    let id = args.get_or("id", args.positional.first().map(|s| s.as_str()).unwrap_or("1"));
    let table = match id {
        "1" => tg::table1(),
        "2" => tg::table2(),
        "3" => tg::table3(),
        "4" => tg::table4(),
        "5" => tg::table5(),
        "7" => tg::table7(),
        "10" => tg::table10(),
        "11" => tg::table11(),
        "12" => tg::table12(),
        "fig4" => tg::fig4(&[0.10, 0.25, 0.50]),
        other => return Err(anyhow!("unknown table id '{other}'")),
    };
    table.print();
    Ok(())
}

fn cmd_pack(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get_or("out", "artifacts/model.rmes"));
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let comp = method_of(args)?;
    let rate = args.get_f64("rate", 0.25);
    let seed = args.get_u64("seed", 0);
    let qarg = args.get_or("quantize", "none");
    let quantize = store::QuantizeMode::parse(qarg)
        .ok_or_else(|| anyhow!("unknown --quantize mode '{qarg}' (use int8 or none)"))?;
    let t0 = std::time::Instant::now();
    let (summary, report) = if let Some(ckpt) = args.get("ckpt") {
        let model = model_io::load_model(Path::new(ckpt))?;
        let top = args.get_usize("layers", top_layers_default(&model.cfg));
        store::pack_model_with(&model, comp.as_ref(), rate, top, None, seed, quantize, &out)?
    } else {
        let cfg = parse_model(args)?;
        let assets = Assets::load(&cfg);
        let top = args.get_usize("layers", top_layers_default(&cfg));
        let calib = assets.calibration_tokens(cfg.max_seq);
        store::pack_model_with(
            &assets.model,
            comp.as_ref(),
            rate,
            top,
            Some(&calib),
            seed,
            quantize,
            &out,
        )?
    };
    println!(
        "packed {} layers / {} expert shards with {} at rate {rate} in {:.2}s",
        summary.n_layers,
        summary.n_expert_shards,
        report.method,
        t0.elapsed().as_secs_f64()
    );
    if summary.quantized_shards > 0 {
        println!(
            "  int8 residual shards: {} (max dequant error bound {:.3e})",
            summary.quantized_shards, summary.max_quant_err
        );
    }
    println!(
        "  artifact: {} ({}) — backbone {} + expert shards {} on disk ({} decoded)",
        summary.path.display(),
        format_bytes(summary.file_bytes as usize),
        format_bytes(summary.backbone_disk_bytes as usize),
        format_bytes(summary.expert_disk_bytes as usize),
        format_bytes(summary.expert_raw_bytes as usize),
    );
    println!(
        "  dense expert bytes before compression: {}",
        format_bytes(report.total_bytes_before())
    );
    Ok(())
}

fn cmd_serve_packed(args: &Args) -> Result<()> {
    let artifact = args
        .get("artifact")
        .map(|s| s.to_string())
        .or_else(|| args.positional.first().cloned())
        .ok_or_else(|| anyhow!("serve-packed needs --artifact <path.rmes>"))?;
    // Window-policy defaults come from RESMOE_BATCH / RESMOE_LINGER_US;
    // explicit flags win.
    let env = ServerConfig::from_env();
    let sc = ServerConfig {
        batch_max: args.get_usize("batch-max", env.batch_max),
        batch_wait_us: args.get_u64("batch-wait-us", env.batch_wait_us),
        cache_budget_bytes: args.get_usize("cache-mb", 64) * 1024 * 1024,
        workers: args.get_usize("workers", 2),
        ..env
    };
    let n_requests = args.get_usize("requests", 64);
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    resmoe::coordinator::demo::run_packed_demo(
        Path::new(&artifact),
        sc,
        n_requests,
        metrics_out.as_deref(),
    )
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let artifact = args
        .get("artifact")
        .map(|s| s.to_string())
        .or_else(|| args.positional.first().cloned())
        .ok_or_else(|| anyhow!("loadgen needs --artifact <path.rmes>"))?;
    let scenario = args.get_or("scenario", "all").to_string();
    let seed = args.get_u64("seed", 7);
    let vworkers = args.get_usize("vworkers", 4);
    let budget = args.get_usize("cache-mb", 4) * 1024 * 1024;
    let (doc, runs) = resmoe::loadgen::run_all(
        Path::new(&artifact),
        budget,
        &scenario,
        seed,
        vworkers,
    )?;
    for r in &runs {
        println!(
            "loadgen[{}]: {} arrivals, {} executed, {} shed (admit {} / deadline {}), \
             {} errors, {} degraded, fp {:016x}",
            r.name,
            r.arrivals,
            r.executed,
            r.shed_admission + r.shed_deadline,
            r.shed_admission,
            r.shed_deadline,
            r.errors,
            r.degraded,
            r.responses_fp,
        );
    }
    if let Some(out) = args.get("out").or_else(|| args.get("metrics-out")) {
        let path = PathBuf::from(out);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("loadgen: wrote {}", path.display());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = parse_model(args)?;
    let assets = Assets::load(&cfg);
    let env = ServerConfig::from_env();
    let sc = ServerConfig {
        batch_max: args.get_usize("batch-max", env.batch_max),
        batch_wait_us: args.get_u64("batch-wait-us", env.batch_wait_us),
        cache_budget_bytes: args.get_usize("cache-mb", 64) * 1024 * 1024,
        workers: args.get_usize("workers", 2),
        ..env
    };
    let n_requests = args.get_usize("requests", 64);
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    resmoe::coordinator::demo::run_demo(&assets, sc, n_requests, metrics_out.as_deref())
}
