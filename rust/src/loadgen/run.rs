//! Engine-backed scenario execution: take the pure virtual-clock replay
//! from [`super::schedule`] and drive every surviving window through real
//! [`Engine::handle_batch`] calls, recording the *virtual* latencies on
//! the engine's own observability registry (PR 7) so every counter,
//! histogram, and snapshot diff gate applies unchanged to synthetic
//! traffic.
//!
//! Determinism contract: prefetch is disabled on every engine, windows
//! execute sequentially in formation order, and latencies come from the
//! virtual clock — so a fixed seed yields bit-identical schedules,
//! responses, and counter snapshots regardless of `--vworkers` or host
//! load. `vworkers` only parameterizes a separately-reported pool-latency
//! model; it never influences a decision.

use super::scenario::{Scenario, GEN_NEW_TOKENS};
use super::schedule::{
    self, fnv1a, fnv1a_u64, percentile_us, schedule_fingerprint, Event, Replay, FNV_OFFSET,
};
use crate::coordinator::{Engine, FlushReason, Request, Response, ServerStats};
use crate::store::ExpertStore;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// Classification head the mixed scenario targets. Packed `RMES` artifacts
/// carry no task heads, so Classify folds to Score there (reported as
/// `classify_disabled`).
pub const CLASSIFY_TASK: &str = "nli";

/// A set of engines (one per tenant) sharing one artifact store.
pub struct Fleet {
    pub engines: Vec<Engine>,
}

impl Fleet {
    /// Open `tenants` engines over one shared `RMES` store — independent
    /// caches and registries, contended artifact reads. Prefetch is
    /// disabled on every engine (determinism contract).
    pub fn from_artifact(
        artifact: &Path,
        cache_budget_bytes: usize,
        tenants: usize,
    ) -> Result<Fleet> {
        let store = Arc::new(ExpertStore::open(artifact)?);
        let mut engines = Vec::new();
        for t in 0..tenants.max(1) {
            let mut e = Engine::from_shared_store(Arc::clone(&store), cache_budget_bytes)?;
            e.disable_prefetch();
            e.set_tenant(&tenant_name(t, tenants));
            engines.push(e);
        }
        Ok(Fleet { engines })
    }

    /// Wrap caller-supplied engines (tests build monolithic
    /// [`Engine::compressed`] fleets with no artifact on disk). Prefetch
    /// is disabled and tenants are tagged here too.
    pub fn from_engines(mut engines: Vec<Engine>) -> Fleet {
        let n = engines.len();
        for (t, e) in engines.iter_mut().enumerate() {
            e.disable_prefetch();
            if e.tenant().is_none() {
                e.set_tenant(&tenant_name(t, n));
            }
        }
        Fleet { engines }
    }
}

fn tenant_name(t: usize, tenants: usize) -> String {
    if tenants > 1 {
        format!("tenant-{}", (b'a' + (t % 26) as u8) as char)
    } else {
        "default".to_string()
    }
}

/// One executed scenario: the JSON report plus the raw numbers the
/// property tests and gates pin.
pub struct ScenarioRun {
    pub name: String,
    pub doc: Json,
    pub schedule_fp: u64,
    pub responses_fp: u64,
    pub counters_fp: u64,
    pub arrivals: u64,
    pub executed: u64,
    pub shed_admission: u64,
    pub shed_deadline: u64,
    pub errors: u64,
    pub degraded: u64,
}

fn make_request(ev: &Event, vocab: usize, classify_enabled: bool) -> Request {
    let tok = (ev.profile as usize % vocab) as u32;
    match ev.kind {
        1 => Request::Generate {
            prompt: vec![tok; ev.len.min(6) as usize],
            max_new: GEN_NEW_TOKENS as usize,
        },
        2 if classify_enabled => Request::Classify {
            task: CLASSIFY_TASK.to_string(),
            tokens: vec![tok; ev.len as usize],
        },
        _ => Request::Score { tokens: vec![tok; ev.len as usize] },
    }
}

/// Fold a response into an FNV fingerprint (tag byte per variant, then
/// the payload bit-exactly — f64 scores via `to_bits`).
fn fold_response(mut h: u64, r: &Response) -> u64 {
    match r {
        Response::Score(s) => {
            h = fnv1a_u64(h, 1);
            fnv1a_u64(h, s.to_bits())
        }
        Response::Generate(toks) => {
            h = fnv1a_u64(h, 2);
            h = fnv1a_u64(h, toks.len() as u64);
            toks.iter().fold(h, |h, &t| fnv1a_u64(h, t as u64))
        }
        Response::Classify(c) => {
            h = fnv1a_u64(h, 3);
            fnv1a_u64(h, *c as u64)
        }
        Response::Metrics(s) => {
            h = fnv1a_u64(h, 4);
            fnv1a(h, s.as_bytes())
        }
        Response::Error(e) => {
            h = fnv1a_u64(h, 5);
            fnv1a(h, e.as_bytes())
        }
        Response::Degraded(inner) => {
            h = fnv1a_u64(h, 6);
            fold_response(h, inner)
        }
        Response::Overloaded(m) => {
            h = fnv1a_u64(h, 7);
            fnv1a(h, m.as_bytes())
        }
    }
}

/// The pool-latency model: replay the already-decided windows over `k`
/// wall workers (earliest-free pickup) and report what request latency
/// would have looked like. Purely observational — decisions (membership,
/// sheds, ordering) are fixed upstream, so this never breaks replay.
fn pool_latencies(rp: &Replay, k: usize) -> Vec<u64> {
    let k = k.max(1);
    let mut free_at = vec![0u64; k];
    let mut out = Vec::new();
    for w in &rp.windows {
        if w.live.is_empty() {
            continue;
        }
        let (slot, &free) = free_at
            .iter()
            .enumerate()
            .min_by_key(|&(_, &f)| f)
            .expect("k >= 1");
        let start = w.formed_us.max(free);
        let completion = start + w.dur_us;
        free_at[slot] = completion;
        for &idx in &w.live {
            out.push(completion.saturating_sub(rp.arrival_us[idx]));
        }
    }
    out
}

fn ms(us: Option<u64>) -> Json {
    match us {
        Some(v) => Json::num(v as f64 / 1000.0),
        None => Json::Null,
    }
}

/// Execute one scenario against a fleet. The fleet must be fresh (its
/// registries zeroed) for per-scenario counters and fingerprints to make
/// sense; `run_all` builds one per scenario.
pub fn run_scenario(
    fleet: &Fleet,
    sc: &Scenario,
    seed: u64,
    vworkers: usize,
) -> Result<ScenarioRun> {
    if fleet.engines.len() != sc.tenants.max(1) {
        return Err(anyhow!(
            "scenario '{}' wants {} tenant engine(s), fleet has {}",
            sc.name,
            sc.tenants.max(1),
            fleet.engines.len()
        ));
    }
    let events = schedule::generate(sc, seed);
    let rp = schedule::replay(sc, &events);
    let schedule_fp = schedule_fingerprint(&events);

    let vocab = fleet
        .engines
        .iter()
        .map(|e| e.model().cfg.vocab_size)
        .min()
        .unwrap_or(1)
        .max(1);
    let classify_enabled = fleet
        .engines
        .iter()
        .all(|e| e.model().head(CLASSIFY_TASK).is_some());

    let stats: Vec<ServerStats> =
        fleet.engines.iter().map(|e| ServerStats::new(e.registry())).collect();

    // Admission sheds happen at arrival time, before any window forms.
    for &idx in &rp.admit_shed {
        stats[events[idx].tenant as usize].record_shed();
    }

    let mut responses_fp = FNV_OFFSET;
    let mut errors = 0u64;
    let mut degraded = 0u64;
    let mut executed = 0u64;
    let mut live_tokens = 0u64;
    let mut windows_by_reason = [0u64; 3];
    let mut occupancy_sum = 0u64;
    let wall_start = Instant::now();
    for w in &rp.windows {
        let engine = &fleet.engines[w.tenant as usize];
        let st = &stats[w.tenant as usize];
        engine.note_flush(w.reason, w.waited_us);
        windows_by_reason[match w.reason {
            FlushReason::Full => 0,
            FlushReason::Linger => 1,
            FlushReason::Closed => 2,
        }] += 1;
        for _ in &w.shed {
            st.record_shed();
        }
        if w.live.is_empty() {
            continue;
        }
        let reqs: Vec<Request> = w
            .live
            .iter()
            .map(|&i| make_request(&events[i], vocab, classify_enabled))
            .collect();
        let resps = engine.handle_batch(&reqs);
        let tokens: u64 = w.live.iter().map(|&i| events[i].tokens()).sum();
        st.record_batch(resps.len(), tokens);
        occupancy_sum += resps.len() as u64;
        live_tokens += tokens;
        executed += resps.len() as u64;
        for (&idx, r) in w.live.iter().zip(&resps) {
            let lat = rp.latency_us[idx]
                .ok_or_else(|| anyhow!("live request {idx} has no virtual latency"))?;
            st.record_request(std::time::Duration::from_micros(lat));
            responses_fp = fold_response(responses_fp, r);
            match r {
                Response::Error(_) => errors += 1,
                Response::Degraded(_) => degraded += 1,
                _ => {}
            }
        }
    }
    let wall_exec_s = wall_start.elapsed().as_secs_f64();

    // Counter fingerprint: every tenant's snapshot JSON, in tenant order.
    // Virtual latencies + disabled prefetch make every metric replayable
    // EXCEPT the wall-clock duration counters (`*_ns`), which the
    // fingerprint therefore drops; the report keeps the full snapshot.
    let mut counters_fp = FNV_OFFSET;
    let mut tenants_detail = Vec::new();
    for engine in &fleet.engines {
        let snap = engine.metrics_snapshot();
        let mut canon = snap.clone();
        canon.counters.retain(|(name, _)| !name.ends_with("_ns"));
        counters_fp = fnv1a(counters_fp, canon.to_json().to_string().as_bytes());
        tenants_detail.push(Json::obj(vec![
            ("tenant", Json::str(engine.tenant().unwrap_or("default"))),
            ("snapshot", snap.to_json()),
        ]));
    }

    // Virtual latency distributions.
    let lat: Vec<u64> = rp.latency_us.iter().filter_map(|&l| l).collect();
    let ttft: Vec<u64> = rp.ttft_us.iter().filter_map(|&l| l).collect();
    // Closed-loop replays reissue arrivals, so the span starts at the
    // first EFFECTIVE arrival (identical to the schedule's in open loop).
    let makespan_us = rp
        .windows
        .iter()
        .map(|w| w.completion_us)
        .max()
        .unwrap_or(0)
        .saturating_sub(rp.arrival_us.first().copied().unwrap_or(0));
    let tok_s = if makespan_us > 0 {
        live_tokens as f64 * 1e6 / makespan_us as f64
    } else {
        0.0
    };
    let pool = pool_latencies(&rp, vworkers);

    // Cache-decision metrics, summed across tenants (each engine has its
    // own cache; dense engines report none).
    let mut hits = 0u64;
    let mut misses = 0u64;
    let mut fused = 0u64;
    let mut restores = 0u64;
    let mut quant = 0u64;
    let mut promotions = 0u64;
    let mut pf_useful = 0.0f64;
    let mut have_cache = false;
    for e in &fleet.engines {
        if let Some(cm) = e.cache_metrics() {
            have_cache = true;
            hits += cm.hits;
            misses += cm.misses;
            fused += cm.fused_serves;
            restores += cm.restore_serves;
            quant += cm.quant_serves;
            promotions += cm.quant_promotions;
            pf_useful += cm.prefetch_usefulness();
        }
    }
    let hit_rate = if hits + misses > 0 {
        hits as f64 / (hits + misses) as f64
    } else {
        0.0
    };

    // Skew census: cumulative per-slot serves across the fleet; the
    // top-decile share is what the zipf gates pin.
    let mut census: BTreeMap<(usize, usize), u64> = BTreeMap::new();
    for e in &fleet.engines {
        for (block, slot, serves) in e.slot_serves() {
            *census.entry((block, slot)).or_insert(0) += serves;
        }
    }
    let mut serves: Vec<u64> = census.values().copied().collect();
    serves.sort_unstable_by(|a, b| b.cmp(a));
    let slot_total: u64 = serves.iter().sum();
    let slots = serves.len();
    let top = slots.div_ceil(10).max(1);
    let top_share = if slot_total > 0 {
        serves.iter().take(top).sum::<u64>() as f64 / slot_total as f64
    } else {
        0.0
    };
    let proportional = if slots > 0 { top as f64 / slots as f64 } else { 0.0 };
    let skew_ratio = if proportional > 0.0 { top_share / proportional } else { 0.0 };

    let arrivals = events.len() as u64;
    let shed_admission = rp.admit_shed.len() as u64;
    let shed_deadline = rp.deadline_shed.len() as u64;
    let windows = rp.windows.iter().filter(|w| !w.live.is_empty()).count() as u64;
    let mean_batch = if windows > 0 {
        occupancy_sum as f64 / windows as f64
    } else {
        0.0
    };

    let doc = Json::obj(vec![
        ("scenario", Json::str(sc.name)),
        ("seed", Json::num(seed as f64)),
        ("vworkers", Json::num(vworkers as f64)),
        ("tenants", Json::num(sc.tenants.max(1) as f64)),
        ("arrivals", Json::num(arrivals as f64)),
        ("executed", Json::num(executed as f64)),
        ("shed_admission", Json::num(shed_admission as f64)),
        ("shed_deadline", Json::num(shed_deadline as f64)),
        ("errors", Json::num(errors as f64)),
        ("degraded", Json::num(degraded as f64)),
        ("classify_disabled", Json::Bool(!classify_enabled)),
        (
            "virtual",
            Json::obj(vec![
                ("p50_ms", ms(percentile_us(&lat, 50))),
                ("p99_ms", ms(percentile_us(&lat, 99))),
                ("ttft_p50_ms", ms(percentile_us(&ttft, 50))),
                ("ttft_p99_ms", ms(percentile_us(&ttft, 99))),
                ("tok_s", Json::num(tok_s)),
                ("makespan_ms", Json::num(makespan_us as f64 / 1000.0)),
                ("windows", Json::num(windows as f64)),
                ("windows_full", Json::num(windows_by_reason[0] as f64)),
                ("windows_linger", Json::num(windows_by_reason[1] as f64)),
                ("windows_closed", Json::num(windows_by_reason[2] as f64)),
                ("mean_batch", Json::num(mean_batch)),
            ]),
        ),
        (
            "pool",
            Json::obj(vec![
                ("p50_ms", ms(percentile_us(&pool, 50))),
                ("p99_ms", ms(percentile_us(&pool, 99))),
            ]),
        ),
        ("wall_exec_s", Json::num(wall_exec_s)),
        (
            "cache",
            if have_cache {
                Json::obj(vec![
                    ("hit_rate", Json::num(hit_rate)),
                    ("prefetch_useful_rate", Json::num(pf_useful)),
                    ("fused_serves", Json::num(fused as f64)),
                    ("restore_serves", Json::num(restores as f64)),
                    ("quant_serves", Json::num(quant as f64)),
                    ("quant_promotions", Json::num(promotions as f64)),
                ])
            } else {
                Json::Null
            },
        ),
        (
            "skew",
            Json::obj(vec![
                ("slots", Json::num(slots as f64)),
                ("top_decile_share", Json::num(top_share)),
                ("proportional_share", Json::num(proportional)),
                ("ratio", Json::num(skew_ratio)),
            ]),
        ),
        (
            "fingerprints",
            Json::obj(vec![
                ("schedule", Json::str(&format!("{schedule_fp:016x}"))),
                ("responses", Json::str(&format!("{responses_fp:016x}"))),
                ("counters", Json::str(&format!("{counters_fp:016x}"))),
            ]),
        ),
        ("tenants_detail", Json::Arr(tenants_detail)),
    ]);

    Ok(ScenarioRun {
        name: sc.name.to_string(),
        doc,
        schedule_fp,
        responses_fp,
        counters_fp,
        arrivals,
        executed,
        shed_admission,
        shed_deadline,
        errors,
        degraded,
    })
}

/// Run scenarios (one fresh fleet each, so counters stay per-scenario)
/// against a packed artifact and assemble the benchmark document.
pub fn run_all(
    artifact: &Path,
    cache_budget_bytes: usize,
    scenario: &str,
    seed: u64,
    vworkers: usize,
) -> Result<(Json, Vec<ScenarioRun>)> {
    let scenarios: Vec<Scenario> = if scenario == "all" {
        Scenario::canned()
    } else {
        vec![Scenario::by_name(scenario).ok_or_else(|| {
            anyhow!(
                "unknown scenario '{}' (have: {}, or 'all')",
                scenario,
                Scenario::canned()
                    .iter()
                    .map(|s| s.name)
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?]
    };
    let mut runs = Vec::new();
    for sc in &scenarios {
        let fleet = Fleet::from_artifact(artifact, cache_budget_bytes, sc.tenants)?;
        runs.push(run_scenario(&fleet, sc, seed, vworkers)?);
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("scenarios")),
        ("source", Json::str("rust-loadgen")),
        ("kernel", Json::str(crate::tensor::kernel_label())),
        ("seed", Json::num(seed as f64)),
        ("vworkers", Json::num(vworkers as f64)),
        ("scenarios", Json::Arr(runs.iter().map(|r| r.doc.clone()).collect())),
    ]);
    Ok((doc, runs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_model, ResMoE};
    use crate::moe::{Model, ModelConfig};
    use crate::tensor::Matrix;
    use crate::Rng;

    fn tiny_model(seed: u64) -> Model {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        let mut m = Model::random(&cfg, &mut rng);
        m.heads.push((
            CLASSIFY_TASK.to_string(),
            Matrix::randn(3, m.cfg.d_model, 0.2, &mut rng),
        ));
        m
    }

    fn tiny_engine(seed: u64) -> Engine {
        let m = tiny_model(seed);
        let mut rng = Rng::new(seed ^ 0x5eed);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        Engine::compressed(m, cm.layers, 48 * 1024)
    }

    fn tiny_fleet(tenants: usize) -> Fleet {
        Fleet::from_engines((0..tenants).map(|_| tiny_engine(11)).collect())
    }

    #[test]
    fn run_scenario_executes_and_conserves() {
        let sc = Scenario::by_name("mixed").unwrap();
        let run = run_scenario(&tiny_fleet(1), &sc, 7, 4).unwrap();
        assert_eq!(run.arrivals, sc.requests as u64);
        assert_eq!(
            run.executed + run.shed_admission + run.shed_deadline,
            run.arrivals
        );
        assert_eq!(run.errors, 0, "mixed traffic must not error");
        assert_eq!(run.shed_admission + run.shed_deadline, 0);
        assert!(run.executed > 0);
    }

    #[test]
    fn fixed_seed_runs_are_bit_identical() {
        let sc = Scenario::by_name("zipf12").unwrap();
        let a = run_scenario(&tiny_fleet(1), &sc, 7, 4).unwrap();
        let b = run_scenario(&tiny_fleet(1), &sc, 7, 1).unwrap();
        assert_eq!(a.schedule_fp, b.schedule_fp);
        assert_eq!(a.responses_fp, b.responses_fp, "responses must replay");
        assert_eq!(a.counters_fp, b.counters_fp, "counters must replay");
        let c = run_scenario(&tiny_fleet(1), &sc, 8, 4).unwrap();
        assert_ne!(a.schedule_fp, c.schedule_fp, "seed must matter");
    }

    #[test]
    fn fleet_requires_matching_tenants() {
        let sc = Scenario::by_name("multi_tenant").unwrap();
        assert!(run_scenario(&tiny_fleet(1), &sc, 7, 4).is_err());
        let run = run_scenario(&tiny_fleet(2), &sc, 7, 4).unwrap();
        assert_eq!(run.errors, 0);
        assert!(run.executed > 0);
    }

    #[test]
    fn classify_folds_to_score_without_head() {
        let mut m = tiny_model(5);
        m.heads.clear();
        let mut rng = Rng::new(5 ^ 0x5eed);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        let fleet = Fleet::from_engines(vec![Engine::compressed(m, cm.layers, 48 * 1024)]);
        let sc = Scenario::by_name("mixed").unwrap();
        let run = run_scenario(&fleet, &sc, 7, 4).unwrap();
        assert_eq!(run.errors, 0, "headless classify must fold, not error");
        let doc = run.doc.to_string();
        assert!(doc.contains("\"classify_disabled\":true"));
    }

    #[test]
    fn pool_model_is_observation_only() {
        let sc = Scenario::by_name("bursty").unwrap();
        let events = schedule::generate(&sc, 7);
        let rp = schedule::replay(&sc, &events);
        let one = pool_latencies(&rp, 1);
        let four = pool_latencies(&rp, 4);
        assert_eq!(one.len(), four.len(), "membership never changes with k");
        assert!(four.iter().zip(&one).all(|(f, o)| f <= o));
    }

    #[test]
    fn gen_storm_batches_decode_and_replays() {
        // The closed-loop decode storm: Generate-dominated windows run
        // through the iteration-level decode lane, error-free, and the
        // whole run (responses AND counters, decode.* included) replays
        // bit-identically under a fixed seed.
        let sc = Scenario::by_name("gen_storm").unwrap();
        let a = run_scenario(&tiny_fleet(1), &sc, 7, 4).unwrap();
        assert_eq!(a.errors, 0, "the storm must not error");
        assert_eq!(a.shed_admission + a.shed_deadline, 0, "closed loop never sheds");
        assert_eq!(a.executed, a.arrivals);
        let b = run_scenario(&tiny_fleet(1), &sc, 7, 1).unwrap();
        assert_eq!(a.responses_fp, b.responses_fp, "decode batching must replay");
        assert_eq!(a.counters_fp, b.counters_fp);
        // The decode lane actually engaged: multi-Generate windows step
        // with batch > 1 somewhere in 96 requests at 8:1:1.
        let fleet = tiny_fleet(1);
        let _ = run_scenario(&fleet, &sc, 7, 4).unwrap();
        let dm = fleet.engines[0].decode_metrics();
        assert!(dm.seqs > 0, "storm windows must admit decode sequences: {dm:?}");
        assert!(dm.mean_step_batch() > 1.0, "storm must actually batch: {dm:?}");
    }
}
