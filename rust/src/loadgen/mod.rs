//! Seeded, scenario-diverse traffic harness for the serving stack.
//!
//! Three layers, strictly separated so determinism is auditable:
//!
//! * [`scenario`] — the scenario model (arrival process × routing skew ×
//!   request mix × client behavior) plus the canned scenario set, all in
//!   integer virtual-clock arithmetic.
//! * [`schedule`] — seeded schedule generation (xoshiro256** + integer
//!   quantile tables) and the engine-free virtual replay: real
//!   [`crate::coordinator::Batcher`] windows, a tenant-serial virtual
//!   service pipe, admission-depth and deadline sheds, slow-reader drain
//!   pacing. `scripts/sim_loadgen.py` is a line-for-line Python replica
//!   — the two must produce bit-identical schedules and fingerprints.
//! * [`run`] — execute the surviving windows through real
//!   [`crate::coordinator::Engine`] batches, record *virtual* latencies
//!   on the PR 7 observability registry, fingerprint schedule /
//!   responses / counters, and emit the `BENCH_scenarios.json` report.
//!
//! A fixed `(scenario, seed)` pair replays bit-identically across runs
//! and worker counts; `--vworkers` feeds a reporting-only pool-latency
//! model and can never change a decision.

pub mod run;
pub mod scenario;
pub mod schedule;

pub use run::{run_all, run_scenario, Fleet, ScenarioRun, CLASSIFY_TASK};
pub use scenario::{Arrivals, Mix, Routing, Scenario, ServiceModel};
pub use schedule::{generate, percentile_us, replay, schedule_fingerprint, Event, Replay};
