//! Seeded schedule generation + pure virtual-clock replay.
//!
//! Everything in this file is **engine-free and integer-only**: a schedule
//! is a deterministic function of `(Scenario, seed)`, and the window
//! formation / admission / drain replay is a deterministic function of the
//! schedule — `scripts/sim_loadgen.py` ports both verbatim, so a
//! toolchain-less session can still validate the whole virtual-time story
//! (window composition, sheds, latency percentiles) against this code.
//!
//! The replay is also **worker-count-invariant by construction**: windows
//! form from arrivals + policy alone (the same [`Batcher`] state machine
//! the real server drives), and the decision-bearing service pipe is one
//! virtual worker per tenant. The `vworkers` knob of the runner shapes a
//! separately-reported pool latency model and nothing else — which is what
//! lets a fixed seed replay bit-identically across `--vworkers 1` and `4`.

use super::scenario::{
    Arrivals, Routing, Scenario, EXP_Q1024, GEN_NEW_TOKENS, LEN_RANGE, MIN_LEN, N_PROFILES,
};
use crate::coordinator::{Batcher, FlushReason};
use crate::util::Rng;

// ------------------------------------------------------------ fingerprints

pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64 over a byte stream (the replay-identity fingerprint hash).
pub fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Fold a `u64` into an FNV-1a stream (little-endian bytes).
pub fn fnv1a_u64(h: u64, v: u64) -> u64 {
    fnv1a(h, &v.to_le_bytes())
}

// ---------------------------------------------------------------- schedule

/// One scheduled arrival. `kind`: 0 = Score, 1 = Generate, 2 = Classify.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub t_us: u64,
    pub profile: u32,
    pub kind: u32,
    pub len: u32,
    pub tenant: u32,
}

impl Event {
    /// Virtual tokens this request puts through a window (Generate adds
    /// its decoded tokens).
    pub fn tokens(&self) -> u64 {
        u64::from(self.len)
            + if self.kind == 1 { u64::from(GEN_NEW_TOKENS) } else { 0 }
    }
}

/// Seed mixing: each scenario gets its own stream, derived from the user
/// seed and the scenario name (so `zipf09` and `zipf12` under one seed are
/// distinct but individually stable).
pub fn scenario_rng(seed: u64, name: &str) -> Rng {
    Rng::new(seed ^ fnv1a(FNV_OFFSET, name.as_bytes()))
}

fn draw_gap(rng: &mut Rng, arrivals: &Arrivals, i: usize) -> u64 {
    let q = EXP_Q1024[rng.below(EXP_Q1024.len())];
    match arrivals {
        Arrivals::Poisson { mean_gap_us } => mean_gap_us.saturating_mul(q) / 1024,
        Arrivals::OnOff { burst_gap_us, idle_gap_us, burst_len, ramp_permille, ramp_period } => {
            let cycle = (*burst_len as usize) + 1;
            let base = if i % cycle < *burst_len as usize { *burst_gap_us } else { *idle_gap_us };
            let step = (i / *ramp_period as usize) % ramp_permille.len();
            let intensity = ramp_permille[step].max(1);
            base.saturating_mul(q) / 1024 * 1000 / intensity
        }
    }
}

fn draw_profile(rng: &mut Rng, routing: &Routing) -> u32 {
    match routing {
        Routing::Uniform => rng.below(N_PROFILES) as u32,
        Routing::Zipf { weights } => {
            let total: u64 = weights.iter().sum();
            let mut r = rng.below(total as usize) as u64;
            for (i, &w) in weights.iter().enumerate() {
                if r < w {
                    return i as u32;
                }
                r -= w;
            }
            (weights.len() - 1) as u32
        }
    }
}

/// Generate the full arrival schedule for `(scenario, seed)`. Draw order
/// per event is fixed — gap, profile, kind, [tenant] — so the stream is
/// identical across implementations.
pub fn generate(sc: &Scenario, seed: u64) -> Vec<Event> {
    let mut rng = scenario_rng(seed, sc.name);
    let kind_total =
        u64::from(sc.mix.score) + u64::from(sc.mix.generate) + u64::from(sc.mix.classify);
    assert!(kind_total > 0, "scenario {} has an empty request mix", sc.name);
    let mut t = 0u64;
    let mut events = Vec::with_capacity(sc.requests);
    for i in 0..sc.requests {
        t = t.saturating_add(draw_gap(&mut rng, &sc.arrivals, i));
        let profile = draw_profile(&mut rng, &sc.routing);
        let r = rng.below(kind_total as usize) as u64;
        let kind = if r < u64::from(sc.mix.score) {
            0
        } else if r < u64::from(sc.mix.score) + u64::from(sc.mix.generate) {
            1
        } else {
            2
        };
        let len = MIN_LEN + rng.below(LEN_RANGE) as u32;
        let tenant = if sc.tenants > 1 { rng.below(sc.tenants) as u32 } else { 0 };
        events.push(Event { t_us: t, profile, kind, len, tenant });
    }
    events
}

/// Schedule fingerprint: FNV-1a over every event field, in order.
pub fn schedule_fingerprint(events: &[Event]) -> u64 {
    let mut h = FNV_OFFSET;
    for e in events {
        h = fnv1a_u64(h, e.t_us);
        h = fnv1a_u64(h, u64::from(e.profile));
        h = fnv1a_u64(h, u64::from(e.kind));
        h = fnv1a_u64(h, u64::from(e.len));
        h = fnv1a_u64(h, u64::from(e.tenant));
    }
    h
}

// ------------------------------------------------------------------ replay

/// One virtually-formed window, ready for real-engine execution.
#[derive(Clone, Debug)]
pub struct VWindow {
    pub tenant: u32,
    /// Virtual formation time (arrivals + policy only).
    pub formed_us: u64,
    pub reason: FlushReason,
    pub waited_us: u64,
    /// Schedule indices that execute (survived the deadline check).
    pub live: Vec<usize>,
    /// Schedule indices shed at pickup (deadline exceeded).
    pub shed: Vec<usize>,
    /// When the tenant's virtual service pipe starts this window (includes
    /// backlog) and finishes it.
    pub exec_start_us: u64,
    pub completion_us: u64,
    /// Virtual service duration (`base + per_token * live tokens`).
    pub dur_us: u64,
}

/// The pure virtual-time replay of a schedule.
#[derive(Clone, Debug, Default)]
pub struct Replay {
    /// Windows in formation order (global event order; ties keep tenant
    /// arrival order — the loop is sequential and deterministic).
    pub windows: Vec<VWindow>,
    /// Schedule indices shed at admission (virtual depth >= max_queue).
    pub admit_shed: Vec<usize>,
    /// Schedule indices shed at pickup (past deadline).
    pub deadline_shed: Vec<usize>,
    /// Per-executed-request virtual latency µs, indexed by schedule index
    /// (`None` for shed requests): completion - arrival.
    pub latency_us: Vec<Option<u64>>,
    /// Virtual time-to-first-token for executed Generate requests.
    pub ttft_us: Vec<Option<u64>>,
    /// Effective arrival instant per schedule index. Open loop: the
    /// schedule's `t_us` verbatim. Closed loop: when the issuing client
    /// actually became ready (previous completion + think time).
    pub arrival_us: Vec<u64>,
}

struct TenantState {
    batcher: Batcher<usize>,
    /// Virtual single-server service pipe (decision-bearing; worker-count
    /// independent).
    busy_until_us: u64,
    /// Slow-reader drain cursor: next instant the client can consume a
    /// response.
    drain_cursor_us: u64,
    /// Drain times of every windowed request, nondecreasing (service pipe
    /// completions are monotone per tenant and the cursor only grows).
    drains_us: Vec<u64>,
}

impl TenantState {
    fn new(sc: &Scenario) -> TenantState {
        TenantState {
            batcher: Batcher::new(sc.policy),
            busy_until_us: 0,
            drain_cursor_us: 0,
            drains_us: Vec::new(),
        }
    }

    /// Windowed requests not yet consumed by the client at `t` — the
    /// "responses backing up" component of virtual depth.
    fn undrained_at(&self, t: u64) -> usize {
        self.drains_us.len() - self.drains_us.partition_point(|&d| d <= t)
    }
}

/// Run a formed window through the tenant's virtual service pipe: deadline
/// check at pickup, service duration from live tokens, drain bookkeeping.
fn execute_window(
    sc: &Scenario,
    events: &[Event],
    st: &mut TenantState,
    tenant: u32,
    idxs: Vec<usize>,
    reason: FlushReason,
    formed_us: u64,
    waited_us: u64,
    out: &mut Replay,
) {
    let exec_start_us = formed_us.max(st.busy_until_us);
    let (mut live, mut shed) = (Vec::new(), Vec::new());
    for idx in idxs {
        let waited = exec_start_us.saturating_sub(out.arrival_us[idx]);
        if sc.deadline_us > 0 && waited > sc.deadline_us {
            shed.push(idx);
        } else {
            live.push(idx);
        }
    }
    let tokens: u64 = live.iter().map(|&i| events[i].tokens()).sum();
    let dur_us = if live.is_empty() {
        0
    } else {
        sc.service.base_us.saturating_add(sc.service.per_token_us.saturating_mul(tokens))
    };
    let completion_us = exec_start_us.saturating_add(dur_us);
    st.busy_until_us = completion_us;
    for &idx in &live {
        out.latency_us[idx] = Some(completion_us.saturating_sub(out.arrival_us[idx]));
        if events[idx].kind == 1 {
            out.ttft_us[idx] = Some(
                exec_start_us
                    .saturating_add(sc.service.base_us)
                    .saturating_sub(out.arrival_us[idx]),
            );
        }
        let drain = completion_us.max(st.drain_cursor_us);
        st.drain_cursor_us = drain.saturating_add(sc.drain_gap_us);
        st.drains_us.push(drain);
    }
    out.deadline_shed.extend(shed.iter().copied());
    out.windows.push(VWindow {
        tenant,
        formed_us,
        reason,
        waited_us,
        live,
        shed,
        exec_start_us,
        completion_us,
        dur_us,
    });
}

/// Flush every linger window due at or before `now` for one tenant.
fn flush_due(
    sc: &Scenario,
    events: &[Event],
    st: &mut TenantState,
    tenant: u32,
    now_us: u64,
    out: &mut Replay,
) {
    while let Some(dl) = st.batcher.deadline_us() {
        if dl > now_us {
            break;
        }
        match st.batcher.poll(dl) {
            Some(w) => execute_window(
                sc, events, st, tenant, w.items, w.reason, dl, w.waited_us, out,
            ),
            None => break,
        }
    }
}

/// Replay the schedule through per-tenant admission queues and virtual
/// service pipes. Pure: same `(scenario, events)` in, same `Replay` out.
/// Dispatches on the client model: open loop (arrivals verbatim) or
/// closed loop ([`Scenario::closed_loop_clients`] > 0).
pub fn replay(sc: &Scenario, events: &[Event]) -> Replay {
    let mut out = Replay {
        latency_us: vec![None; events.len()],
        ttft_us: vec![None; events.len()],
        arrival_us: events.iter().map(|e| e.t_us).collect(),
        ..Replay::default()
    };
    if sc.closed_loop_clients > 0 {
        replay_closed(sc, events, &mut out);
        return out;
    }
    let mut tenants: Vec<TenantState> =
        (0..sc.tenants.max(1)).map(|_| TenantState::new(sc)).collect();
    for (i, ev) in events.iter().enumerate() {
        let tn = ev.tenant as usize;
        // 1. Linger flushes due before this arrival (every tenant: virtual
        //    time advances globally).
        for t in 0..tenants.len() {
            flush_due(sc, events, &mut tenants[t], t as u32, ev.t_us, &mut out);
        }
        // 2. Admission: depth = queued + produced-but-undrained responses.
        let st = &mut tenants[tn];
        let depth = st.batcher.pending_len() + st.undrained_at(ev.t_us);
        if sc.max_queue > 0 && depth >= sc.max_queue {
            out.admit_shed.push(i);
            continue;
        }
        // 3. Admit; a full window flushes immediately at the arrival.
        st.batcher.push(i, ev.t_us);
        if let Some(w) = st.batcher.poll(ev.t_us) {
            execute_window(
                sc, events, st, tn as u32, w.items, w.reason, ev.t_us, w.waited_us, &mut out,
            );
        }
    }
    // 4. End of schedule: remaining linger deadlines fire, then the client
    //    "hangs up" and close-flushes the tail at the last arrival time.
    let t_end = events.last().map(|e| e.t_us).unwrap_or(0);
    for t in 0..tenants.len() {
        flush_due(sc, events, &mut tenants[t], t as u32, u64::MAX, &mut out);
        tenants[t].batcher.close();
        while let Some(w) = tenants[t].batcher.poll(t_end) {
            execute_window(
                sc, events, &mut tenants[t], t as u32, w.items, w.reason, t_end, w.waited_us,
                &mut out,
            );
        }
    }
    out
}

/// Unblock clients whose requests finished in `windows[seen..]`: live
/// members become ready at the window's completion, shed members at
/// pickup (when the real server would answer `Overloaded`). Returns the
/// new high-water mark.
fn unblock_clients(
    windows: &[VWindow],
    seen: usize,
    owner: &[usize],
    ready: &mut [u64],
) -> usize {
    for w in &windows[seen..] {
        for &i in &w.live {
            if owner[i] != usize::MAX {
                ready[owner[i]] = w.completion_us;
            }
        }
        for &i in &w.shed {
            if owner[i] != usize::MAX {
                ready[owner[i]] = w.exec_start_us;
            }
        }
    }
    windows.len()
}

/// Closed-loop replay: a pool of `closed_loop_clients` virtual clients
/// issues the schedule's events IN ORDER, at most one outstanding request
/// per client. Event `i`'s think time is the schedule's inter-arrival gap
/// (`t_us[i] - t_us[i-1]`), so the same seeded draws parameterize both
/// client models; its effective arrival is `ready + think` where `ready`
/// is the instant the issuing client's previous response completed.
/// In-flight work is bounded by the pool size, which is what makes this
/// the saturation probe: a slow service pipe slows the offered load down
/// instead of growing the queue without bound.
///
/// Determinism: clients are selected min-(ready, id); time never moves
/// backwards (`now` clamps); when every client is blocked, virtual time
/// jumps to the earliest linger deadline, whose flush completes a window
/// and unblocks its owners. Pure integer arithmetic throughout — the
/// Python replica (`scripts/sim_loadgen.py`) ports this loop verbatim.
fn replay_closed(sc: &Scenario, events: &[Event], out: &mut Replay) {
    let clients = sc.closed_loop_clients;
    let mut tenants: Vec<TenantState> =
        (0..sc.tenants.max(1)).map(|_| TenantState::new(sc)).collect();
    // Per-client next-issue instant; u64::MAX while awaiting a response.
    let mut ready = vec![0u64; clients];
    // Schedule index → issuing client, for unblocking at completion.
    let mut owner = vec![usize::MAX; events.len()];
    let mut seen = 0usize;
    let mut next = 0usize;
    let mut now = 0u64;
    while next < events.len() {
        let (c, &r) = ready
            .iter()
            .enumerate()
            .min_by_key(|&(id, &t)| (t, id))
            .expect("closed loop requires at least one client");
        if r == u64::MAX {
            // Every client is waiting: the only way forward is a linger
            // flush (a blocked client's request is either pending in a
            // batcher — which then carries a deadline — or already
            // windowed, in which case it was unblocked above).
            let dl = tenants
                .iter()
                .filter_map(|t| t.batcher.deadline_us())
                .min()
                .expect("blocked clients imply a pending linger window");
            now = now.max(dl);
            for tn in 0..tenants.len() {
                flush_due(sc, events, &mut tenants[tn], tn as u32, now, out);
            }
            seen = unblock_clients(&out.windows, seen, &owner, &mut ready);
            continue;
        }
        let i = next;
        next += 1;
        let think = if i == 0 {
            events[0].t_us
        } else {
            events[i].t_us - events[i - 1].t_us
        };
        let t = now.max(r.saturating_add(think));
        now = t;
        out.arrival_us[i] = t;
        for tn in 0..tenants.len() {
            flush_due(sc, events, &mut tenants[tn], tn as u32, t, out);
        }
        let tn = events[i].tenant as usize;
        let st = &mut tenants[tn];
        let depth = st.batcher.pending_len() + st.undrained_at(t);
        if sc.max_queue > 0 && depth >= sc.max_queue {
            // Instant Overloaded answer: the client thinks again from `t`.
            out.admit_shed.push(i);
            ready[c] = t;
        } else {
            owner[i] = c;
            ready[c] = u64::MAX;
            st.batcher.push(i, t);
            if let Some(w) = st.batcher.poll(t) {
                execute_window(
                    sc, events, st, tn as u32, w.items, w.reason, t, w.waited_us, out,
                );
            }
        }
        seen = unblock_clients(&out.windows, seen, &owner, &mut ready);
    }
    // Tail: outstanding linger windows fire, then the pool hangs up and
    // close-flushes whatever remains at the last issue instant.
    for tn in 0..tenants.len() {
        flush_due(sc, events, &mut tenants[tn], tn as u32, u64::MAX, out);
        tenants[tn].batcher.close();
        while let Some(w) = tenants[tn].batcher.poll(now) {
            execute_window(
                sc, events, &mut tenants[tn], tn as u32, w.items, w.reason, now, w.waited_us,
                out,
            );
        }
    }
}

// ------------------------------------------------------------- percentiles

/// Integer nearest-rank percentile over an UNSORTED sample (sorts a copy):
/// index `(n - 1) * q / 100` of the sorted values. Matches the Python
/// replica exactly (integer floor division).
pub fn percentile_us(sample: &[u64], q: u64) -> Option<u64> {
    if sample.is_empty() {
        return None;
    }
    let mut v = sample.to_vec();
    v.sort_unstable();
    Some(v[((v.len() - 1) as u64 * q / 100) as usize])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loadgen::scenario::Scenario;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Classic FNV-1a 64 test vectors.
        assert_eq!(fnv1a(FNV_OFFSET, b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(FNV_OFFSET, b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(FNV_OFFSET, b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn schedules_are_seed_deterministic_and_seed_sensitive() {
        for sc in Scenario::canned() {
            let a = generate(&sc, 7);
            let b = generate(&sc, 7);
            assert_eq!(a, b, "{}: same seed, same schedule", sc.name);
            let c = generate(&sc, 8);
            assert_ne!(
                schedule_fingerprint(&a),
                schedule_fingerprint(&c),
                "{}: different seeds, different schedules",
                sc.name
            );
            assert_eq!(a.len(), sc.requests);
            assert!(a.windows(2).all(|w| w[0].t_us <= w[1].t_us), "arrivals ordered");
        }
    }

    #[test]
    fn zipf_schedules_skew_profile_mass() {
        // Top-decile profiles must draw a super-proportional share of
        // requests — the schedule-level half of the skew acceptance gate
        // (the cache-level half runs in check_scenarios.py).
        for (name, min_ratio) in [("zipf09", 2.0), ("zipf12", 2.5)] {
            let sc = Scenario::by_name(name).unwrap();
            let ev = generate(&sc, 7);
            let mut counts = [0u64; N_PROFILES];
            for e in &ev {
                counts[e.profile as usize] += 1;
            }
            let mut sorted = counts;
            sorted.sort_unstable_by(|a, b| b.cmp(a));
            let top = N_PROFILES.div_ceil(10);
            let share: u64 = sorted[..top].iter().sum();
            let ratio = share as f64 / ev.len() as f64 / (top as f64 / N_PROFILES as f64);
            assert!(ratio >= min_ratio, "{name}: top-decile ratio {ratio:.2} < {min_ratio}");
        }
        // Uniform control: mixed scenario profiles stay roughly flat.
        let sc = Scenario::by_name("mixed").unwrap();
        let ev = generate(&sc, 7);
        let mut counts = [0u64; N_PROFILES];
        for e in &ev {
            counts[e.profile as usize] += 1;
        }
        assert!(counts.iter().filter(|&&c| c > 0).count() > N_PROFILES / 2);
    }

    #[test]
    fn replay_conserves_every_request() {
        for sc in Scenario::canned() {
            let ev = generate(&sc, 7);
            let rp = replay(&sc, &ev);
            let executed: usize = rp.windows.iter().map(|w| w.live.len()).sum();
            assert_eq!(
                executed + rp.admit_shed.len() + rp.deadline_shed.len(),
                ev.len(),
                "{}: executed + shed == arrivals",
                sc.name
            );
            // No request appears twice.
            let mut seen = vec![false; ev.len()];
            for idx in rp
                .windows
                .iter()
                .flat_map(|w| w.live.iter().chain(w.shed.iter()))
                .chain(rp.admit_shed.iter())
            {
                assert!(!seen[*idx], "{}: request {idx} duplicated", sc.name);
                seen[*idx] = true;
            }
            assert!(seen.iter().all(|&s| s), "{}: every request accounted", sc.name);
            // Virtual pipe sanity per tenant.
            for t in 0..sc.tenants {
                let mut last = 0u64;
                for w in rp.windows.iter().filter(|w| w.tenant == t as u32) {
                    assert!(w.exec_start_us >= w.formed_us);
                    assert!(w.exec_start_us >= last, "pipe is serial per tenant");
                    last = w.completion_us;
                }
            }
        }
    }

    #[test]
    fn slow_reader_sheds_and_others_do_not() {
        for sc in Scenario::canned() {
            let ev = generate(&sc, 7);
            let rp = replay(&sc, &ev);
            let sheds = rp.admit_shed.len() + rp.deadline_shed.len();
            if sc.name == "slow_reader" {
                assert!(sheds > 0, "slow_reader must shed under backpressure");
                assert!(
                    rp.windows.iter().map(|w| w.live.len()).sum::<usize>() > 0,
                    "but not shed everything"
                );
            } else {
                assert_eq!(sheds, 0, "{}: no sheds intended", sc.name);
            }
        }
    }

    #[test]
    fn bursty_schedule_forms_full_and_linger_windows() {
        let sc = Scenario::by_name("bursty").unwrap();
        let ev = generate(&sc, 7);
        let rp = replay(&sc, &ev);
        let full = rp.windows.iter().filter(|w| w.reason == FlushReason::Full).count();
        let linger = rp.windows.iter().filter(|w| w.reason == FlushReason::Linger).count();
        assert!(full > 0, "bursts must fill windows");
        assert!(linger > 0, "idle gaps must strand stragglers");
    }

    #[test]
    fn closed_loop_bounds_in_flight_requests() {
        let sc = Scenario::by_name("gen_storm").unwrap();
        assert!(sc.closed_loop_clients > 0, "gen_storm is the closed-loop scenario");
        let ev = generate(&sc, 7);
        let rp = replay(&sc, &ev);
        // The pool issues events in schedule order; virtual time never
        // runs backwards.
        assert!(rp.arrival_us.windows(2).all(|w| w[0] <= w[1]));
        // Reconstruct per-request completion instants.
        let mut done = vec![0u64; ev.len()];
        for w in &rp.windows {
            for &i in &w.live {
                done[i] = w.completion_us;
            }
            for &i in &w.shed {
                done[i] = w.exec_start_us;
            }
        }
        for &i in &rp.admit_shed {
            done[i] = rp.arrival_us[i];
        }
        // The closed-loop invariant: never more outstanding requests than
        // clients, at any arrival instant.
        for i in 0..ev.len() {
            let a = rp.arrival_us[i];
            let in_flight = (0..ev.len())
                .filter(|&j| rp.arrival_us[j] <= a && done[j] > a)
                .count();
            assert!(
                in_flight <= sc.closed_loop_clients,
                "event {i}: {in_flight} in flight > pool of {}",
                sc.closed_loop_clients
            );
        }
        // The storm is decode-dominated and sheds nothing (also pinned
        // scenario-wide by slow_reader_sheds_and_others_do_not).
        let gens = ev.iter().filter(|e| e.kind == 1).count();
        assert!(gens * 2 >= ev.len(), "{gens}/{} generates", ev.len());
        assert!(rp.admit_shed.is_empty() && rp.deadline_shed.is_empty());
    }

    #[test]
    fn open_loop_arrivals_pass_through_verbatim() {
        // Open-loop replays must report the schedule's own arrival
        // instants (the closed-loop field is a strict generalization).
        let sc = Scenario::by_name("mixed").unwrap();
        let ev = generate(&sc, 7);
        let rp = replay(&sc, &ev);
        assert!(rp.arrival_us.iter().zip(&ev).all(|(&a, e)| a == e.t_us));
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile_us(&[], 50), None);
        assert_eq!(percentile_us(&[7], 99), Some(7));
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_us(&v, 50), Some(50));
        assert_eq!(percentile_us(&v, 99), Some(99));
    }
}
