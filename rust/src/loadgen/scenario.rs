//! Scenario model: arrival process × routing skew × request mix × client
//! behavior, all expressed in **integer-only** virtual-clock arithmetic so
//! the Python replica (`scripts/sim_loadgen.py`) reproduces every schedule
//! bit-for-bit. No floats anywhere on the schedule path.

use crate::coordinator::BatchPolicy;

/// Number of token profiles routing skew is expressed over. A profile maps
/// to one token id (`profile % vocab`), so with the 32-token demo vocab the
/// profile distribution IS the token distribution the router sees.
pub const N_PROFILES: usize = 32;

/// Tokens decoded per Generate request (virtual + real).
pub const GEN_NEW_TOKENS: u32 = 4;

/// Request length draw: `MIN_LEN + below(LEN_RANGE)` tokens.
pub const MIN_LEN: u32 = 4;
pub const LEN_RANGE: usize = 12;

/// 64 integer quantiles of the unit exponential, scaled by 1024: entry `i`
/// is `round(-ln(1 - (i+0.5)/64) * 1024)`. An inter-arrival gap is
/// `mean_gap_us * EXP_Q1024[rng.below(64)] / 1024` — a seeded, integer
/// Poisson process with mean ~0.9946 * mean_gap_us.
pub const EXP_Q1024: [u64; 64] = [
    8, 24, 41, 58, 75, 92, 110, 128, 146, 165, 184, 203, 223, 243, 263, 284,
    305, 327, 349, 372, 395, 419, 444, 469, 494, 520, 547, 575, 603, 633,
    663, 694, 726, 759, 793, 828, 865, 903, 942, 983, 1026, 1070, 1117,
    1166, 1217, 1271, 1328, 1388, 1452, 1520, 1594, 1672, 1758, 1851, 1953,
    2067, 2195, 2342, 2513, 2719, 2976, 3320, 3844, 4968,
];

/// Integer Zipf(s = 0.9) profile weights: `round(1e6 / (i+1)^0.9)`.
/// Top decile (4 of 32 profiles) holds ~46% of the mass (3.7x proportional).
pub const ZIPF09: [u64; 32] = [
    1000000, 535887, 372041, 287175, 234924, 199372, 173545, 153893, 138415,
    125893, 115544, 106841, 99415, 93000, 87401, 82469, 78090, 74175, 70652,
    67464, 64566, 61918, 59490, 57255, 55189, 53275, 51496, 49838, 48288,
    46837, 45475, 44194,
];

/// Integer Zipf(s = 1.2) profile weights: `round(1e6 / (i+1)^1.2)`.
/// Top decile holds ~61% of the mass (4.9x proportional).
pub const ZIPF12: [u64; 32] = [
    1000000, 435275, 267581, 189465, 144956, 116471, 96802, 82469, 71599,
    63096, 56277, 50697, 46054, 42135, 38787, 35897, 33378, 31165, 29208,
    27464, 25902, 24496, 23223, 22067, 21012, 20046, 19159, 18340, 17584,
    16883, 16232, 15625,
];

/// When a request arrives relative to its predecessor.
#[derive(Clone, Debug)]
pub enum Arrivals {
    /// Poisson process: exponential inter-arrival gaps with the given mean
    /// (µs), drawn from [`EXP_Q1024`].
    Poisson { mean_gap_us: u64 },
    /// On/off bursts under a diurnal ramp: each cycle is `burst_len`
    /// arrivals at `burst_gap_us` mean followed by one `idle_gap_us` mean
    /// pause; every gap is then divided by the current ramp intensity
    /// (per-mille), which steps through `ramp_permille` once per
    /// `ramp_period` arrivals. Gaps keep their exponential jitter.
    OnOff {
        burst_gap_us: u64,
        idle_gap_us: u64,
        burst_len: u32,
        ramp_permille: Vec<u64>,
        ramp_period: u32,
    },
}

/// Which token profile a request draws (profile → token id → expert skew).
#[derive(Clone, Debug)]
pub enum Routing {
    /// Profiles uniform over [`N_PROFILES`].
    Uniform,
    /// Profiles Zipf-weighted by an integer table ([`ZIPF09`]/[`ZIPF12`]).
    Zipf { weights: Vec<u64> },
}

/// Integer request-kind weights (kind draw: `below(score+generate+classify)`
/// walked cumulatively; ids 0=Score, 1=Generate, 2=Classify).
#[derive(Clone, Copy, Debug)]
pub struct Mix {
    pub score: u32,
    pub generate: u32,
    pub classify: u32,
}

impl Mix {
    pub const fn score_only() -> Mix {
        Mix { score: 1, generate: 0, classify: 0 }
    }
}

/// Virtual service-time model for a flushed window:
/// `base_us + per_token_us * window_tokens` (integer µs).
#[derive(Clone, Copy, Debug)]
pub struct ServiceModel {
    pub base_us: u64,
    pub per_token_us: u64,
}

/// One canned traffic scenario. All knobs are virtual-clock integers; the
/// same struct drives both the pure schedule replay (engine-free, shared
/// with `sim_loadgen.py`) and the real-engine execution.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    /// Total arrivals to generate (across all tenants).
    pub requests: usize,
    pub arrivals: Arrivals,
    pub routing: Routing,
    pub mix: Mix,
    /// Virtual admission depth cap (0 = unbounded; same zero semantics as
    /// `RESMOE_MAX_QUEUE`). Depth = queued + produced-but-undrained.
    pub max_queue: usize,
    /// Virtual per-request deadline (µs; 0 = none; same zero semantics as
    /// `RESMOE_DEADLINE_MS`), checked when the window reaches the virtual
    /// worker — matching the real server's shed-at-pickup.
    pub deadline_us: u64,
    /// Window-forming policy (the `RESMOE_BATCH`/`RESMOE_LINGER_US` pair).
    pub policy: BatchPolicy,
    pub service: ServiceModel,
    /// Client drain pacing: each response occupies the client for this many
    /// µs before the next one is consumed (0 = drained instantly). Slow
    /// readers back responses up against `max_queue`/`deadline_us`.
    pub drain_gap_us: u64,
    /// Engines sharing one store (1 = single tenant).
    pub tenants: usize,
    /// 0 = open loop (arrivals come straight from the schedule). N > 0 =
    /// closed loop: a fixed pool of N virtual clients, each issuing its
    /// next request only after its previous response completes plus a
    /// think time (the schedule's inter-arrival gap for that event). In-
    /// flight requests are bounded by N, so throughput self-limits at
    /// saturation instead of queueing without bound — the regime
    /// `check_scenarios.py` probes for decode saturation.
    pub closed_loop_clients: usize,
}

impl Scenario {
    fn base(name: &'static str) -> Scenario {
        Scenario {
            name,
            requests: 96,
            arrivals: Arrivals::Poisson { mean_gap_us: 400 },
            routing: Routing::Uniform,
            mix: Mix::score_only(),
            max_queue: 0,
            deadline_us: 0,
            policy: BatchPolicy { max_batch: 4, linger_us: 800 },
            service: ServiceModel { base_us: 300, per_token_us: 40 },
            drain_gap_us: 0,
            tenants: 1,
            closed_loop_clients: 0,
        }
    }

    /// The canned scenario set (every name is also a `loadgen --scenario`
    /// value; `all` runs the lot).
    pub fn canned() -> Vec<Scenario> {
        vec![
            // Zipf-routed steady state at two skew strengths: the cache
            // sees a hot set, and the skew gate checks the top-decile
            // slots absorb a super-proportional serve share.
            Scenario {
                routing: Routing::Zipf { weights: ZIPF09.to_vec() },
                ..Scenario::base("zipf09")
            },
            Scenario {
                routing: Routing::Zipf { weights: ZIPF12.to_vec() },
                ..Scenario::base("zipf12")
            },
            // Bursty on/off arrivals under a diurnal ramp: windows
            // alternate between full flushes (bursts) and lone stragglers
            // (idle), with intensity sweeping 0.25x → 2x.
            Scenario {
                arrivals: Arrivals::OnOff {
                    burst_gap_us: 80,
                    idle_gap_us: 5000,
                    burst_len: 8,
                    ramp_permille: vec![250, 500, 1000, 2000, 1000, 500],
                    ramp_period: 16,
                },
                policy: BatchPolicy { max_batch: 8, linger_us: 1500 },
                ..Scenario::base("bursty")
            },
            // Mixed Score/Generate/Classify traffic (2:1:1). Classify folds
            // to Score when the served model exposes no task head
            // (`classify_disabled` in the report).
            Scenario {
                arrivals: Arrivals::Poisson { mean_gap_us: 500 },
                mix: Mix { score: 2, generate: 1, classify: 1 },
                ..Scenario::base("mixed")
            },
            // Slow-reader clients: arrivals far outpace the drain rate, so
            // undrained responses pile up against the admission depth cap
            // and the backlogged pipe pushes pickups past the deadline —
            // the ONLY scenario where sheds are intended (and both shed
            // paths fire: depth cap at admission, deadline at pickup).
            Scenario {
                arrivals: Arrivals::Poisson { mean_gap_us: 150 },
                max_queue: 64,
                deadline_us: 20_000,
                policy: BatchPolicy { max_batch: 4, linger_us: 500 },
                drain_gap_us: 4000,
                ..Scenario::base("slow_reader")
            },
            // Two tenants over one shared store: independent caches and
            // registries, contended artifact reads.
            Scenario {
                arrivals: Arrivals::Poisson { mean_gap_us: 300 },
                routing: Routing::Zipf { weights: ZIPF12.to_vec() },
                tenants: 2,
                ..Scenario::base("multi_tenant")
            },
            // Decode-heavy storm: 8:1:1 Generate/Score/Classify under the
            // strong Zipf skew, issued by a fixed pool of closed-loop
            // clients (think time = the schedule's inter-arrival gaps).
            // The decode-lane saturation scenario: offered load self-
            // limits at the pool size, so it must neither shed nor error
            // while the windows stay Generate-dominated.
            Scenario {
                arrivals: Arrivals::Poisson { mean_gap_us: 250 },
                routing: Routing::Zipf { weights: ZIPF12.to_vec() },
                mix: Mix { score: 1, generate: 8, classify: 1 },
                policy: BatchPolicy { max_batch: 8, linger_us: 800 },
                closed_loop_clients: 8,
                ..Scenario::base("gen_storm")
            },
        ]
    }

    /// Look up a canned scenario by name.
    pub fn by_name(name: &str) -> Option<Scenario> {
        Scenario::canned().into_iter().find(|s| s.name == name)
    }
}
