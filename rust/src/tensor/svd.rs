//! Singular value decomposition.
//!
//! Two engines:
//! * [`jacobi_svd`] — one-sided Jacobi rotations; exact (to f32 precision),
//!   used for the residual design matrices (a few hundred columns).
//! * [`randomized_svd`] — Halko-style subspace iteration for a cheap
//!   rank-k sketch; used where only the top of the spectrum matters.
//!
//! Both return the thin factorization `a ≈ u @ diag(s) @ vt`.

use super::linalg::qr_thin;
use super::matrix::Matrix;
use crate::util::Rng;

/// Result of a (possibly truncated) SVD.
#[derive(Debug, Clone, PartialEq)]
pub struct Svd {
    pub u: Matrix,  // m × k
    pub s: Vec<f32>, // k, descending
    pub vt: Matrix, // k × n
}

impl Svd {
    /// Reconstruct `u @ diag(s) @ vt`.
    pub fn reconstruct(&self) -> Matrix {
        let mut us = self.u.clone();
        for r in 0..us.rows {
            let row = us.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                *v *= self.s[c];
            }
        }
        us.matmul(&self.vt)
    }

    /// Keep only the top `k` components.
    pub fn truncate(&self, k: usize) -> Svd {
        let k = k.min(self.s.len());
        Svd {
            u: self.u.slice_cols(0, k),
            s: self.s[..k].to_vec(),
            vt: self.vt.slice_rows(0, k),
        }
    }

    /// Parameter count of the factored representation (paper App. A.4:
    /// `m·k + k + k·n`).
    pub fn n_params(&self) -> usize {
        let k = self.s.len();
        self.u.rows * k + k + k * self.vt.cols
    }
}

/// Full thin SVD via one-sided Jacobi. Operates on the transposed problem
/// when `rows < cols` so the rotation loop always runs over the smaller
/// dimension's Gram matrix.
pub fn jacobi_svd(a: &Matrix) -> Svd {
    if a.rows < a.cols {
        let t = jacobi_svd(&a.transpose());
        // a = (a^T)^T = (U S V^T)^T = V S U^T
        return Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() };
    }
    let (m, n) = a.shape();
    // Work on columns of W = A (m >= n); accumulate V.
    let mut w = a.clone();
    let mut v = Matrix::identity(n);
    let max_sweeps = 60;
    let eps = 1e-10f64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries for columns p, q.
                let mut app = 0.0f64;
                let mut aqq = 0.0f64;
                let mut apq = 0.0f64;
                for i in 0..m {
                    let wp = w.at(i, p) as f64;
                    let wq = w.at(i, q) as f64;
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let wp = w.at(i, p);
                    let wq = w.at(i, q);
                    *w.at_mut(i, p) = cf * wp - sf * wq;
                    *w.at_mut(i, q) = sf * wp + cf * wq;
                }
                for i in 0..n {
                    let vp = v.at(i, p);
                    let vq = v.at(i, q);
                    *v.at_mut(i, p) = cf * vp - sf * vq;
                    *v.at_mut(i, q) = sf * vp + cf * vq;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }
    // Singular values = column norms of W; U = normalized columns.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n)
        .map(|c| (0..m).map(|r| (w.at(r, c) as f64).powi(2)).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&x, &y| norms[y].partial_cmp(&norms[x]).unwrap());
    let mut u = Matrix::zeros(m, n);
    let mut s = vec![0.0f32; n];
    let mut vt = Matrix::zeros(n, n);
    for (new_c, &old_c) in order.iter().enumerate() {
        let norm = norms[old_c];
        s[new_c] = norm as f32;
        if norm > 1e-20 {
            for r in 0..m {
                *u.at_mut(r, new_c) = (w.at(r, old_c) as f64 / norm) as f32;
            }
        }
        for r in 0..n {
            *vt.at_mut(new_c, r) = v.at(r, old_c);
        }
    }
    Svd { u, s, vt }
}

/// Randomized truncated SVD: rank `k`, `oversample` extra dims, `n_iter`
/// power iterations (Halko, Martinsson, Tropp 2011).
pub fn randomized_svd(a: &Matrix, k: usize, oversample: usize, n_iter: usize, rng: &mut Rng) -> Svd {
    let (m, n) = a.shape();
    let l = (k + oversample).min(n.min(m));
    // Range finder: Y = A Ω, orthonormalize, power-iterate.
    let omega = Matrix::randn(n, l, 1.0, rng);
    let mut q = qr_thin(&a.matmul(&omega)).0;
    for _ in 0..n_iter {
        let z = qr_thin(&a.matmul_tn(&q)).0; // A^T Q  → n × l
        q = qr_thin(&a.matmul(&z)).0;
    }
    // Project: B = Q^T A (l × n); small exact SVD of B.
    let b = q.matmul_tn(&a); // q^T @ a → l × n
    let svd_b = jacobi_svd(&b);
    let u = q.matmul(&svd_b.u);
    Svd { u, s: svd_b.s, vt: svd_b.vt }.truncate(k)
}

/// Best rank-k approximation error (squared Frobenius) — used in tests and
/// the ablation benches to sanity-check compressor optimality.
pub fn rank_k_error_sq(a: &Matrix, k: usize) -> f64 {
    let svd = jacobi_svd(a);
    svd.s.iter().skip(k).map(|&x| (x as f64) * (x as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reconstruction_error(a: &Matrix, svd: &Svd) -> f64 {
        svd.reconstruct().sq_dist(a)
    }

    #[test]
    fn svd_exact_reconstruction() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(12, 7, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        assert!(reconstruction_error(&a, &svd) < 1e-6);
    }

    #[test]
    fn svd_wide_matrix() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(5, 13, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        assert_eq!(svd.u.shape(), (5, 5));
        assert_eq!(svd.vt.shape(), (5, 13));
        assert!(reconstruction_error(&a, &svd) < 1e-6);
    }

    #[test]
    fn singular_values_descending_nonnegative() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(10, 10, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(svd.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn u_and_v_orthonormal() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(15, 8, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        let utu = svd.u.matmul_tn(&svd.u);
        let vvt = svd.vt.matmul_nt(&svd.vt);
        assert!(utu.sq_dist(&Matrix::identity(8)) < 1e-6);
        assert!(vvt.sq_dist(&Matrix::identity(8)) < 1e-6);
    }

    #[test]
    fn known_singular_values() {
        // diag(3, 2, 1) embedded in a rectangular matrix.
        let mut a = Matrix::zeros(5, 3);
        *a.at_mut(0, 0) = 3.0;
        *a.at_mut(1, 1) = 2.0;
        *a.at_mut(2, 2) = 1.0;
        let svd = jacobi_svd(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-5);
        assert!((svd.s[1] - 2.0).abs() < 1e-5);
        assert!((svd.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn truncation_error_matches_tail_energy() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        let k = 5;
        let err = svd.truncate(k).reconstruct().sq_dist(&a);
        let tail: f64 = svd.s.iter().skip(k).map(|&x| (x as f64) * (x as f64)).sum();
        assert!((err - tail).abs() / tail.max(1e-9) < 1e-3, "err={err} tail={tail}");
    }

    #[test]
    fn randomized_close_to_exact_on_low_rank() {
        let mut rng = Rng::new(6);
        // Build an exactly rank-4 matrix.
        let u = Matrix::randn(30, 4, 1.0, &mut rng);
        let v = Matrix::randn(4, 25, 1.0, &mut rng);
        let a = u.matmul(&v);
        let svd = randomized_svd(&a, 4, 4, 2, &mut rng);
        assert!(reconstruction_error(&a, &svd) < 1e-4 * a.frob_norm_sq());
    }

    #[test]
    fn n_params_formula() {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(16, 9, 1.0, &mut rng);
        let svd = jacobi_svd(&a).truncate(3);
        assert_eq!(svd.n_params(), 16 * 3 + 3 + 3 * 9);
    }

    #[test]
    fn rank_k_error_decreasing_in_k() {
        let mut rng = Rng::new(8);
        let a = Matrix::randn(14, 10, 1.0, &mut rng);
        let errs: Vec<f64> = (0..=10).map(|k| rank_k_error_sq(&a, k)).collect();
        for w in errs.windows(2) {
            assert!(w[0] >= w[1] - 1e-9);
        }
        assert!(errs[10] < 1e-6);
        assert!((errs[0] - a.frob_norm_sq()).abs() < 1e-3 * a.frob_norm_sq());
    }
}
