//! Dense linear algebra: Householder QR and helpers used by the SVD module
//! and the analysis tooling. No external LAPACK is available offline.
//!
//! §Perf: the reflector applications run **row-major** — one pass over the
//! matrix rows accumulates every column's `vᵀX` dot simultaneously, a
//! second pass applies the rank-1 update — so the inner loops are
//! contiguous axpys dispatched through the SIMD kernel layer. This is a
//! pure loop interchange: each `(i, j)` contribution is added in the same
//! `i` order as the historical column-major code, so results are
//! bit-identical under the scalar kernel.

use super::kernel;
use super::matrix::Matrix;

/// Apply the Householder reflector `H = I - 2 v vᵀ / (vᵀv)` to the row
/// range `k..m`, column range `lo..n`, of `x`, row-major (see module doc).
fn apply_reflector(x: &mut Matrix, v: &[f32], vnorm_sq: f32, k: usize, lo: usize) {
    let (m, n) = x.shape();
    let mut dots = vec![0.0f32; n - lo];
    for i in k..m {
        kernel::axpy(&mut dots, v[i - k], &x.row(i)[lo..n]);
    }
    for d in dots.iter_mut() {
        *d = 2.0 * *d / vnorm_sq;
    }
    for i in k..m {
        kernel::axpy(&mut x.row_mut(i)[lo..n], -v[i - k], &dots);
    }
}

/// Thin QR decomposition via Householder reflections: `a = q @ r` with
/// `q` (m×n, orthonormal columns) and `r` (n×n upper triangular). Requires
/// m >= n.
pub fn qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = a.shape();
    assert!(m >= n, "qr_thin requires m >= n, got {m}x{n}");
    let mut r = a.clone();
    // Householder vectors stored per column.
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n);
    for k in 0..n {
        // Build the reflector for column k from rows k..m.
        let mut v: Vec<f32> = (k..m).map(|i| r.at(i, k)).collect();
        let norm = v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32;
        if norm > 0.0 {
            let sign = if v[0] >= 0.0 { 1.0 } else { -1.0 };
            v[0] += sign * norm;
            let vnorm_sq: f32 = v.iter().map(|x| x * x).sum();
            if vnorm_sq > 0.0 {
                apply_reflector(&mut r, &v, vnorm_sq, k, k);
            }
        }
        vs.push(v);
    }
    // Accumulate Q by applying the reflectors to the identity (thin: first n
    // columns only).
    let mut q = Matrix::from_fn(m, n, |i, j| if i == j { 1.0 } else { 0.0 });
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm_sq: f32 = v.iter().map(|x| x * x).sum();
        if vnorm_sq == 0.0 {
            continue;
        }
        apply_reflector(&mut q, v, vnorm_sq, k, 0);
    }
    // Zero the strictly-lower part of the top n×n of R.
    let mut r_out = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            *r_out.at_mut(i, j) = r.at(i, j);
        }
    }
    (q, r_out)
}

/// Squared column norms of a matrix.
pub fn col_norms_sq(a: &Matrix) -> Vec<f64> {
    let mut out = vec![0.0f64; a.cols];
    for r in 0..a.rows {
        let row = a.row(r);
        for (c, &v) in row.iter().enumerate() {
            out[c] += (v as f64) * (v as f64);
        }
    }
    out
}

/// Row L2 norms.
pub fn row_norms(a: &Matrix) -> Vec<f64> {
    (0..a.rows)
        .map(|r| a.row(r).iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(20, 8, 1.0, &mut rng);
        let (q, r) = qr_thin(&a);
        let qr = q.matmul(&r);
        assert!(qr.sq_dist(&a) < 1e-6, "dist={}", qr.sq_dist(&a));
    }

    #[test]
    fn q_has_orthonormal_columns() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(30, 10, 1.0, &mut rng);
        let (q, _) = qr_thin(&a);
        let qtq = q.matmul_tn(&q);
        let eye = Matrix::identity(10);
        assert!(qtq.sq_dist(&eye) < 1e-6, "dist={}", qtq.sq_dist(&eye));
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(12, 6, 1.0, &mut rng);
        let (_, r) = qr_thin(&a);
        for i in 0..6 {
            for j in 0..i {
                assert_eq!(r.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn qr_square_and_rank_deficient() {
        // A rank-1 square matrix should still factor with small residual.
        let u = Matrix::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let v = Matrix::from_vec(1, 4, vec![1.0, 0.5, -1.0, 2.0]);
        let a = u.matmul(&v);
        let (q, r) = qr_thin(&a);
        assert!(q.matmul(&r).sq_dist(&a) < 1e-8);
    }

    #[test]
    fn norms() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 0.0, 4.0, 1.0]);
        let cn = col_norms_sq(&a);
        assert!((cn[0] - 25.0).abs() < 1e-9);
        assert!((cn[1] - 1.0).abs() < 1e-9);
        let rn = row_norms(&a);
        assert!((rn[0] - 3.0).abs() < 1e-9);
        assert!((rn[1] - (17.0f64).sqrt()).abs() < 1e-9);
    }
}
