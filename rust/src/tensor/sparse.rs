//! Sparse matrix storage for pruned residuals.
//!
//! The paper's Appendix A.7 observes that PyTorch's COO format with int64
//! indices makes a 75 %-pruned Mixtral MLP *larger* than the dense original
//! (840 MB vs 672 MB) and that int16 indices + CSR would shrink it to 252 MB.
//! We implement exactly that spectrum so Table 10 can report real bytes:
//!
//! * [`Coo`] with configurable index width (16/32/64-bit),
//! * [`Csr`] with u32 row pointers and 16/32-bit column indices,
//! * dense round-trips, `A @ x`, `A^T x`, dense accumulation (`restore`).

use super::matrix::Matrix;

/// Index bit-width for sparse coordinates — the storage knob from App. A.7.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IndexWidth {
    U16,
    U32,
    U64,
}

impl IndexWidth {
    pub fn bytes(self) -> usize {
        match self {
            IndexWidth::U16 => 2,
            IndexWidth::U32 => 4,
            IndexWidth::U64 => 8,
        }
    }

    /// Narrowest width able to address `max_dim`.
    pub fn narrowest_for(max_dim: usize) -> IndexWidth {
        if max_dim <= u16::MAX as usize + 1 {
            IndexWidth::U16
        } else if max_dim <= u32::MAX as usize + 1 {
            IndexWidth::U32
        } else {
            IndexWidth::U64
        }
    }
}

/// COO sparse matrix (sorted by (row, col); indices stored as u32 in memory,
/// `index_width` only affects the *accounted/serialized* byte size).
#[derive(Debug, Clone, PartialEq)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub row_idx: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
    pub index_width: IndexWidth,
}

impl Coo {
    /// Extract nonzeros from a dense matrix.
    pub fn from_dense(m: &Matrix, index_width: IndexWidth) -> Coo {
        let mut row_idx = Vec::new();
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        for r in 0..m.rows {
            for c in 0..m.cols {
                let v = m.at(r, c);
                if v != 0.0 {
                    row_idx.push(r as u32);
                    col_idx.push(c as u32);
                    values.push(v);
                }
            }
        }
        Coo { rows: m.rows, cols: m.cols, row_idx, col_idx, values, index_width }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.nnz() {
            *out.at_mut(self.row_idx[i] as usize, self.col_idx[i] as usize) = self.values[i];
        }
        out
    }

    /// Storage bytes at the accounted index width (2 indices + 1 f32 per nnz).
    pub fn memory_bytes(&self) -> usize {
        self.nnz() * (2 * self.index_width.bytes() + 4)
    }

    /// dense += self (the ResMoE `W_ω + Δ_k` restore step).
    pub fn add_to_dense(&self, dense: &mut Matrix) {
        assert_eq!((dense.rows, dense.cols), (self.rows, self.cols));
        for i in 0..self.nnz() {
            *dense.at_mut(self.row_idx[i] as usize, self.col_idx[i] as usize) += self.values[i];
        }
    }
}

/// CSR sparse matrix: u32 row pointers, configurable column-index width.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
    pub index_width: IndexWidth,
}

impl Csr {
    pub fn from_dense(m: &Matrix, index_width: IndexWidth) -> Csr {
        let mut row_ptr = Vec::with_capacity(m.rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for r in 0..m.rows {
            for c in 0..m.cols {
                let v = m.at(r, c);
                if v != 0.0 {
                    col_idx.push(c as u32);
                    values.push(v);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        Csr { rows: m.rows, cols: m.cols, row_ptr, col_idx, values, index_width }
    }

    pub fn from_coo(coo: &Coo) -> Csr {
        Csr::from_dense(&coo.to_dense(), coo.index_width)
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                *out.at_mut(r, self.col_idx[i] as usize) = self.values[i];
            }
        }
        out
    }

    /// Storage bytes: col indices at accounted width + f32 values + u32 row
    /// pointers (the CSR layout from App. A.7's 252 MB estimate).
    pub fn memory_bytes(&self) -> usize {
        self.nnz() * (self.index_width.bytes() + 4) + (self.rows + 1) * 4
    }

    /// y = self @ x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            let mut acc = 0.0f32;
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                acc += self.values[i] * x[self.col_idx[i] as usize];
            }
            y[r] = acc;
        }
        y
    }

    /// dense += self.
    pub fn add_to_dense(&self, dense: &mut Matrix) {
        assert_eq!((dense.rows, dense.cols), (self.rows, self.cols));
        for r in 0..self.rows {
            let row = dense.row_mut(r);
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                row[self.col_idx[i] as usize] += self.values[i];
            }
        }
    }

    /// C = self @ dense  (sparse-dense matmul; used when applying a pruned
    /// residual directly to a batch of activations).
    pub fn matmul_dense(&self, dense: &Matrix) -> Matrix {
        assert_eq!(self.cols, dense.rows);
        let mut out = Matrix::zeros(self.rows, dense.cols);
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                let v = self.values[i];
                let k = self.col_idx[i] as usize;
                let src = dense.row(k);
                let dst = out.row_mut(r);
                for (d, &s) in dst.iter_mut().zip(src.iter()) {
                    *d += v * s;
                }
            }
        }
        out
    }

    /// out (+)= x @ selfᵀ — the fused-forward SpMM (restore-free expert
    /// up/gate projection). `self` is the (pI × p) sparse residual piece,
    /// `x` the (B × p) activations, `out` (B × pI). Runs at O(B · nnz)
    /// instead of the O(B · pI · p) a restored dense weight would cost;
    /// large batches fan out over the worker pool. Dispatches to the
    /// gather-free AVX2 panel kernel or the scalar twin per
    /// [`crate::tensor::kernel::kernel_kind`].
    pub fn matmul_nt_into(&self, x: &Matrix, out: &mut Matrix, accumulate: bool) {
        self.matmul_nt_into_with(crate::tensor::kernel::kernel_kind(), x, out, accumulate);
    }

    /// [`Self::matmul_nt_into`] under an explicit kernel kind (tests and
    /// benches force kinds).
    pub fn matmul_nt_into_with(
        &self,
        kind: crate::tensor::KernelKind,
        x: &Matrix,
        out: &mut Matrix,
        accumulate: bool,
    ) {
        crate::tensor::kernel::csr_matmul_nt_into_with(kind, self, x, out, accumulate);
    }

    /// Scalar SpMM twin: per batch row, dot each CSR row against the
    /// activation row (L1-resident random access). Assumes `out` was
    /// zero-filled or carries the accumulation seed.
    pub(crate) fn matmul_nt_scalar(&self, x: &Matrix, out: &mut Matrix) {
        let row_kernel = |b: usize, out_row: &mut [f32]| {
            let x_row = x.row(b);
            for r in 0..self.rows {
                let lo = self.row_ptr[r] as usize;
                let hi = self.row_ptr[r + 1] as usize;
                if lo == hi {
                    continue;
                }
                let mut acc = 0.0f32;
                for i in lo..hi {
                    acc += self.values[i] * x_row[self.col_idx[i] as usize];
                }
                out_row[r] += acc;
            }
        };
        if x.rows * self.nnz() >= crate::tensor::matrix::PAR_MIN_FLOPS && x.rows > 1 {
            crate::util::threads::parallel_rows_mut(
                &mut out.data,
                x.rows,
                self.rows,
                |b, row| row_kernel(b, row),
            );
        } else {
            for b in 0..x.rows {
                let row = &mut out.data[b * self.rows..(b + 1) * self.rows];
                row_kernel(b, row);
            }
        }
    }

    /// out += h @ self — the fused-forward down-projection correction
    /// (h: B × pI, self: pI × p, out: B × p). Dispatches per
    /// [`crate::tensor::kernel::kernel_kind`].
    pub fn matmul_acc_into(&self, h: &Matrix, out: &mut Matrix) {
        self.matmul_acc_into_with(crate::tensor::kernel::kernel_kind(), h, out);
    }

    /// [`Self::matmul_acc_into`] under an explicit kernel kind.
    pub fn matmul_acc_into_with(
        &self,
        kind: crate::tensor::KernelKind,
        h: &Matrix,
        out: &mut Matrix,
    ) {
        crate::tensor::kernel::csr_matmul_acc_into_with(kind, self, h, out);
    }

    /// Scalar down-projection twin — row-scatter form: zero activations
    /// (ReLU) skip their whole CSR row.
    pub(crate) fn matmul_acc_scalar(&self, h: &Matrix, out: &mut Matrix) {
        for b in 0..h.rows {
            let h_row = h.row(b);
            let out_row = out.row_mut(b);
            for r in 0..self.rows {
                let hv = h_row[r];
                if hv == 0.0 {
                    continue;
                }
                for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                    out_row[self.col_idx[i] as usize] += hv * self.values[i];
                }
            }
        }
    }

    /// Columns `[lo, hi)` as a new CSR with rebased column indices — the
    /// fused forward splits the design-matrix residual into per-weight
    /// pieces once at build time.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Csr {
        assert!(lo <= hi && hi <= self.cols, "csr slice_cols range");
        let mut row_ptr = Vec::with_capacity(self.rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                let c = self.col_idx[i] as usize;
                if c >= lo && c < hi {
                    col_idx.push((c - lo) as u32);
                    values.push(self.values[i]);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        Csr {
            rows: self.rows,
            cols: hi - lo,
            row_ptr,
            col_idx,
            values,
            index_width: self.index_width,
        }
    }

    /// Column `c` densified (splits the b1/b3 bias deltas out of the
    /// residual design matrix).
    pub fn col_dense(&self, c: usize) -> Vec<f32> {
        assert!(c < self.cols, "csr col_dense range");
        let mut out = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                if self.col_idx[i] as usize == c {
                    out[r] = self.values[i];
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sparse_random(rows: usize, cols: usize, density: f64, rng: &mut Rng) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| {
            if rng.uniform() < density {
                rng.normal()
            } else {
                0.0
            }
        })
    }

    #[test]
    fn coo_roundtrip() {
        let mut rng = Rng::new(1);
        let m = sparse_random(17, 23, 0.2, &mut rng);
        let coo = Coo::from_dense(&m, IndexWidth::U16);
        assert_eq!(coo.to_dense(), m);
    }

    #[test]
    fn csr_roundtrip() {
        let mut rng = Rng::new(2);
        let m = sparse_random(31, 9, 0.3, &mut rng);
        let csr = Csr::from_dense(&m, IndexWidth::U16);
        assert_eq!(csr.to_dense(), m);
        assert_eq!(csr.nnz(), Coo::from_dense(&m, IndexWidth::U16).nnz());
    }

    #[test]
    fn coo_to_csr() {
        let mut rng = Rng::new(3);
        let m = sparse_random(12, 12, 0.25, &mut rng);
        let coo = Coo::from_dense(&m, IndexWidth::U32);
        let csr = Csr::from_coo(&coo);
        assert_eq!(csr.to_dense(), m);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Rng::new(4);
        let m = sparse_random(20, 15, 0.3, &mut rng);
        let x = rng.normal_vec(15, 1.0);
        let csr = Csr::from_dense(&m, IndexWidth::U16);
        let y_sparse = csr.matvec(&x);
        let y_dense = m.matvec(&x);
        for (a, b) in y_sparse.iter().zip(&y_dense) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_dense_matches() {
        let mut rng = Rng::new(5);
        let m = sparse_random(10, 8, 0.4, &mut rng);
        let d = Matrix::randn(8, 6, 1.0, &mut rng);
        let csr = Csr::from_dense(&m, IndexWidth::U16);
        let got = csr.matmul_dense(&d);
        let want = m.matmul(&d);
        assert!(got.sq_dist(&want) < 1e-8);
    }

    #[test]
    fn restore_add_to_dense() {
        let mut rng = Rng::new(6);
        let base = Matrix::randn(9, 9, 1.0, &mut rng);
        let delta = sparse_random(9, 9, 0.2, &mut rng);
        let coo = Coo::from_dense(&delta, IndexWidth::U16);
        let mut restored = base.clone();
        coo.add_to_dense(&mut restored);
        assert!(restored.sq_dist(&base.add(&delta)) < 1e-10);

        let csr = Csr::from_dense(&delta, IndexWidth::U16);
        let mut restored2 = base.clone();
        csr.add_to_dense(&mut restored2);
        assert!(restored2.sq_dist(&base.add(&delta)) < 1e-10);
    }

    #[test]
    fn paper_a7_memory_accounting() {
        // Reproduce App. A.7's arithmetic shape: at 25 % density, COO+int64
        // is LARGER than dense; CSR+int16 is much smaller.
        let rows = 1024;
        let cols = 1024;
        let dense_bytes = rows * cols * 4;
        let mut rng = Rng::new(7);
        let m = sparse_random(rows, cols, 0.25, &mut rng);
        let coo64 = Coo::from_dense(&m, IndexWidth::U64);
        let csr16 = Csr::from_dense(&m, IndexWidth::U16);
        assert!(
            coo64.memory_bytes() > dense_bytes,
            "COO int64 at 25% density should exceed dense: {} vs {}",
            coo64.memory_bytes(),
            dense_bytes
        );
        assert!(csr16.memory_bytes() < dense_bytes / 2);
    }

    #[test]
    fn narrowest_index_width() {
        assert_eq!(IndexWidth::narrowest_for(1000), IndexWidth::U16);
        assert_eq!(IndexWidth::narrowest_for(65536), IndexWidth::U16);
        assert_eq!(IndexWidth::narrowest_for(65537), IndexWidth::U32);
        assert_eq!(IndexWidth::narrowest_for(1 << 40), IndexWidth::U64);
    }

    #[test]
    fn spmm_nt_matches_dense() {
        // x @ Δᵀ through the CSR kernel == densified matmul_nt, with and
        // without accumulation, across densities incl. empty.
        let mut rng = Rng::new(8);
        for density in [0.0, 0.05, 0.25, 1.0] {
            let delta = sparse_random(14, 9, density, &mut rng);
            let csr = Csr::from_dense(&delta, IndexWidth::U16);
            let x = Matrix::randn(6, 9, 1.0, &mut rng);
            let mut got = Matrix::zeros(6, 14);
            csr.matmul_nt_into(&x, &mut got, false);
            let want = x.matmul_nt(&delta);
            assert!(got.sq_dist(&want) < 1e-8, "density {density}");

            let seed = Matrix::randn(6, 14, 1.0, &mut rng);
            let mut acc = seed.clone();
            csr.matmul_nt_into(&x, &mut acc, true);
            assert!(acc.sq_dist(&seed.add(&want)) < 1e-8);
        }
    }

    #[test]
    fn spmm_acc_matches_dense() {
        let mut rng = Rng::new(9);
        let delta = sparse_random(12, 7, 0.3, &mut rng);
        let csr = Csr::from_dense(&delta, IndexWidth::U16);
        let h = Matrix::randn(5, 12, 1.0, &mut rng);
        let seed = Matrix::randn(5, 7, 1.0, &mut rng);
        let mut got = seed.clone();
        csr.matmul_acc_into(&h, &mut got);
        let want = seed.add(&h.matmul(&delta));
        assert!(got.sq_dist(&want) < 1e-8);
    }

    #[test]
    fn slice_cols_and_col_dense() {
        let mut rng = Rng::new(10);
        let m = sparse_random(11, 13, 0.3, &mut rng);
        let csr = Csr::from_dense(&m, IndexWidth::U16);
        let sliced = csr.slice_cols(3, 9);
        assert_eq!(sliced.to_dense(), m.slice_cols(3, 9));
        // Degenerate slices.
        assert_eq!(csr.slice_cols(5, 5).nnz(), 0);
        assert_eq!(csr.slice_cols(0, 13).to_dense(), m);
        for c in 0..13 {
            assert_eq!(csr.col_dense(c), m.col(c));
        }
    }

    #[test]
    fn spmm_matches_dense_under_every_kernel() {
        // Both SpMM ops, both kernel kinds, ragged batch sizes straddling
        // the 8-lane SpMM tile (1, 7, 8, 9) and densities incl. empty/full.
        use crate::tensor::kernel::{kernel_kind, KernelKind};
        let mut kinds = vec![KernelKind::Scalar];
        if kernel_kind() != KernelKind::Scalar {
            kinds.push(kernel_kind());
        }
        let mut rng = Rng::new(20);
        for density in [0.0, 0.05, 0.25, 1.0] {
            let delta = sparse_random(14, 9, density, &mut rng);
            let csr = Csr::from_dense(&delta, IndexWidth::U16);
            for b in [1usize, 7, 8, 9] {
                let x = Matrix::randn(b, 9, 1.0, &mut rng);
                let want = x.matmul(&delta.transpose());
                for &kind in &kinds {
                    let mut got = Matrix::zeros(b, 14);
                    csr.matmul_nt_into_with(kind, &x, &mut got, false);
                    assert!(
                        got.sq_dist(&want) < 1e-6 * want.frob_norm_sq().max(1.0),
                        "{kind:?} nt d={density} b={b}: {}",
                        got.sq_dist(&want)
                    );
                    let seed = Matrix::randn(b, 14, 1.0, &mut rng);
                    let mut acc = seed.clone();
                    csr.matmul_nt_into_with(kind, &x, &mut acc, true);
                    assert!(acc.sq_dist(&seed.add(&want)) < 1e-6 * want.frob_norm_sq().max(1.0));

                    let h = Matrix::randn(b, 14, 1.0, &mut rng);
                    let want_acc = h.matmul(&delta);
                    let seed2 = Matrix::randn(b, 9, 1.0, &mut rng);
                    let mut got2 = seed2.clone();
                    csr.matmul_acc_into_with(kind, &h, &mut got2);
                    assert!(
                        got2.sq_dist(&seed2.add(&want_acc)) < 1e-6 * want_acc.frob_norm_sq().max(1.0),
                        "{kind:?} acc d={density} b={b}"
                    );
                }
            }
        }
    }

    #[test]
    fn spmm_rows_are_batch_position_independent() {
        // The serving bit-parity theorem for the sparse path: a batch row's
        // result is independent of its position / batch size, under the
        // ACTIVE kernel (whatever RESMOE_SIMD resolved), including ragged
        // 8-lane tile tails (7 = 4 + 3).
        let mut rng = Rng::new(21);
        let delta = sparse_random(12, 10, 0.3, &mut rng);
        let csr = Csr::from_dense(&delta, IndexWidth::U16);
        let xa = Matrix::randn(4, 10, 1.0, &mut rng);
        let xb = Matrix::randn(3, 10, 1.0, &mut rng);
        let cat = xa.vcat(&xb);
        let mut full = Matrix::zeros(7, 12);
        csr.matmul_nt_into(&cat, &mut full, false);
        let mut ya = Matrix::zeros(4, 12);
        csr.matmul_nt_into(&xa, &mut ya, false);
        let mut yb = Matrix::zeros(3, 12);
        csr.matmul_nt_into(&xb, &mut yb, false);
        assert_eq!(full.data, ya.vcat(&yb).data, "spmm_nt rows must be position-independent");

        let ha = Matrix::randn(4, 12, 1.0, &mut rng);
        let hb = Matrix::randn(3, 12, 1.0, &mut rng);
        let hcat = ha.vcat(&hb);
        let mut out_full = Matrix::zeros(7, 10);
        csr.matmul_acc_into(&hcat, &mut out_full);
        let mut oa = Matrix::zeros(4, 10);
        csr.matmul_acc_into(&ha, &mut oa);
        let mut ob = Matrix::zeros(3, 10);
        csr.matmul_acc_into(&hb, &mut ob);
        assert_eq!(out_full.data, oa.vcat(&ob).data, "spmm_acc rows must be position-independent");
    }

    #[test]
    fn empty_matrix_ok() {
        let m = Matrix::zeros(5, 5);
        let coo = Coo::from_dense(&m, IndexWidth::U16);
        assert_eq!(coo.nnz(), 0);
        assert_eq!(coo.memory_bytes(), 0);
        assert_eq!(coo.to_dense(), m);
    }
}
