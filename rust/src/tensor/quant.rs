//! Int8 symmetric quantization for residual shards (PR 6).
//!
//! ResMoE keeps the shared barycenter `W_ω` in f32 and compresses only the
//! per-expert residual Δ_k; this module adds the int8 tier for those
//! residuals: per-row symmetric scales (`scale_r = absmax_r / 127`, code =
//! `round(v / scale)` clamped to ±127), so dequantization is a single f32
//! multiply `code as f32 * scale` — one rounding per element.
//!
//! **Numerics contract.** The dequant-fused kernels (`kernel::qmatmul_*`)
//! compute exactly that dequantized value in-register and then run the
//! *identical* FMA fold as the f32 kernels of the same [`KernelKind`], so
//! `fused(q) == gemm(dequant(q))` holds BITWISE per kind. Quantization
//! error against the original f32 residual is bounded per element by
//! `0.5 · scale_r` (plus a small f32-rounding slack); [`QuantMatrix::
//! abs_error_bound`] advertises that bound and the pack/store layers carry
//! it per shard so serve paths can property-test against it.

use super::matrix::Matrix;
use super::sparse::{Csr, IndexWidth};
use crate::tensor::kernel::{self, KernelKind};

/// Multiplicative slack on the `0.5·scale` error bound covering the f32
/// roundings in `v/scale` and `code·scale` (each exact to within half an
/// ulp; 1e-3 is orders of magnitude more than needed and keeps the bound a
/// one-liner).
pub const QUANT_BOUND_SLACK: f32 = 1.0 + 1e-3;

/// Dense row-major int8 matrix with one symmetric scale per row.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Row-major codes; value = `data[r*cols + c] as f32 * scales[r]`.
    pub data: Vec<i8>,
    /// Per-row dequantization scales (`absmax_r / 127`; 0.0 for zero rows).
    pub scales: Vec<f32>,
}

impl QuantMatrix {
    /// Quantize a dense f32 matrix with per-row symmetric scales.
    pub fn quantize(m: &Matrix) -> QuantMatrix {
        let mut data = vec![0i8; m.rows * m.cols];
        let mut scales = vec![0.0f32; m.rows];
        for r in 0..m.rows {
            let row = m.row(r);
            let mut absmax = 0.0f32;
            for &v in row {
                absmax = absmax.max(v.abs());
            }
            if absmax == 0.0 {
                continue; // zero row: scale 0.0, codes stay 0
            }
            let scale = absmax / 127.0;
            scales[r] = scale;
            for (o, &v) in data[r * m.cols..(r + 1) * m.cols].iter_mut().zip(row) {
                *o = (v / scale).round().clamp(-127.0, 127.0) as i8;
            }
        }
        QuantMatrix { rows: m.rows, cols: m.cols, data, scales }
    }

    /// Dequantize to a dense f32 matrix (the reference the fused kernels
    /// must match bitwise).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let s = self.scales[r];
            let codes = &self.data[r * self.cols..(r + 1) * self.cols];
            for (o, &q) in out.row_mut(r).iter_mut().zip(codes) {
                *o = q as f32 * s;
            }
        }
        out
    }

    pub fn n_params(&self) -> usize {
        self.rows * self.cols
    }

    /// Storage bytes: 1 byte per code + one f32 scale per row.
    pub fn memory_bytes(&self) -> usize {
        self.data.len() + self.rows * 4
    }

    /// Advertised per-element |orig − dequant| bound: `0.5 · max_r scale_r`
    /// with [`QUANT_BOUND_SLACK`].
    pub fn abs_error_bound(&self) -> f32 {
        let maxs = self.scales.iter().cloned().fold(0.0f32, f32::max);
        0.5 * maxs * QUANT_BOUND_SLACK
    }

    /// Columns `[lo, hi)` as a new quantized matrix (scales are per row, so
    /// a column slice keeps them — still a valid quantization of the sliced
    /// dequantized matrix under the same bound).
    pub fn slice_cols(&self, lo: usize, hi: usize) -> QuantMatrix {
        assert!(lo <= hi && hi <= self.cols, "quant slice_cols range");
        let w = hi - lo;
        let mut data = vec![0i8; self.rows * w];
        for r in 0..self.rows {
            data[r * w..(r + 1) * w]
                .copy_from_slice(&self.data[r * self.cols + lo..r * self.cols + hi]);
        }
        QuantMatrix { rows: self.rows, cols: w, data, scales: self.scales.clone() }
    }

    /// Column `c` dequantized (splits bias deltas out of a quantized design
    /// matrix, mirroring `Csr::col_dense`).
    pub fn col_dense(&self, c: usize) -> Vec<f32> {
        assert!(c < self.cols, "quant col_dense range");
        (0..self.rows).map(|r| self.data[r * self.cols + c] as f32 * self.scales[r]).collect()
    }

    /// out (+)= x @ selfᵀ with dequantization fused into the microkernel
    /// (no materialized f32 matrix). Bitwise equal to
    /// `x.matmul_nt(&self.to_dense())` under the same kernel kind.
    pub fn matmul_nt_into(&self, x: &Matrix, out: &mut Matrix, accumulate: bool) {
        self.matmul_nt_into_with(kernel::kernel_kind(), x, out, accumulate);
    }

    /// [`Self::matmul_nt_into`] under an explicit kernel kind.
    pub fn matmul_nt_into_with(
        &self,
        kind: KernelKind,
        x: &Matrix,
        out: &mut Matrix,
        accumulate: bool,
    ) {
        kernel::qmatmul_nt_into_with(kind, x, self, out, accumulate);
    }

    /// out += h @ self (NN orientation; the fused down-projection
    /// correction), dequant-fused.
    pub fn matmul_acc_into(&self, h: &Matrix, out: &mut Matrix) {
        self.matmul_acc_into_with(kernel::kernel_kind(), h, out);
    }

    /// [`Self::matmul_acc_into`] under an explicit kernel kind.
    pub fn matmul_acc_into_with(&self, kind: KernelKind, h: &Matrix, out: &mut Matrix) {
        kernel::qmatmul_acc_into_with(kind, h, self, out);
    }
}

/// CSR sparse matrix with int8 values and one symmetric scale per row
/// (structure mirrors [`Csr`]; indices stay at the accounted width).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantCsr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<i8>,
    /// Per-row scales over the row's nonzeros (0.0 for empty/zero rows).
    pub scales: Vec<f32>,
    pub index_width: IndexWidth,
}

impl QuantCsr {
    /// Quantize an f32 CSR's values with per-row symmetric scales (the
    /// sparsity pattern is preserved exactly).
    pub fn quantize(csr: &Csr) -> QuantCsr {
        let mut values = vec![0i8; csr.nnz()];
        let mut scales = vec![0.0f32; csr.rows];
        for r in 0..csr.rows {
            let lo = csr.row_ptr[r] as usize;
            let hi = csr.row_ptr[r + 1] as usize;
            let mut absmax = 0.0f32;
            for &v in &csr.values[lo..hi] {
                absmax = absmax.max(v.abs());
            }
            if absmax == 0.0 {
                continue;
            }
            let scale = absmax / 127.0;
            scales[r] = scale;
            for (o, &v) in values[lo..hi].iter_mut().zip(&csr.values[lo..hi]) {
                *o = (v / scale).round().clamp(-127.0, 127.0) as i8;
            }
        }
        QuantCsr {
            rows: csr.rows,
            cols: csr.cols,
            row_ptr: csr.row_ptr.clone(),
            col_idx: csr.col_idx.clone(),
            values,
            scales,
            index_width: csr.index_width,
        }
    }

    /// Dequantize to an f32 CSR (reference for the bitwise-fused contract).
    pub fn to_csr(&self) -> Csr {
        let mut values = vec![0.0f32; self.nnz()];
        for r in 0..self.rows {
            let s = self.scales[r];
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                values[i] = self.values[i] as f32 * s;
            }
        }
        Csr {
            rows: self.rows,
            cols: self.cols,
            row_ptr: self.row_ptr.clone(),
            col_idx: self.col_idx.clone(),
            values,
            index_width: self.index_width,
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn n_params(&self) -> usize {
        self.nnz()
    }

    /// Storage bytes: 1-byte values + accounted-width col indices + u32 row
    /// pointers + per-row f32 scales.
    pub fn memory_bytes(&self) -> usize {
        self.nnz() * (self.index_width.bytes() + 1) + (self.rows + 1) * 4 + self.rows * 4
    }

    /// Advertised per-nonzero |orig − dequant| bound (`0.5 · max_r scale_r`
    /// with slack); zeros are stored structurally and carry no error.
    pub fn abs_error_bound(&self) -> f32 {
        let maxs = self.scales.iter().cloned().fold(0.0f32, f32::max);
        0.5 * maxs * QUANT_BOUND_SLACK
    }

    /// Columns `[lo, hi)` with rebased indices (per-row scales survive a
    /// column slice unchanged).
    pub fn slice_cols(&self, lo: usize, hi: usize) -> QuantCsr {
        assert!(lo <= hi && hi <= self.cols, "quant csr slice_cols range");
        let mut row_ptr = Vec::with_capacity(self.rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                let c = self.col_idx[i] as usize;
                if c >= lo && c < hi {
                    col_idx.push((c - lo) as u32);
                    values.push(self.values[i]);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        QuantCsr {
            rows: self.rows,
            cols: hi - lo,
            row_ptr,
            col_idx,
            values,
            scales: self.scales.clone(),
            index_width: self.index_width,
        }
    }

    /// Column `c` dequantized.
    pub fn col_dense(&self, c: usize) -> Vec<f32> {
        assert!(c < self.cols, "quant csr col_dense range");
        let mut out = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                if self.col_idx[i] as usize == c {
                    out[r] = self.values[i] as f32 * self.scales[r];
                }
            }
        }
        out
    }

    /// out (+)= x @ selfᵀ, dequant-fused SpMM. Bitwise equal to
    /// `self.to_csr().matmul_nt_into(..)` under the same kernel kind.
    pub fn matmul_nt_into(&self, x: &Matrix, out: &mut Matrix, accumulate: bool) {
        self.matmul_nt_into_with(kernel::kernel_kind(), x, out, accumulate);
    }

    /// [`Self::matmul_nt_into`] under an explicit kernel kind.
    pub fn matmul_nt_into_with(
        &self,
        kind: KernelKind,
        x: &Matrix,
        out: &mut Matrix,
        accumulate: bool,
    ) {
        kernel::qcsr_matmul_nt_into_with(kind, self, x, out, accumulate);
    }

    /// out += h @ self, dequant-fused.
    pub fn matmul_acc_into(&self, h: &Matrix, out: &mut Matrix) {
        self.matmul_acc_into_with(kernel::kernel_kind(), h, out);
    }

    /// [`Self::matmul_acc_into`] under an explicit kernel kind.
    pub fn matmul_acc_into_with(&self, kind: KernelKind, h: &Matrix, out: &mut Matrix) {
        kernel::qcsr_matmul_acc_into_with(kind, self, h, out);
    }

    /// Scalar dequant-fused SpMM twin (mirrors `Csr::matmul_nt_scalar`
    /// with `v = code·scale_r` computed inline — same single rounding as a
    /// materialized dequant, so results are bitwise equal to it).
    pub(crate) fn matmul_nt_scalar(&self, x: &Matrix, out: &mut Matrix) {
        let row_kernel = |b: usize, out_row: &mut [f32]| {
            let x_row = x.row(b);
            for r in 0..self.rows {
                let lo = self.row_ptr[r] as usize;
                let hi = self.row_ptr[r + 1] as usize;
                if lo == hi {
                    continue;
                }
                let s = self.scales[r];
                let mut acc = 0.0f32;
                for i in lo..hi {
                    acc += (self.values[i] as f32 * s) * x_row[self.col_idx[i] as usize];
                }
                out_row[r] += acc;
            }
        };
        if x.rows * self.nnz() >= crate::tensor::matrix::PAR_MIN_FLOPS && x.rows > 1 {
            crate::util::threads::parallel_rows_mut(&mut out.data, x.rows, self.rows, |b, row| {
                row_kernel(b, row)
            });
        } else {
            for b in 0..x.rows {
                let row = &mut out.data[b * self.rows..(b + 1) * self.rows];
                row_kernel(b, row);
            }
        }
    }

    /// Scalar dequant-fused down-projection twin (mirrors
    /// `Csr::matmul_acc_scalar`; the `hv == 0` skip depends only on `h`).
    pub(crate) fn matmul_acc_scalar(&self, h: &Matrix, out: &mut Matrix) {
        for b in 0..h.rows {
            let h_row = h.row(b);
            let out_row = out.row_mut(b);
            for r in 0..self.rows {
                let hv = h_row[r];
                if hv == 0.0 {
                    continue;
                }
                let s = self.scales[r];
                for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                    out_row[self.col_idx[i] as usize] += hv * (self.values[i] as f32 * s);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn active_kinds() -> Vec<KernelKind> {
        let mut kinds = vec![KernelKind::Scalar];
        if kernel::kernel_kind() != KernelKind::Scalar {
            kinds.push(kernel::kernel_kind());
        }
        kinds
    }

    #[test]
    fn dense_roundtrip_within_bound() {
        let mut rng = Rng::new(60);
        for (r, c) in [(1usize, 1usize), (7, 13), (16, 64), (33, 5)] {
            let m = Matrix::randn(r, c, 1.5, &mut rng);
            let q = QuantMatrix::quantize(&m);
            let back = q.to_dense();
            let bound = q.abs_error_bound();
            let mut worst = 0.0f32;
            for (a, b) in m.data.iter().zip(&back.data) {
                worst = worst.max((a - b).abs());
            }
            assert!(worst <= bound, "{r}x{c}: err {worst} > bound {bound}");
            assert!(bound > 0.0);
            // int8 + per-row scales must be ≤ ~0.30× the f32 bytes at these
            // shapes (4 bytes → 1 byte + scale amortized over the row).
            assert!(q.memory_bytes() * 100 <= m.data.len() * 4 * 30 + 400);
        }
    }

    #[test]
    fn zero_and_constant_rows() {
        let m = Matrix::from_vec(3, 4, vec![0.0; 12]);
        let q = QuantMatrix::quantize(&m);
        assert!(q.scales.iter().all(|&s| s == 0.0));
        assert_eq!(q.to_dense().data, m.data);
        assert_eq!(q.abs_error_bound(), 0.0);
        // A constant row quantizes exactly: code ±127 times absmax/127.
        let c = Matrix::from_vec(1, 3, vec![2.0, -2.0, 2.0]);
        let qc = QuantMatrix::quantize(&c);
        assert_eq!(qc.data, vec![127, -127, 127]);
    }

    #[test]
    fn csr_roundtrip_preserves_pattern_and_bound() {
        let mut rng = Rng::new(61);
        let dense = Matrix::from_fn(14, 9, |_, _| {
            if rng.uniform() < 0.3 {
                rng.normal()
            } else {
                0.0
            }
        });
        let csr = Csr::from_dense(&dense, IndexWidth::U16);
        let q = QuantCsr::quantize(&csr);
        let back = q.to_csr();
        assert_eq!(back.row_ptr, csr.row_ptr);
        assert_eq!(back.col_idx, csr.col_idx);
        let bound = q.abs_error_bound();
        for (a, b) in csr.values.iter().zip(&back.values) {
            assert!((a - b).abs() <= bound);
        }
    }

    #[test]
    fn fused_nt_matches_dequant_then_gemm_bitwise() {
        let mut rng = Rng::new(62);
        for (b, n, k) in [(1usize, 5usize, 9usize), (7, 17, 31), (6, 16, 64), (9, 40, 300)] {
            let w = Matrix::randn(n, k, 1.0, &mut rng);
            let q = QuantMatrix::quantize(&w);
            let dq = q.to_dense();
            let x = Matrix::randn(b, k, 1.0, &mut rng);
            for kind in active_kinds() {
                let mut fused = Matrix::zeros(b, n);
                q.matmul_nt_into_with(kind, &x, &mut fused, false);
                let mut want = Matrix::zeros(b, n);
                kernel::matmul_nt_into_with(kind, &x, &dq, &mut want, false);
                assert_eq!(fused.data, want.data, "{kind:?} nt {b}x{k}@{n}");
                // Accumulating form too.
                let seed = Matrix::randn(b, n, 1.0, &mut rng);
                let mut facc = seed.clone();
                q.matmul_nt_into_with(kind, &x, &mut facc, true);
                let mut wacc = seed.clone();
                kernel::matmul_nt_into_with(kind, &x, &dq, &mut wacc, true);
                assert_eq!(facc.data, wacc.data, "{kind:?} nt-acc {b}x{k}@{n}");
            }
        }
    }

    #[test]
    fn fused_nn_matches_dequant_then_gemm_bitwise() {
        let mut rng = Rng::new(63);
        for (b, k, n) in [(1usize, 4usize, 7usize), (5, 17, 16), (8, 30, 33), (3, 64, 224)] {
            let w = Matrix::randn(k, n, 1.0, &mut rng);
            let q = QuantMatrix::quantize(&w);
            let dq = q.to_dense();
            let mut h = Matrix::randn(b, k, 1.0, &mut rng);
            // Sprinkle exact zeros to exercise the av==0 skip.
            for i in 0..h.data.len() {
                if i % 5 == 0 {
                    h.data[i] = 0.0;
                }
            }
            for kind in active_kinds() {
                let seed = Matrix::randn(b, n, 1.0, &mut rng);
                let mut fused = seed.clone();
                q.matmul_acc_into_with(kind, &h, &mut fused);
                let mut want = seed.clone();
                kernel::matmul_into_with(kind, &h, &dq, &mut want, true);
                assert_eq!(fused.data, want.data, "{kind:?} nn {b}x{k}@{n}");
            }
        }
    }

    #[test]
    fn fused_csr_matches_dequant_then_spmm_bitwise() {
        let mut rng = Rng::new(64);
        for density in [0.0, 0.25, 1.0] {
            let dense = Matrix::from_fn(14, 9, |_, _| {
                if rng.uniform() < density {
                    rng.normal()
                } else {
                    0.0
                }
            });
            let csr = Csr::from_dense(&dense, IndexWidth::U16);
            let q = QuantCsr::quantize(&csr);
            let dq = q.to_csr();
            for b in [1usize, 8, 9] {
                let x = Matrix::randn(b, 9, 1.0, &mut rng);
                let h = Matrix::randn(b, 14, 1.0, &mut rng);
                for kind in active_kinds() {
                    let mut fused = Matrix::zeros(b, 14);
                    q.matmul_nt_into_with(kind, &x, &mut fused, false);
                    let mut want = Matrix::zeros(b, 14);
                    dq.matmul_nt_into_with(kind, &x, &mut want, false);
                    assert_eq!(fused.data, want.data, "{kind:?} qcsr nt d={density} b={b}");

                    let seed = Matrix::randn(b, 9, 1.0, &mut rng);
                    let mut facc = seed.clone();
                    q.matmul_acc_into_with(kind, &h, &mut facc);
                    let mut wacc = seed.clone();
                    dq.matmul_acc_into_with(kind, &h, &mut wacc);
                    assert_eq!(facc.data, wacc.data, "{kind:?} qcsr acc d={density} b={b}");
                }
            }
        }
    }

    #[test]
    fn quant_rows_are_batch_position_independent() {
        // The serving parity micro-theorem extends to the quantized tier
        // under the active kernel: row results don't depend on batch splits.
        let mut rng = Rng::new(65);
        let w = Matrix::randn(21, 19, 1.0, &mut rng);
        let q = QuantMatrix::quantize(&w);
        let xa = Matrix::randn(4, 19, 1.0, &mut rng);
        let xb = Matrix::randn(3, 19, 1.0, &mut rng);
        let cat = xa.vcat(&xb);
        let mut full = Matrix::zeros(7, 21);
        q.matmul_nt_into(&cat, &mut full, false);
        let mut ya = Matrix::zeros(4, 21);
        q.matmul_nt_into(&xa, &mut ya, false);
        let mut yb = Matrix::zeros(3, 21);
        q.matmul_nt_into(&xb, &mut yb, false);
        assert_eq!(full.data, ya.vcat(&yb).data);
    }

    #[test]
    fn slice_cols_and_col_dense_match_dense_ops() {
        let mut rng = Rng::new(66);
        let m = Matrix::randn(11, 13, 1.0, &mut rng);
        let q = QuantMatrix::quantize(&m);
        let dq = q.to_dense();
        assert_eq!(q.slice_cols(3, 9).to_dense().data, dq.slice_cols(3, 9).data);
        assert_eq!(q.slice_cols(0, 13).to_dense().data, dq.data);
        for c in 0..13 {
            assert_eq!(q.col_dense(c), dq.col(c));
        }
        let sparse = Matrix::from_fn(11, 13, |r, c| if (r + c) % 3 == 0 { 0.7 } else { 0.0 });
        let qc = QuantCsr::quantize(&Csr::from_dense(&sparse, IndexWidth::U16));
        let dqc = qc.to_csr();
        assert_eq!(qc.slice_cols(2, 10).to_csr().to_dense().data, dqc.to_dense().slice_cols(2, 10).data);
        for c in 0..13 {
            assert_eq!(qc.col_dense(c), dqc.col_dense(c));
        }
    }
}
