//! Numeric substrate: dense matrices over 32B-aligned storage, sparse
//! formats (COO/CSR with narrow-index accounting per paper App. A.7), QR,
//! SVD, and the runtime-dispatched SIMD microkernel layer (`kernel` +
//! `simd`; `RESMOE_SIMD=0` pins the portable scalar twin).

pub mod avec;
pub mod kernel;
pub mod linalg;
pub mod matrix;
pub mod quant;
#[cfg(target_arch = "x86_64")]
pub(crate) mod simd;
pub mod sparse;
pub mod svd;

pub use avec::AVec;
pub use kernel::{kernel_kind, kernel_label, KernelKind};
pub use matrix::Matrix;
pub use quant::{QuantCsr, QuantMatrix};
pub use sparse::{Coo, Csr, IndexWidth};
pub use svd::Svd;
