//! Numeric substrate: dense matrices, sparse formats (COO/CSR with
//! narrow-index accounting per paper App. A.7), QR, and SVD.

pub mod linalg;
pub mod matrix;
pub mod sparse;
pub mod svd;

pub use matrix::Matrix;
pub use sparse::{Coo, Csr, IndexWidth};
pub use svd::Svd;
