//! Runtime-dispatched microkernel subsystem: one source of truth for every
//! FLOP on the serving hot path.
//!
//! Two kernel families live here:
//!
//! * **Scalar** — the portable cache-blocked kernels (the pre-PR-5 code,
//!   moved from `matrix.rs`), auto-vectorized by LLVM at the baseline
//!   target. Always available; pinned with `RESMOE_SIMD=0`.
//! * **Avx2** — register-blocked AVX2+FMA microkernels
//!   (`tensor/simd.rs`) behind packed-panel drivers, selected at runtime
//!   when the CPU reports `avx2` and `fma`.
//!
//! The kind is resolved ONCE per process ([`kernel_kind`], cached in a
//! `OnceLock`): every path — serial, batched, store-paged, fused — funnels
//! through these entry points, so path-vs-path bit-for-bit parity is
//! preserved by construction whichever kernel is active. The two kernels
//! may differ from each other in final bits (FMA, lane-split reductions,
//! polynomial `exp`); tests compare them under a relative tolerance and
//! compare paths under equality. See README §Kernels.
//!
//! Determinism rules every kernel here obeys (and reviews must preserve):
//! an output element's arithmetic depends only on the reduction extent and
//! its column position — never on the batch row count, its row position,
//! tile membership, or the executing thread. That is what keeps
//! `batched == serial` and `store == monolithic` exact under SIMD.

use super::matrix::{Matrix, PAR_MIN_FLOPS};
use super::quant::{QuantCsr, QuantMatrix};
use super::sparse::Csr;
use crate::util::threads::{parallel_row_chunks_mut, parallel_rows_mut};
use std::sync::OnceLock;

/// Which kernel family executes the tensor ops of this process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable cache-blocked scalar kernels (LLVM auto-vectorized).
    Scalar,
    /// Register-blocked AVX2+FMA microkernels with packed panels.
    Avx2,
}

/// Kill-switch / dispatch policy, kept pure for testability: an explicit
/// off-value in `RESMOE_SIMD` (case-insensitive) beats CPU detection;
/// anything else defers to what the hardware reports.
pub fn resolve_kind(env: Option<&str>, detected: bool) -> KernelKind {
    let off = env.is_some_and(|v| {
        matches!(v.to_ascii_lowercase().as_str(), "0" | "off" | "false" | "scalar")
    });
    if off {
        KernelKind::Scalar
    } else if detected {
        KernelKind::Avx2
    } else {
        KernelKind::Scalar
    }
}

fn detect_avx2_fma() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The process-wide kernel kind (env `RESMOE_SIMD=0` forces scalar),
/// resolved once and cached — a per-process pin so every serving path sees
/// the same arithmetic.
pub fn kernel_kind() -> KernelKind {
    static KIND: OnceLock<KernelKind> = OnceLock::new();
    *KIND.get_or_init(|| {
        resolve_kind(std::env::var("RESMOE_SIMD").ok().as_deref(), detect_avx2_fma())
    })
}

/// Human-readable label for logs/benches.
pub fn kernel_label() -> &'static str {
    match kernel_kind() {
        KernelKind::Scalar => "scalar",
        KernelKind::Avx2 => "avx2+fma",
    }
}

// ====================================================================== GEMM

/// out (+)= a @ otherᵀ under an explicit kernel kind (tests and benches
/// force kinds; production callers go through `matrix::matmul_nt_into`).
pub fn matmul_nt_into_with(
    kind: KernelKind,
    a: &Matrix,
    other: &Matrix,
    out: &mut Matrix,
    accumulate: bool,
) {
    assert_eq!(a.cols, other.cols, "matmul_nt dim mismatch");
    let (m, n) = (a.rows, other.rows);
    assert_eq!((out.rows, out.cols), (m, n), "matmul_nt output shape");
    if n == 0 || m == 0 {
        return;
    }
    match kind {
        KernelKind::Scalar => gemm_nt_scalar(a, other, out, accumulate),
        KernelKind::Avx2 => avx2::gemm_nt(a, other, out, accumulate),
    }
}

/// out (+)= a @ b under an explicit kernel kind.
pub fn matmul_into_with(kind: KernelKind, a: &Matrix, b: &Matrix, out: &mut Matrix, accumulate: bool) {
    assert_eq!(a.cols, b.rows, "matmul dim mismatch {:?} @ {:?}", a.shape(), b.shape());
    let (m, n) = (a.rows, b.cols);
    assert_eq!((out.rows, out.cols), (m, n), "matmul output shape");
    if m == 0 || n == 0 {
        return;
    }
    match kind {
        KernelKind::Scalar => gemm_nn_scalar(a, b, out, accumulate),
        KernelKind::Avx2 => avx2::gemm_nn(a, b, out, accumulate),
    }
}

/// aᵀ @ b under an explicit kernel kind.
pub fn matmul_tn_with(kind: KernelKind, a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_tn dim mismatch");
    match kind {
        KernelKind::Scalar => gemm_tn_scalar(a, b),
        KernelKind::Avx2 => avx2::gemm_tn(a, b),
    }
}

// ============================================================= int8 GEMM
// Dequant-fused entries: `q` stays int8 end to end; the kernels dequantize
// codes in registers (`code as f32 * scale`, one rounding — identical to a
// materialized dequant) and then run the byte-identical f32 fold of their
// kind, so `fused(q) == gemm(q.to_dense())` holds BITWISE per kind. That
// makes the quantized tier's only numeric delta vs f32 serving the
// quantization error itself, which `QuantMatrix::abs_error_bound` bounds.

/// out (+)= x @ qᵀ with in-register dequantization (`q`: n×k int8 codes +
/// per-row scales; `x`: m×k f32; `out`: m×n).
pub fn qmatmul_nt_into_with(
    kind: KernelKind,
    x: &Matrix,
    q: &QuantMatrix,
    out: &mut Matrix,
    accumulate: bool,
) {
    assert_eq!(x.cols, q.cols, "qmatmul_nt dim mismatch");
    assert_eq!((out.rows, out.cols), (x.rows, q.rows), "qmatmul_nt output shape");
    if q.rows == 0 || x.rows == 0 {
        return;
    }
    match kind {
        KernelKind::Scalar => qgemm_nt_scalar(x, q, out, accumulate),
        KernelKind::Avx2 => avx2::qgemm_nt(x, q, out, accumulate),
    }
}

/// out += h @ q with in-register dequantization (`h`: m×k f32; `q`: k×n
/// int8 + per-row scales, rows being the k dimension; `out`: m×n).
pub fn qmatmul_acc_into_with(kind: KernelKind, h: &Matrix, q: &QuantMatrix, out: &mut Matrix) {
    assert_eq!(h.cols, q.rows, "qmatmul_acc dim mismatch");
    assert_eq!((out.rows, out.cols), (h.rows, q.cols), "qmatmul_acc output shape");
    if h.rows == 0 || q.cols == 0 || q.rows == 0 {
        return;
    }
    match kind {
        KernelKind::Scalar => qgemm_nn_scalar(h, q, out),
        KernelKind::Avx2 => avx2::qgemm_nn(h, q, out),
    }
}

/// out (+)= x @ qcsrᵀ, dequant-fused SpMM (zero fill handled here, like the
/// f32 entry, so both kernels share the always-accumulate tile contract).
pub fn qcsr_matmul_nt_into_with(
    kind: KernelKind,
    qcsr: &QuantCsr,
    x: &Matrix,
    out: &mut Matrix,
    accumulate: bool,
) {
    assert_eq!(x.cols, qcsr.cols, "qcsr matmul_nt dim mismatch");
    assert_eq!((out.rows, out.cols), (x.rows, qcsr.rows), "qcsr matmul_nt output shape");
    if !accumulate {
        out.data.fill(0.0);
    }
    if qcsr.rows == 0 || x.rows == 0 {
        return;
    }
    match kind {
        KernelKind::Scalar => qcsr.matmul_nt_scalar(x, out),
        KernelKind::Avx2 => avx2::qspmm_nt(qcsr, x, out),
    }
}

/// out += h @ qcsr, dequant-fused.
pub fn qcsr_matmul_acc_into_with(kind: KernelKind, qcsr: &QuantCsr, h: &Matrix, out: &mut Matrix) {
    assert_eq!(h.cols, qcsr.rows, "qcsr matmul_acc dim mismatch");
    assert_eq!((out.rows, out.cols), (h.rows, qcsr.cols), "qcsr matmul_acc output shape");
    if qcsr.rows == 0 || qcsr.cols == 0 || h.rows == 0 {
        return;
    }
    match kind {
        KernelKind::Scalar => qcsr.matmul_acc_scalar(h, out),
        KernelKind::Avx2 => avx2::qspmm_acc(qcsr, h, out),
    }
}

// ------------------------------------------------------------ scalar twins
// The pre-PR-5 kernels, verbatim: i-k-j NN, packed-panel NT with 8/4-wide
// independent accumulators, k-outer TN. These define the `RESMOE_SIMD=0`
// arithmetic and stay bit-identical to the seed lineage.

/// j-tile width (rows of `other` processed per packed panel) and k-panel
/// depth of the blocked scalar `matmul_nt`. 64×256 f32 ≈ 64 KB — the panel
/// plus the active A-row slice stay L2-resident while being reused across a
/// worker's whole row chunk.
const NT_JB: usize = 64;
const NT_KB: usize = 256;

fn gemm_nt_scalar(a: &Matrix, other: &Matrix, out: &mut Matrix, accumulate: bool) {
    let (m, n, k) = (a.rows, other.rows, a.cols);
    let chunk_kernel = |r0: usize, chunk: &mut [f32]| {
        let rows = chunk.len() / n;
        if !accumulate {
            chunk.fill(0.0);
        }
        // Single k-panel (covers every decode-shape matmul): `other`'s
        // contiguous rows already ARE the packed layout, so run the tile
        // kernel straight over them — zero allocation, zero copy.
        if k <= NT_KB {
            let mut jb = 0usize;
            while jb < n {
                let je = (jb + NT_JB).min(n);
                let jw = je - jb;
                for i in 0..rows {
                    let a_row = a.row(r0 + i);
                    let out_row = &mut chunk[i * n + jb..i * n + je];
                    nt_tile(a_row, &other.data[jb * k..], k, jw, out_row);
                }
                jb = je;
            }
            return;
        }
        let mut pack = vec![0.0f32; NT_JB * NT_KB];
        let mut kb = 0usize;
        while kb < k {
            let ke = (kb + NT_KB).min(k);
            let kw = ke - kb;
            let mut jb = 0usize;
            while jb < n {
                let je = (jb + NT_JB).min(n);
                let jw = je - jb;
                for (t, j) in (jb..je).enumerate() {
                    pack[t * kw..(t + 1) * kw].copy_from_slice(&other.row(j)[kb..ke]);
                }
                for i in 0..rows {
                    let a_row = &a.row(r0 + i)[kb..ke];
                    let out_row = &mut chunk[i * n + jb..i * n + je];
                    nt_tile(a_row, &pack, kw, jw, out_row);
                }
                jb = je;
            }
            kb = ke;
        }
    };
    if m * n * k >= PAR_MIN_FLOPS && m > 1 {
        parallel_row_chunks_mut(&mut out.data, m, n, |r0, chunk| chunk_kernel(r0, chunk));
    } else {
        chunk_kernel(0, &mut out.data);
    }
}

/// One packed tile: out[j] += dot(a_row, pack row j) for `jw` columns, with
/// 8-/4-wide independent accumulators.
#[inline]
fn nt_tile(a_row: &[f32], pack: &[f32], kw: usize, jw: usize, out: &mut [f32]) {
    let mut j = 0usize;
    while j + 8 <= jw {
        let b0 = &pack[j * kw..(j + 1) * kw];
        let b1 = &pack[(j + 1) * kw..(j + 2) * kw];
        let b2 = &pack[(j + 2) * kw..(j + 3) * kw];
        let b3 = &pack[(j + 3) * kw..(j + 4) * kw];
        let b4 = &pack[(j + 4) * kw..(j + 5) * kw];
        let b5 = &pack[(j + 5) * kw..(j + 6) * kw];
        let b6 = &pack[(j + 6) * kw..(j + 7) * kw];
        let b7 = &pack[(j + 7) * kw..(j + 8) * kw];
        let mut s = [0.0f32; 8];
        for kk in 0..kw {
            let av = a_row[kk];
            s[0] += av * b0[kk];
            s[1] += av * b1[kk];
            s[2] += av * b2[kk];
            s[3] += av * b3[kk];
            s[4] += av * b4[kk];
            s[5] += av * b5[kk];
            s[6] += av * b6[kk];
            s[7] += av * b7[kk];
        }
        for (o, sv) in out[j..j + 8].iter_mut().zip(s) {
            *o += sv;
        }
        j += 8;
    }
    while j + 4 <= jw {
        let b0 = &pack[j * kw..(j + 1) * kw];
        let b1 = &pack[(j + 1) * kw..(j + 2) * kw];
        let b2 = &pack[(j + 2) * kw..(j + 3) * kw];
        let b3 = &pack[(j + 3) * kw..(j + 4) * kw];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for kk in 0..kw {
            let av = a_row[kk];
            s0 += av * b0[kk];
            s1 += av * b1[kk];
            s2 += av * b2[kk];
            s3 += av * b3[kk];
        }
        out[j] += s0;
        out[j + 1] += s1;
        out[j + 2] += s2;
        out[j + 3] += s3;
        j += 4;
    }
    while j < jw {
        let b0 = &pack[j * kw..(j + 1) * kw];
        let mut acc = 0.0f32;
        for kk in 0..kw {
            acc += a_row[kk] * b0[kk];
        }
        out[j] += acc;
        j += 1;
    }
}

fn gemm_nn_scalar(a: &Matrix, b: &Matrix, out: &mut Matrix, accumulate: bool) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let kernel = |r: usize, out_row: &mut [f32]| {
        if !accumulate {
            out_row.fill(0.0);
        }
        let a_row = a.row(r);
        for kk in 0..k {
            let av = a_row[kk];
            if av == 0.0 {
                continue;
            }
            let b_row = &b.data[kk * n..kk * n + n];
            for (o, &bv) in out_row.iter_mut().zip(b_row.iter()) {
                *o += av * bv;
            }
        }
    };
    if 2 * m * n * k >= PAR_MIN_FLOPS && m > 1 {
        parallel_rows_mut(&mut out.data, m, n, |r, row| kernel(r, row));
    } else {
        for r in 0..m {
            let row = &mut out.data[r * n..(r + 1) * n];
            kernel(r, row);
        }
    }
}

fn gemm_tn_scalar(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, n, k) = (a.cols, b.cols, a.rows);
    let mut out = Matrix::zeros(m, n);
    for kk in 0..k {
        let a_row = a.row(kk);
        let b_row = b.row(kk);
        for i in 0..m {
            let av = a_row[i];
            if av == 0.0 {
                continue;
            }
            let out_row = &mut out.data[i * n..(i + 1) * n];
            for (o, &bv) in out_row.iter_mut().zip(b_row.iter()) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Scalar int8 NT twin: identical blocking/fold to [`gemm_nt_scalar`]'s
/// packed path, except the pack is filled by dequantizing codes
/// (`code as f32 * scale` — pure data movement plus the one dequant
/// rounding). For `k ≤ NT_KB` the packed path runs a single k-panel whose
/// per-element arithmetic equals the zero-copy fast path, so this is
/// bitwise `gemm_nt_scalar(a, q.to_dense(), ..)` at every shape.
fn qgemm_nt_scalar(a: &Matrix, q: &QuantMatrix, out: &mut Matrix, accumulate: bool) {
    let (m, n, k) = (a.rows, q.rows, a.cols);
    let chunk_kernel = |r0: usize, chunk: &mut [f32]| {
        let rows = chunk.len() / n;
        if !accumulate {
            chunk.fill(0.0);
        }
        if k == 0 {
            return;
        }
        let mut pack = vec![0.0f32; NT_JB * NT_KB];
        let mut kb = 0usize;
        while kb < k {
            let ke = (kb + NT_KB).min(k);
            let kw = ke - kb;
            let mut jb = 0usize;
            while jb < n {
                let je = (jb + NT_JB).min(n);
                let jw = je - jb;
                for (t, j) in (jb..je).enumerate() {
                    let s = q.scales[j];
                    let codes = &q.data[j * k + kb..j * k + ke];
                    for (p, &c) in pack[t * kw..(t + 1) * kw].iter_mut().zip(codes) {
                        *p = c as f32 * s;
                    }
                }
                for i in 0..rows {
                    let a_row = &a.row(r0 + i)[kb..ke];
                    let out_row = &mut chunk[i * n + jb..i * n + je];
                    nt_tile(a_row, &pack, kw, jw, out_row);
                }
                jb = je;
            }
            kb = ke;
        }
    };
    if m * n * k >= PAR_MIN_FLOPS && m > 1 {
        parallel_row_chunks_mut(&mut out.data, m, n, |r0, chunk| chunk_kernel(r0, chunk));
    } else {
        chunk_kernel(0, &mut out.data);
    }
}

/// Scalar int8 NN twin (always-accumulate): [`gemm_nn_scalar`]'s i-k-j loop
/// with the B row dequantized inline — `av * (code as f32 * s)` rounds the
/// dequant first, exactly like a materialized dequant feeding the f32 twin.
fn qgemm_nn_scalar(a: &Matrix, q: &QuantMatrix, out: &mut Matrix) {
    let (m, k, n) = (a.rows, a.cols, q.cols);
    let kernel = |r: usize, out_row: &mut [f32]| {
        let a_row = a.row(r);
        for kk in 0..k {
            let av = a_row[kk];
            if av == 0.0 {
                continue;
            }
            let s = q.scales[kk];
            let q_row = &q.data[kk * n..kk * n + n];
            for (o, &code) in out_row.iter_mut().zip(q_row.iter()) {
                *o += av * (code as f32 * s);
            }
        }
    };
    if 2 * m * n * k >= PAR_MIN_FLOPS && m > 1 {
        parallel_rows_mut(&mut out.data, m, n, |r, row| kernel(r, row));
    } else {
        for r in 0..m {
            let row = &mut out.data[r * n..(r + 1) * n];
            kernel(r, row);
        }
    }
}

// ====================================================================== CSR

/// out (+)= x @ csrᵀ under an explicit kind (`Csr::matmul_nt_into` fronts
/// this). The non-accumulating zero fill happens here so both kernels share
/// the always-accumulate tile contract.
pub fn csr_matmul_nt_into_with(
    kind: KernelKind,
    csr: &Csr,
    x: &Matrix,
    out: &mut Matrix,
    accumulate: bool,
) {
    assert_eq!(x.cols, csr.cols, "csr matmul_nt dim mismatch");
    assert_eq!((out.rows, out.cols), (x.rows, csr.rows), "csr matmul_nt output shape");
    if !accumulate {
        out.data.fill(0.0);
    }
    if csr.rows == 0 || x.rows == 0 {
        return;
    }
    match kind {
        KernelKind::Scalar => csr.matmul_nt_scalar(x, out),
        KernelKind::Avx2 => avx2::spmm_nt(csr, x, out),
    }
}

/// out += h @ csr under an explicit kind (`Csr::matmul_acc_into` fronts it).
pub fn csr_matmul_acc_into_with(kind: KernelKind, csr: &Csr, h: &Matrix, out: &mut Matrix) {
    assert_eq!(h.cols, csr.rows, "csr matmul_acc dim mismatch");
    assert_eq!((out.rows, out.cols), (h.rows, csr.cols), "csr matmul_acc output shape");
    if csr.rows == 0 || csr.cols == 0 || h.rows == 0 {
        return;
    }
    match kind {
        KernelKind::Scalar => csr.matmul_acc_scalar(h, out),
        KernelKind::Avx2 => avx2::spmm_acc(csr, h, out),
    }
}

// =============================================================== elementwise
// Two tiers:
//  * EXACT ops (one rounding per element: add/mul/axpy/relu/bias/scale) —
//    the SIMD bodies use non-fused mul+add, so both kinds produce the SAME
//    bits and the dispatch is purely a throughput choice.
//  * APPROXIMATE ops (exp-based: softmax/silu/rmsnorm reductions) — SIMD
//    uses a polynomial `exp` and lane-split sums; results differ from
//    scalar within ~1e-7 relative and are applied PER ROW so a column's
//    arithmetic never depends on the batch row count.

/// SiLU (sigmoid-weighted linear unit) — scalar reference, also re-exported
/// through `moe::expert`.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// h[i] = silu(h[i]) * g[i] — the SwiGLU combine over equally shaped
/// matrices, row-wise under SIMD.
pub fn silu_mul(h: &mut Matrix, g: &Matrix) {
    debug_assert_eq!(h.shape(), g.shape());
    match kernel_kind() {
        KernelKind::Scalar => silu_mul_scalar(h, g),
        KernelKind::Avx2 => avx2::silu_mul(h, g),
    }
}

fn silu_mul_scalar(h: &mut Matrix, g: &Matrix) {
    for (hv, gv) in h.data.iter_mut().zip(g.data.iter()) {
        *hv = silu(*hv) * *gv;
    }
}

/// In-place ReLU (exact: both kinds produce identical bits).
pub fn relu_inplace(m: &mut Matrix) {
    match kernel_kind() {
        KernelKind::Scalar => relu_scalar(&mut m.data),
        KernelKind::Avx2 => avx2::relu(&mut m.data),
    }
}

fn relu_scalar(data: &mut [f32]) {
    for v in data.iter_mut() {
        *v = v.max(0.0);
    }
}

/// Numerically stable in-place softmax (max-subtract, exp, scale by the
/// reciprocal of the sum).
pub fn softmax_inplace(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    match kernel_kind() {
        KernelKind::Scalar => softmax_scalar(xs),
        KernelKind::Avx2 => avx2::softmax(xs),
    }
}

fn softmax_scalar(xs: &mut [f32]) {
    let mut max = f32::NEG_INFINITY;
    for &v in xs.iter() {
        max = max.max(v);
    }
    let mut sum = 0.0f32;
    for v in xs.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

/// RMS normalization of one row with learned gain (eps matches the
/// transformer's historical constant).
pub fn rmsnorm(x: &[f32], gain: &[f32], out: &mut [f32]) {
    const EPS: f32 = 1e-6;
    match kernel_kind() {
        KernelKind::Scalar => rmsnorm_scalar(x, gain, out, EPS),
        KernelKind::Avx2 => avx2::rmsnorm(x, gain, out, EPS),
    }
}

fn rmsnorm_scalar(x: &[f32], gain: &[f32], out: &mut [f32], eps: f32) {
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(gain) {
        *o = v * inv * g;
    }
}

/// Dot product (SIMD: FMA lanes + fixed-order horizontal reduction).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match kernel_kind() {
        KernelKind::Scalar => dot_scalar(a, b),
        KernelKind::Avx2 => avx2::dot(a, b),
    }
}

fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>()
}

/// dst[i] += alpha * src[i] (exact: non-fused on both kinds).
pub fn axpy(dst: &mut [f32], alpha: f32, src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    match kernel_kind() {
        KernelKind::Scalar => axpy_scalar(dst, alpha, src),
        KernelKind::Avx2 => avx2::axpy(dst, alpha, src),
    }
}

fn axpy_scalar(dst: &mut [f32], alpha: f32, src: &[f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d += alpha * *s;
    }
}

/// dst[i] += src[i] (exact).
pub fn add_slice(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    match kernel_kind() {
        KernelKind::Scalar => add_slice_scalar(dst, src),
        KernelKind::Avx2 => avx2::add_slice(dst, src),
    }
}

fn add_slice_scalar(dst: &mut [f32], src: &[f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// row-broadcast `m[r, :] += bias` (exact; shared by the dense and fused
/// forwards — re-exported through `moe::expert::add_bias_rows`).
pub fn add_bias_rows(m: &mut Matrix, bias: &[f32]) {
    debug_assert_eq!(m.cols, bias.len());
    match kernel_kind() {
        KernelKind::Scalar => add_bias_rows_scalar(m, bias),
        KernelKind::Avx2 => avx2::add_bias_rows(m, bias),
    }
}

fn add_bias_rows_scalar(m: &mut Matrix, bias: &[f32]) {
    for r in 0..m.rows {
        for (v, &b) in m.row_mut(r).iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// `m[:, j] *= s[j]` — the SharedAct / low-rank singular-value scaling
/// (exact).
pub fn scale_cols(m: &mut Matrix, s: &[f32]) {
    debug_assert_eq!(m.cols, s.len());
    match kernel_kind() {
        KernelKind::Scalar => scale_cols_scalar(m, s),
        KernelKind::Avx2 => avx2::scale_cols(m, s),
    }
}

fn scale_cols_scalar(m: &mut Matrix, s: &[f32]) {
    for r in 0..m.rows {
        for (v, &sv) in m.row_mut(r).iter_mut().zip(s) {
            *v *= sv;
        }
    }
}

// ========================================================= AVX2 drivers
// Packed-panel drivers around the `tensor/simd.rs` microkernels. On
// non-x86_64 targets the module below is replaced by scalar delegates so
// dispatch code compiles everywhere (kernel_kind() never yields Avx2
// there).

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use crate::tensor::avec::AVec;
    use crate::tensor::simd;
    use crate::util::threads::parallel_row_chunks_mut_aligned;

    /// Microkernel tile geometry: 6 rows × 16 columns of C per call.
    const MR: usize = 6;
    const NR: usize = 16;
    /// Cache blocking: k-panel depth × packed-panel width (KC·NC f32 =
    /// 64 KB, L2-resident per worker).
    const KC: usize = 256;
    const NC: usize = 64;

    /// Pack rows `jb..jb+jw` of `other` (the columns of the NT product)
    /// over k-range `kb..kb+kw` into micropanels: for micropanel `mp`,
    /// `pack[mp*kw*16 + kk*16 + lane] = other[jb + mp*16 + lane][kb + kk]`,
    /// with lanes past `jw` zeroed (padding lanes are arithmetic no-ops for
    /// their neighbors — lanes are independent).
    fn pack_nt_panel(other: &Matrix, jb: usize, jw: usize, kb: usize, kw: usize, pack: &mut [f32]) {
        let n_mp = jw.div_ceil(NR);
        for mp in 0..n_mp {
            let base = mp * kw * NR;
            let jlo = jb + mp * NR;
            let jcount = (jb + jw - jlo).min(NR);
            if jcount < NR {
                pack[base..base + kw * NR].fill(0.0);
            }
            for lane in 0..jcount {
                let row = &other.row(jlo + lane)[kb..kb + kw];
                for (kk, &v) in row.iter().enumerate() {
                    pack[base + kk * NR + lane] = v;
                }
            }
        }
    }

    pub fn gemm_nt(a: &Matrix, other: &Matrix, out: &mut Matrix, accumulate: bool) {
        let (m, n, k) = (a.rows, other.rows, a.cols);
        let chunk_kernel = |r0: usize, chunk: &mut [f32]| {
            let rows = chunk.len() / n;
            if !accumulate {
                chunk.fill(0.0);
            }
            if k == 0 {
                return;
            }
            let mut pack = AVec::zeroed(KC * NC);
            // Tall-k GEMMs (k > KC) copy the chunk's A sub-panel contiguous
            // once per k-panel (lda drops from k to kw), reused across every
            // j-block — pure data movement, bit-identical results.
            let mut apack: Vec<f32> = if k > KC { vec![0.0; rows * KC] } else { Vec::new() };
            let mut kb = 0usize;
            while kb < k {
                let kw = (k - kb).min(KC);
                let (a_base, lda) = if k > KC {
                    for i in 0..rows {
                        apack[i * kw..(i + 1) * kw]
                            .copy_from_slice(&a.row(r0 + i)[kb..kb + kw]);
                    }
                    (apack.as_ptr(), kw)
                } else {
                    (a.data[r0 * k + kb..].as_ptr(), k)
                };
                let mut jb = 0usize;
                while jb < n {
                    let jw = (n - jb).min(NC);
                    let n_mp = jw.div_ceil(NR);
                    pack_nt_panel(other, jb, jw, kb, kw, &mut pack);
                    let mut ib = 0usize;
                    while ib < rows {
                        let iw = (rows - ib).min(MR);
                        for mp in 0..n_mp {
                            let jww = (jw - mp * NR).min(NR);
                            // SAFETY: kind() verified avx2+fma; row/col
                            // ranges are in bounds by the loop limits; the
                            // pack holds kw*16 floats per micropanel; a_base
                            // has `rows` rows of ≥ kw floats at stride lda.
                            unsafe {
                                simd::mk_nt(
                                    iw,
                                    a_base.add(ib * lda),
                                    lda,
                                    pack.as_ptr().add(mp * kw * NR),
                                    kw,
                                    chunk.as_mut_ptr().add(ib * n + jb + mp * NR),
                                    n,
                                    jww,
                                );
                            }
                        }
                        ib += iw;
                    }
                    jb += jw;
                }
                kb += kw;
            }
        };
        if m * n * k >= PAR_MIN_FLOPS && m > 1 {
            parallel_row_chunks_mut_aligned(&mut out.data, m, n, MR, |r0, chunk| {
                chunk_kernel(r0, chunk)
            });
        } else {
            chunk_kernel(0, &mut out.data);
        }
    }

    /// NN rows-per-tile (4×16 C tile; B is streamed, not packed, except for
    /// the ragged column tail).
    const NN_MR: usize = 4;

    pub fn gemm_nn(a: &Matrix, b: &Matrix, out: &mut Matrix, accumulate: bool) {
        let (m, k, n) = (a.rows, a.cols, b.cols);
        let n_full = n - n % NR;
        let jt = n - n_full;
        let chunk_kernel = |r0: usize, chunk: &mut [f32]| {
            let rows = chunk.len() / n;
            if !accumulate {
                chunk.fill(0.0);
            }
            if k == 0 {
                return;
            }
            let mut tailpack = AVec::zeroed(if jt > 0 { KC * NR } else { 0 });
            let mut kb = 0usize;
            while kb < k {
                let kw = (k - kb).min(KC);
                if jt > 0 {
                    // Zero-padded ldb=16 scratch so the microkernel's
                    // 16-float loads stay in bounds on the column tail.
                    tailpack.fill(0.0);
                    for kk in 0..kw {
                        let row = &b.row(kb + kk)[n_full..];
                        tailpack[kk * NR..kk * NR + jt].copy_from_slice(row);
                    }
                }
                let mut ib = 0usize;
                while ib < rows {
                    let iw = (rows - ib).min(NN_MR);
                    let a_ptr = a.data[(r0 + ib) * k + kb..].as_ptr();
                    let mut jb = 0usize;
                    while jb < n_full {
                        // SAFETY: avx2+fma verified; B rows kb..kb+kw each
                        // have ≥16 readable floats from column jb.
                        unsafe {
                            simd::mk_nn(
                                iw,
                                a_ptr,
                                k,
                                b.data.as_ptr().add(kb * n + jb),
                                n,
                                kw,
                                chunk.as_mut_ptr().add(ib * n + jb),
                                n,
                                NR,
                            );
                        }
                        jb += NR;
                    }
                    if jt > 0 {
                        // SAFETY: scratch rows are exactly 16 floats.
                        unsafe {
                            simd::mk_nn(
                                iw,
                                a_ptr,
                                k,
                                tailpack.as_ptr(),
                                NR,
                                kw,
                                chunk.as_mut_ptr().add(ib * n + n_full),
                                n,
                                jt,
                            );
                        }
                    }
                    ib += iw;
                }
                kb += kw;
            }
        };
        if 2 * m * n * k >= PAR_MIN_FLOPS && m > 1 {
            parallel_row_chunks_mut_aligned(&mut out.data, m, n, NN_MR, |r0, chunk| {
                chunk_kernel(r0, chunk)
            });
        } else {
            chunk_kernel(0, &mut out.data);
        }
    }

    pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, n, k) = (a.cols, b.cols, a.rows);
        let mut out = Matrix::zeros(m, n);
        for kk in 0..k {
            let a_row = a.row(kk);
            let b_row = b.row(kk);
            for i in 0..m {
                let av = a_row[i];
                if av == 0.0 {
                    continue;
                }
                let out_row = &mut out.data[i * n..(i + 1) * n];
                // SAFETY: avx2+fma verified; slices are equal length.
                unsafe { simd::tn_axpy(out_row, av, b_row) };
            }
        }
        out
    }

    /// Batch-tile width of the SpMM drivers (8 = one AVX lane set).
    const BT: usize = 8;

    pub fn spmm_nt(csr: &Csr, x: &Matrix, out: &mut Matrix) {
        let (bsz, rr, p) = (x.rows, csr.rows, csr.cols);
        let chunk_kernel = |b0: usize, chunk: &mut [f32]| {
            let rows_b = chunk.len() / rr;
            let mut xt = AVec::zeroed(p * BT);
            let mut bt = 0usize;
            while bt < rows_b {
                let bw = (rows_b - bt).min(BT);
                if bw < BT {
                    xt.fill(0.0);
                }
                for lane in 0..bw {
                    let row = x.row(b0 + bt + lane);
                    for (c, &v) in row.iter().enumerate() {
                        xt[c * BT + lane] = v;
                    }
                }
                // SAFETY: avx2+fma verified; the Csr invariants (validated
                // on decode) bound col_idx < p and row_ptr monotone; the
                // out tile rows bt..bt+bw exist in this chunk.
                unsafe {
                    simd::spmm_nt_tile(
                        &csr.row_ptr,
                        &csr.col_idx,
                        &csr.values,
                        xt.as_ptr(),
                        chunk.as_mut_ptr().add(bt * rr),
                        rr,
                        bw,
                        rr,
                    );
                }
                bt += bw;
            }
        };
        if bsz * csr.nnz() >= PAR_MIN_FLOPS && bsz > 1 {
            parallel_row_chunks_mut_aligned(&mut out.data, bsz, rr, BT, |b0, chunk| {
                chunk_kernel(b0, chunk)
            });
        } else {
            chunk_kernel(0, &mut out.data);
        }
    }

    pub fn spmm_acc(csr: &Csr, h: &Matrix, out: &mut Matrix) {
        let (bsz, pi, p) = (h.rows, csr.rows, csr.cols);
        let chunk_kernel = |b0: usize, chunk: &mut [f32]| {
            let rows_b = chunk.len() / p;
            let mut ht = AVec::zeroed(pi * BT);
            let mut outt = AVec::zeroed(p * BT);
            let mut bt = 0usize;
            while bt < rows_b {
                let bw = (rows_b - bt).min(BT);
                if bw < BT {
                    ht.fill(0.0);
                }
                for lane in 0..bw {
                    let row = h.row(b0 + bt + lane);
                    for (r, &v) in row.iter().enumerate() {
                        ht[r * BT + lane] = v;
                    }
                }
                outt.fill(0.0);
                // SAFETY: avx2+fma verified; Csr invariants bound indices;
                // ht/outt hold pi*8 / p*8 floats.
                unsafe {
                    simd::spmm_acc_tile(
                        &csr.row_ptr,
                        &csr.col_idx,
                        &csr.values,
                        ht.as_ptr(),
                        outt.as_mut_ptr(),
                        pi,
                    );
                }
                for lane in 0..bw {
                    let orow = &mut chunk[(bt + lane) * p..(bt + lane + 1) * p];
                    for (c, o) in orow.iter_mut().enumerate() {
                        *o += outt[c * BT + lane];
                    }
                }
                bt += bw;
            }
        };
        if bsz * csr.nnz() >= PAR_MIN_FLOPS && bsz > 1 {
            parallel_row_chunks_mut_aligned(&mut out.data, bsz, p, BT, |b0, chunk| {
                chunk_kernel(b0, chunk)
            });
        } else {
            chunk_kernel(0, &mut out.data);
        }
    }

    // ------------------------------------------------- int8 quant drivers

    /// Int8 twin of [`pack_nt_panel`]: same k-major 16-lane micropanel
    /// layout over the codes, plus per-lane column scales (`spack`, one f32
    /// per packed lane, padding lanes 0.0 so their dequant is exactly 0).
    fn pack_qnt_panel(
        q: &QuantMatrix,
        jb: usize,
        jw: usize,
        kb: usize,
        kw: usize,
        pack: &mut [i8],
        spack: &mut [f32],
    ) {
        let n_mp = jw.div_ceil(NR);
        for mp in 0..n_mp {
            let base = mp * kw * NR;
            let jlo = jb + mp * NR;
            let jcount = (jb + jw - jlo).min(NR);
            if jcount < NR {
                pack[base..base + kw * NR].fill(0);
                spack[mp * NR..(mp + 1) * NR].fill(0.0);
            }
            for lane in 0..jcount {
                spack[mp * NR + lane] = q.scales[jlo + lane];
                let row = &q.data[(jlo + lane) * q.cols + kb..(jlo + lane) * q.cols + kb + kw];
                for (kk, &v) in row.iter().enumerate() {
                    pack[base + kk * NR + lane] = v;
                }
            }
        }
    }

    /// Dequant-fused NT driver: identical blocking to [`gemm_nt`]
    /// (including the tall-k A-panel), with int8 micropanels + scale lanes
    /// feeding `mk_nt_q`. The memory traffic through the panel drops 4×.
    pub fn qgemm_nt(a: &Matrix, q: &QuantMatrix, out: &mut Matrix, accumulate: bool) {
        let (m, n, k) = (a.rows, q.rows, a.cols);
        let chunk_kernel = |r0: usize, chunk: &mut [f32]| {
            let rows = chunk.len() / n;
            if !accumulate {
                chunk.fill(0.0);
            }
            if k == 0 {
                return;
            }
            let mut pack = vec![0i8; KC * NC];
            let mut spack = [0.0f32; NC];
            let mut apack: Vec<f32> = if k > KC { vec![0.0; rows * KC] } else { Vec::new() };
            let mut kb = 0usize;
            while kb < k {
                let kw = (k - kb).min(KC);
                let (a_base, lda) = if k > KC {
                    for i in 0..rows {
                        apack[i * kw..(i + 1) * kw]
                            .copy_from_slice(&a.row(r0 + i)[kb..kb + kw]);
                    }
                    (apack.as_ptr(), kw)
                } else {
                    (a.data[r0 * k + kb..].as_ptr(), k)
                };
                let mut jb = 0usize;
                while jb < n {
                    let jw = (n - jb).min(NC);
                    let n_mp = jw.div_ceil(NR);
                    pack_qnt_panel(q, jb, jw, kb, kw, &mut pack, &mut spack);
                    let mut ib = 0usize;
                    while ib < rows {
                        let iw = (rows - ib).min(MR);
                        for mp in 0..n_mp {
                            let jww = (jw - mp * NR).min(NR);
                            // SAFETY: avx2+fma verified; pack holds kw*16
                            // codes and spack 16 scales per micropanel;
                            // a_base has `rows` rows of ≥ kw floats at
                            // stride lda; ragged C tails are mask-guarded
                            // inside the microkernel.
                            unsafe {
                                simd::mk_nt_q(
                                    iw,
                                    a_base.add(ib * lda),
                                    lda,
                                    pack.as_ptr().add(mp * kw * NR),
                                    kw,
                                    spack.as_ptr().add(mp * NR),
                                    chunk.as_mut_ptr().add(ib * n + jb + mp * NR),
                                    n,
                                    jww,
                                );
                            }
                        }
                        ib += iw;
                    }
                    jb += jw;
                }
                kb += kw;
            }
        };
        if m * n * k >= PAR_MIN_FLOPS && m > 1 {
            parallel_row_chunks_mut_aligned(&mut out.data, m, n, MR, |r0, chunk| {
                chunk_kernel(r0, chunk)
            });
        } else {
            chunk_kernel(0, &mut out.data);
        }
    }

    /// Dequant-fused NN driver (always-accumulate): [`gemm_nn`]'s streamed
    /// structure with int8 B rows and one broadcast scale per k step.
    pub fn qgemm_nn(a: &Matrix, q: &QuantMatrix, out: &mut Matrix) {
        let (m, k, n) = (a.rows, a.cols, q.cols);
        let n_full = n - n % NR;
        let jt = n - n_full;
        let chunk_kernel = |r0: usize, chunk: &mut [f32]| {
            let rows = chunk.len() / n;
            if k == 0 {
                return;
            }
            let mut tailpack = vec![0i8; if jt > 0 { KC * NR } else { 0 }];
            let mut kb = 0usize;
            while kb < k {
                let kw = (k - kb).min(KC);
                if jt > 0 {
                    // Zero-padded ldb=16 int8 scratch for the column tail.
                    tailpack.fill(0);
                    for kk in 0..kw {
                        let row = &q.data[(kb + kk) * n + n_full..(kb + kk + 1) * n];
                        tailpack[kk * NR..kk * NR + jt].copy_from_slice(row);
                    }
                }
                let mut ib = 0usize;
                while ib < rows {
                    let iw = (rows - ib).min(NN_MR);
                    let a_ptr = a.data[(r0 + ib) * k + kb..].as_ptr();
                    let mut jb = 0usize;
                    while jb < n_full {
                        // SAFETY: avx2+fma verified; B rows kb..kb+kw each
                        // have ≥16 readable codes from column jb; scales
                        // holds kw f32 from kb.
                        unsafe {
                            simd::mk_nn_q(
                                iw,
                                a_ptr,
                                k,
                                q.data.as_ptr().add(kb * n + jb),
                                n,
                                q.scales.as_ptr().add(kb),
                                kw,
                                chunk.as_mut_ptr().add(ib * n + jb),
                                n,
                                NR,
                            );
                        }
                        jb += NR;
                    }
                    if jt > 0 {
                        // SAFETY: scratch rows are exactly 16 codes.
                        unsafe {
                            simd::mk_nn_q(
                                iw,
                                a_ptr,
                                k,
                                tailpack.as_ptr(),
                                NR,
                                q.scales.as_ptr().add(kb),
                                kw,
                                chunk.as_mut_ptr().add(ib * n + n_full),
                                n,
                                jt,
                            );
                        }
                    }
                    ib += iw;
                }
                kb += kw;
            }
        };
        if 2 * m * n * k >= PAR_MIN_FLOPS && m > 1 {
            parallel_row_chunks_mut_aligned(&mut out.data, m, n, NN_MR, |r0, chunk| {
                chunk_kernel(r0, chunk)
            });
        } else {
            chunk_kernel(0, &mut out.data);
        }
    }

    /// Dequant-fused SpMM drivers: same transposed activation panels as the
    /// f32 versions, int8 values dequantized per nonzero inside the tile.
    pub fn qspmm_nt(qcsr: &QuantCsr, x: &Matrix, out: &mut Matrix) {
        let (bsz, rr, p) = (x.rows, qcsr.rows, qcsr.cols);
        let chunk_kernel = |b0: usize, chunk: &mut [f32]| {
            let rows_b = chunk.len() / rr;
            let mut xt = AVec::zeroed(p * BT);
            let mut bt = 0usize;
            while bt < rows_b {
                let bw = (rows_b - bt).min(BT);
                if bw < BT {
                    xt.fill(0.0);
                }
                for lane in 0..bw {
                    let row = x.row(b0 + bt + lane);
                    for (c, &v) in row.iter().enumerate() {
                        xt[c * BT + lane] = v;
                    }
                }
                // SAFETY: avx2+fma verified; QuantCsr invariants (validated
                // on decode) bound col_idx < p and row_ptr monotone; scales
                // holds one f32 per CSR row.
                unsafe {
                    simd::spmm_nt_q_tile(
                        &qcsr.row_ptr,
                        &qcsr.col_idx,
                        &qcsr.values,
                        &qcsr.scales,
                        xt.as_ptr(),
                        chunk.as_mut_ptr().add(bt * rr),
                        rr,
                        bw,
                        rr,
                    );
                }
                bt += bw;
            }
        };
        if bsz * qcsr.nnz() >= PAR_MIN_FLOPS && bsz > 1 {
            parallel_row_chunks_mut_aligned(&mut out.data, bsz, rr, BT, |b0, chunk| {
                chunk_kernel(b0, chunk)
            });
        } else {
            chunk_kernel(0, &mut out.data);
        }
    }

    pub fn qspmm_acc(qcsr: &QuantCsr, h: &Matrix, out: &mut Matrix) {
        let (bsz, pi, p) = (h.rows, qcsr.rows, qcsr.cols);
        let chunk_kernel = |b0: usize, chunk: &mut [f32]| {
            let rows_b = chunk.len() / p;
            let mut ht = AVec::zeroed(pi * BT);
            let mut outt = AVec::zeroed(p * BT);
            let mut bt = 0usize;
            while bt < rows_b {
                let bw = (rows_b - bt).min(BT);
                if bw < BT {
                    ht.fill(0.0);
                }
                for lane in 0..bw {
                    let row = h.row(b0 + bt + lane);
                    for (r, &v) in row.iter().enumerate() {
                        ht[r * BT + lane] = v;
                    }
                }
                outt.fill(0.0);
                // SAFETY: avx2+fma verified; QuantCsr invariants bound
                // indices; ht/outt hold pi*8 / p*8 floats.
                unsafe {
                    simd::spmm_acc_q_tile(
                        &qcsr.row_ptr,
                        &qcsr.col_idx,
                        &qcsr.values,
                        &qcsr.scales,
                        ht.as_ptr(),
                        outt.as_mut_ptr(),
                        pi,
                    );
                }
                for lane in 0..bw {
                    let orow = &mut chunk[(bt + lane) * p..(bt + lane + 1) * p];
                    for (c, o) in orow.iter_mut().enumerate() {
                        *o += outt[c * BT + lane];
                    }
                }
                bt += bw;
            }
        };
        if bsz * qcsr.nnz() >= PAR_MIN_FLOPS && bsz > 1 {
            parallel_row_chunks_mut_aligned(&mut out.data, bsz, p, BT, |b0, chunk| {
                chunk_kernel(b0, chunk)
            });
        } else {
            chunk_kernel(0, &mut out.data);
        }
    }

    // ------------------------------------------------------- elementwise

    pub fn silu_mul(h: &mut Matrix, g: &Matrix) {
        let cols = h.cols;
        for r in 0..h.rows {
            let row = &mut h.data[r * cols..(r + 1) * cols];
            // SAFETY: avx2+fma verified; rows are equal length.
            unsafe { simd::silu_mul_row(row, g.row(r)) };
        }
    }

    pub fn relu(data: &mut [f32]) {
        // SAFETY: avx2+fma verified.
        unsafe { simd::relu_inplace(data) };
    }

    pub fn softmax(xs: &mut [f32]) {
        // SAFETY: avx2+fma verified.
        unsafe { simd::softmax_inplace(xs) };
    }

    pub fn rmsnorm(x: &[f32], gain: &[f32], out: &mut [f32], eps: f32) {
        // SAFETY: avx2+fma verified; equal lengths checked by caller.
        unsafe { simd::rmsnorm_row(x, gain, out, eps) };
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: avx2+fma verified; equal lengths.
        unsafe { simd::dot(a, b) }
    }

    pub fn axpy(dst: &mut [f32], alpha: f32, src: &[f32]) {
        // SAFETY: avx2+fma verified; equal lengths.
        unsafe { simd::axpy_row(dst, alpha, src) };
    }

    pub fn add_slice(dst: &mut [f32], src: &[f32]) {
        // SAFETY: avx2+fma verified; equal lengths.
        unsafe { simd::add_row(dst, src) };
    }

    pub fn add_bias_rows(m: &mut Matrix, bias: &[f32]) {
        let cols = m.cols;
        for r in 0..m.rows {
            let row = &mut m.data[r * cols..(r + 1) * cols];
            // SAFETY: avx2+fma verified; bias.len() == cols.
            unsafe { simd::add_row(row, bias) };
        }
    }

    pub fn scale_cols(m: &mut Matrix, s: &[f32]) {
        let cols = m.cols;
        for r in 0..m.rows {
            let row = &mut m.data[r * cols..(r + 1) * cols];
            // SAFETY: avx2+fma verified; s.len() == cols.
            unsafe { simd::mul_row(row, s) };
        }
    }
}

/// Scalar delegates so the dispatchers compile on non-x86_64 targets
/// (`kernel_kind()` never returns `Avx2` there, so these are unreachable in
/// practice but keep the code honest if that invariant ever slips).
#[cfg(not(target_arch = "x86_64"))]
mod avx2 {
    use super::*;

    pub fn gemm_nt(a: &Matrix, other: &Matrix, out: &mut Matrix, accumulate: bool) {
        gemm_nt_scalar(a, other, out, accumulate)
    }
    pub fn gemm_nn(a: &Matrix, b: &Matrix, out: &mut Matrix, accumulate: bool) {
        gemm_nn_scalar(a, b, out, accumulate)
    }
    pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
        gemm_tn_scalar(a, b)
    }
    pub fn spmm_nt(csr: &Csr, x: &Matrix, out: &mut Matrix) {
        csr.matmul_nt_scalar(x, out)
    }
    pub fn spmm_acc(csr: &Csr, h: &Matrix, out: &mut Matrix) {
        csr.matmul_acc_scalar(h, out)
    }
    pub fn qgemm_nt(a: &Matrix, q: &QuantMatrix, out: &mut Matrix, accumulate: bool) {
        qgemm_nt_scalar(a, q, out, accumulate)
    }
    pub fn qgemm_nn(a: &Matrix, q: &QuantMatrix, out: &mut Matrix) {
        qgemm_nn_scalar(a, q, out)
    }
    pub fn qspmm_nt(qcsr: &QuantCsr, x: &Matrix, out: &mut Matrix) {
        qcsr.matmul_nt_scalar(x, out)
    }
    pub fn qspmm_acc(qcsr: &QuantCsr, h: &Matrix, out: &mut Matrix) {
        qcsr.matmul_acc_scalar(h, out)
    }
    // Elementwise tier: the ONE scalar implementation (the dispatch
    // functions' Scalar arms) is reused here so the non-x86_64 build can
    // never drift from the x86_64 RESMOE_SIMD=0 arithmetic.
    pub fn silu_mul(h: &mut Matrix, g: &Matrix) {
        silu_mul_scalar(h, g)
    }
    pub fn relu(data: &mut [f32]) {
        relu_scalar(data)
    }
    pub fn softmax(xs: &mut [f32]) {
        softmax_scalar(xs)
    }
    pub fn rmsnorm(x: &[f32], gain: &[f32], out: &mut [f32], eps: f32) {
        rmsnorm_scalar(x, gain, out, eps)
    }
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        dot_scalar(a, b)
    }
    pub fn axpy(dst: &mut [f32], alpha: f32, src: &[f32]) {
        axpy_scalar(dst, alpha, src)
    }
    pub fn add_slice(dst: &mut [f32], src: &[f32]) {
        add_slice_scalar(dst, src)
    }
    pub fn add_bias_rows(m: &mut Matrix, bias: &[f32]) {
        add_bias_rows_scalar(m, bias)
    }
    pub fn scale_cols(m: &mut Matrix, s: &[f32]) {
        scale_cols_scalar(m, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn resolve_kind_policy() {
        // Kill-switch beats detection, case-insensitively.
        for off in ["0", "off", "false", "scalar", "OFF", "False", "SCALAR"] {
            assert_eq!(resolve_kind(Some(off), true), KernelKind::Scalar);
            assert_eq!(resolve_kind(Some(off), false), KernelKind::Scalar);
        }
        // Anything else defers to the hardware.
        for on in [None, Some("1"), Some("on"), Some("avx2"), Some("")] {
            assert_eq!(resolve_kind(on, true), KernelKind::Avx2);
            assert_eq!(resolve_kind(on, false), KernelKind::Scalar);
        }
    }

    #[test]
    fn kernel_kind_is_stable_and_labeled() {
        let k = kernel_kind();
        assert_eq!(k, kernel_kind(), "kind must be pinned per process");
        match k {
            KernelKind::Scalar => assert_eq!(kernel_label(), "scalar"),
            KernelKind::Avx2 => assert_eq!(kernel_label(), "avx2+fma"),
        }
    }

    #[test]
    fn exact_elementwise_tier_is_bitwise_identical_across_kinds() {
        // axpy / add_slice / add_bias / scale_cols use non-fused mul+add in
        // the SIMD bodies precisely so this holds.
        if kernel_kind() == KernelKind::Scalar {
            return; // single kind — nothing to compare
        }
        let mut rng = Rng::new(31);
        for cols in [1usize, 7, 8, 9, 16, 33] {
            let src: Vec<f32> = rng.normal_vec(cols, 1.0);
            let base: Vec<f32> = rng.normal_vec(cols, 1.0);
            let mut via_kernel = base.clone();
            axpy(&mut via_kernel, 0.37, &src); // dispatches to Avx2
            let mut via_scalar = base.clone();
            for (d, s) in via_scalar.iter_mut().zip(&src) {
                *d += 0.37 * *s;
            }
            assert_eq!(via_kernel, via_scalar, "axpy must be exact at cols={cols}");
            let mut a = base.clone();
            add_slice(&mut a, &src);
            let b: Vec<f32> = base.iter().zip(&src).map(|(x, y)| x + y).collect();
            assert_eq!(a, b, "add_slice must be exact at cols={cols}");
        }
    }

    #[test]
    fn softmax_dispatch_matches_scalar_reference_within_tolerance() {
        let mut rng = Rng::new(32);
        for n in [1usize, 2, 5, 8, 9, 31, 64, 100] {
            let xs: Vec<f32> = rng.normal_vec(n, 3.0);
            let mut got = xs.clone();
            softmax_inplace(&mut got);
            // Scalar reference (divide-free formulation, same as dispatch).
            let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = xs.iter().map(|x| (x - max).exp()).collect();
            let sum: f32 = exps.iter().sum();
            let inv = 1.0 / sum;
            let mut total = 0.0f64;
            for (g, e) in got.iter().zip(&exps) {
                let want = e * inv;
                assert!(
                    (g - want).abs() <= 1e-5 * want.abs().max(1e-6),
                    "n={n}: {g} vs {want}"
                );
                total += *g as f64;
            }
            assert!((total - 1.0).abs() < 1e-4, "softmax sums to 1, got {total}");
        }
    }

    #[test]
    fn silu_dispatch_matches_scalar_within_tolerance() {
        let mut rng = Rng::new(33);
        let h0 = Matrix::randn(5, 13, 2.0, &mut rng);
        let g = Matrix::randn(5, 13, 1.0, &mut rng);
        let mut h = h0.clone();
        silu_mul(&mut h, &g);
        for r in 0..5 {
            for c in 0..13 {
                let want = silu(h0.at(r, c)) * g.at(r, c);
                let got = h.at(r, c);
                assert!(
                    (got - want).abs() <= 1e-5 * want.abs().max(1e-5),
                    "({r},{c}): {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn silu_saturates_without_nan_at_extremes() {
        let mut h = Matrix::from_vec(1, 4, vec![-100.0, -20.0, 20.0, 100.0]);
        let g = Matrix::from_vec(1, 4, vec![1.0, 1.0, 1.0, 1.0]);
        silu_mul(&mut h, &g);
        assert!(h.data.iter().all(|v| v.is_finite()), "{:?}", h.data);
        assert!(h.at(0, 0).abs() < 1e-6);
        assert!((h.at(0, 3) - 100.0).abs() < 1e-3);
    }

    #[test]
    fn rmsnorm_dispatch_matches_scalar_within_tolerance() {
        let mut rng = Rng::new(34);
        for n in [1usize, 8, 9, 17, 64] {
            let x: Vec<f32> = rng.normal_vec(n, 1.0);
            let gain: Vec<f32> = rng.normal_vec(n, 1.0);
            let mut out = vec![0.0f32; n];
            rmsnorm(&x, &gain, &mut out);
            let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / n as f32;
            let inv = 1.0 / (ms + 1e-6).sqrt();
            for ((o, &v), &g) in out.iter().zip(&x).zip(&gain) {
                let want = v * inv * g;
                assert!((o - want).abs() <= 1e-5 * want.abs().max(1e-5));
            }
        }
    }
}
