//! Dense row-major `f32` matrix — the arithmetic substrate for everything
//! (no BLAS is available offline).
//!
//! Since PR 5 the matmul entry points are thin shims over the microkernel
//! subsystem in [`super::kernel`]: a runtime-dispatched AVX2+FMA
//! register-blocked kernel with a portable scalar twin (`RESMOE_SIMD=0`
//! pins scalar). Backing storage is 32-byte aligned ([`super::avec::AVec`])
//! so the SIMD panels start on vector boundaries. This is the L3 hot path
//! profiled in EXPERIMENTS.md §Perf / §Kernels.

use super::avec::AVec;
use super::kernel;
use crate::util::Rng;
use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: AVec,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix[{}x{}]", self.rows, self.cols)?;
        if self.rows * self.cols <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

/// Work below this threshold runs single-threaded (pool dispatch costs
/// more than the arithmetic for tiny matrices in the decode hot loop).
pub(crate) const PAR_MIN_FLOPS: usize = 1 << 20;

impl Matrix {
    // ------------------------------------------------------------ creation
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: AVec::zeroed(rows * cols) }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data: AVec::from_vec(data) }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix::from_vec(rows, cols, data)
    }

    /// Gaussian init with the given std (the Switch-Transformer-style init).
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Matrix {
        Matrix::from_vec(rows, cols, rng.normal_vec(rows * cols, std))
    }

    pub fn identity(n: usize) -> Matrix {
        Matrix::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    // ------------------------------------------------------------- access
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows];
        self.col_into(c, &mut out);
        out
    }

    /// Strided copy of column `c` into `out` — the allocation-free variant
    /// of [`Self::col`] for the restore hot path (design-matrix bias
    /// extraction runs once per cache miss).
    pub fn col_into(&self, c: usize, out: &mut [f32]) {
        assert!(c < self.cols, "col_into: column {c} out of range");
        assert_eq!(out.len(), self.rows, "col_into: length mismatch");
        for (r, o) in out.iter_mut().enumerate() {
            *o = self.data[r * self.cols + c];
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn n_params(&self) -> usize {
        self.rows * self.cols
    }

    // -------------------------------------------------------------- matmul
    /// C = self @ other.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch {:?} @ {:?}", self.shape(), other.shape());
        let mut out = Matrix::zeros(self.rows, other.cols);
        matmul_into(self, other, &mut out);
        out
    }

    /// C = self @ other^T (other stored row-major; its rows are the columns
    /// of the product).
    ///
    /// §Perf: packed-panel microkernel with runtime AVX2 dispatch — see
    /// [`super::kernel::matmul_nt_into_with`].
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.rows);
        matmul_nt_into(self, other, &mut out, false);
        out
    }

    /// out += self @ other^T (accumulating form for the fused residual
    /// corrections).
    pub fn matmul_nt_acc(&self, other: &Matrix, out: &mut Matrix) {
        matmul_nt_into(self, other, out, true);
    }

    /// Reference kernel: one serial dot product per output element, no
    /// blocking, no SIMD. `#[cfg(test)]`-only since PR 5 — it exists purely
    /// as the correctness oracle the optimized kernels are tested against
    /// (benches compare forced-kind entry points instead).
    #[cfg(test)]
    pub fn matmul_nt_naive(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt dim mismatch");
        let (m, n, k) = (self.rows, other.rows, self.cols);
        let mut out = Matrix::zeros(m, n);
        for r in 0..m {
            let a = self.row(r);
            let row_out = out.row_mut(r);
            for (j, out_v) in row_out.iter_mut().enumerate() {
                let b = other.row(j);
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += a[kk] * b[kk];
                }
                *out_v = acc;
            }
        }
        out
    }

    /// C = self^T @ other.
    pub fn matmul_tn(&self, other: &Matrix) -> Matrix {
        kernel::matmul_tn_with(kernel::kernel_kind(), self, other)
    }

    /// y = self @ x for a vector x (per-row `kernel::dot` — FMA lanes under
    /// AVX2, the plain accumulation loop under the scalar kind).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|r| kernel::dot(self.row(r), x)).collect()
    }

    // ---------------------------------------------------------- elementwise
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        // Exact op (one rounding per element): vector and scalar kernels
        // are bitwise identical, so this dispatch never changes results.
        kernel::add_slice(&mut self.data, &other.data);
    }

    /// self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        kernel::axpy(&mut self.data, alpha, &other.data);
    }

    pub fn scale(&self, alpha: f32) -> Matrix {
        let data = self.data.iter().map(|a| a * alpha).collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        let data = self.data.iter().map(|&a| f(a)).collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    // --------------------------------------------------------------- norms
    pub fn frob_norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.frob_norm_sq().sqrt()
    }

    /// ||self - other||_F^2 — the paper's approximation-error building block.
    pub fn sq_dist(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }

    // ------------------------------------------------------------ reshaping
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        let mut out = Matrix::zeros(self.rows, self.cols + other.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        out
    }

    /// Vertical concatenation.
    pub fn vcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Columns `[lo, hi)` as a new matrix.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.cols);
        let mut out = Matrix::zeros(self.rows, hi - lo);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[lo..hi]);
        }
        out
    }

    /// Rows `[lo, hi)` as a new matrix (cheap contiguous copy).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.rows);
        Matrix {
            rows: hi - lo,
            cols: self.cols,
            data: AVec::from_vec(self.data[lo * self.cols..hi * self.cols].to_vec()),
        }
    }

    /// Rows permuted: out[i, :] = self[perm[i], :]  (i.e. out = P @ self where
    /// P[i, perm[i]] = 1 — the `T_k W_k` operation of the paper).
    pub fn permute_rows(&self, perm: &[usize]) -> Matrix {
        assert_eq!(perm.len(), self.rows);
        let mut out = Matrix::zeros(self.rows, self.cols);
        for (i, &src) in perm.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(src));
        }
        out
    }

    /// Columns permuted: out[:, j] = self[:, perm[j]] (= self @ P^T for the
    /// same P as `permute_rows` — used for `W2_k T_k^T`).
    pub fn permute_cols(&self, perm: &[usize]) -> Matrix {
        assert_eq!(perm.len(), self.cols);
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = out.row_mut(r);
            for (j, &p) in perm.iter().enumerate() {
                dst[j] = src[p];
            }
        }
        out
    }

    /// Mean of a set of equally shaped matrices.
    pub fn mean_of(mats: &[&Matrix]) -> Matrix {
        assert!(!mats.is_empty());
        let mut out = Matrix::zeros(mats[0].rows, mats[0].cols);
        for m in mats {
            out.add_assign(m);
        }
        out.scale(1.0 / mats.len() as f32)
    }
}

/// C = A @ B through the runtime-dispatched kernel ([`kernel`]).
pub fn matmul_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    kernel::matmul_into_with(kernel::kernel_kind(), a, b, out, false);
}

/// Accumulating matmul: out += A @ B (the fused low-rank path).
pub fn matmul_acc_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    kernel::matmul_into_with(kernel::kernel_kind(), a, b, out, true);
}

/// out (+)= a @ otherᵀ through the runtime-dispatched packed-panel kernel.
pub fn matmul_nt_into(a: &Matrix, other: &Matrix, out: &mut Matrix, accumulate: bool) {
    kernel::matmul_nt_into_with(kernel::kernel_kind(), a, other, out, accumulate);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::kernel::{
        kernel_kind, matmul_into_with, matmul_nt_into_with, matmul_tn_with, KernelKind,
    };

    fn approx_eq(a: &Matrix, b: &Matrix, tol: f64) -> bool {
        a.shape() == b.shape() && a.sq_dist(b).sqrt() < tol
    }

    /// The kernel kinds to test: the scalar twin always, plus the runtime
    /// kind when it differs (i.e. AVX2 on machines that have it).
    fn test_kinds() -> Vec<KernelKind> {
        let mut kinds = vec![KernelKind::Scalar];
        if kernel_kind() != KernelKind::Scalar {
            kinds.push(kernel_kind());
        }
        kinds
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(7, 5, 1.0, &mut rng);
        let i = Matrix::identity(5);
        assert!(approx_eq(&a.matmul(&i), &a, 1e-6));
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(9, 6, 1.0, &mut rng);
        let b = Matrix::randn(6, 11, 1.0, &mut rng);
        let c0 = a.matmul(&b);
        let c1 = a.matmul_nt(&b.transpose());
        let c2 = a.transpose().matmul_tn(&b);
        let c3 = a.matmul_nt_naive(&b.transpose());
        assert!(approx_eq(&c0, &c1, 1e-4));
        assert!(approx_eq(&c0, &c2, 1e-4));
        assert!(approx_eq(&c0, &c3, 1e-4));
    }

    #[test]
    fn every_entry_point_matches_naive_under_every_kernel() {
        // Satellite: all public matmul entries vs the cfg(test) naive
        // reference, under BOTH kernel kinds, across ragged shapes that
        // straddle the 6-row / 16-col / 256-k microkernel tile edges.
        let mut rng = Rng::new(17);
        for (m, n, k) in [
            (1, 1, 1),
            (5, 15, 31),
            (6, 16, 64),
            (7, 17, 65),
            (12, 33, 255),
            (13, 64, 256),
            (23, 65, 257),
            (96, 224, 64),
        ] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let bt = Matrix::randn(n, k, 1.0, &mut rng); // B^T layout (n x k)
            let b = bt.transpose(); // k x n
            let want = a.matmul_nt_naive(&bt);
            let tol = 1e-4 * want.frob_norm_sq().max(1.0);
            for kind in test_kinds() {
                // NT, plain and accumulating.
                let mut got = Matrix::zeros(m, n);
                matmul_nt_into_with(kind, &a, &bt, &mut got, false);
                assert!(got.sq_dist(&want) < tol, "{kind:?} nt {m}x{k}@({n}x{k})^T");
                let seed = Matrix::randn(m, n, 1.0, &mut rng);
                let mut acc = seed.clone();
                matmul_nt_into_with(kind, &a, &bt, &mut acc, true);
                assert!(acc.sq_dist(&seed.add(&want)) < tol, "{kind:?} nt acc");
                // NN, plain and accumulating.
                let mut got_nn = Matrix::zeros(m, n);
                matmul_into_with(kind, &a, &b, &mut got_nn, false);
                assert!(got_nn.sq_dist(&want) < tol, "{kind:?} nn {m}x{k}@{k}x{n}");
                let mut acc_nn = seed.clone();
                matmul_into_with(kind, &a, &b, &mut acc_nn, true);
                assert!(acc_nn.sq_dist(&seed.add(&want)) < tol, "{kind:?} nn acc");
                // TN.
                let got_tn = matmul_tn_with(kind, &a.transpose(), &b);
                assert!(got_tn.sq_dist(&want) < tol, "{kind:?} tn");
            }
        }
    }

    #[test]
    fn kernel_rows_are_batch_position_independent() {
        // The bit-for-bit theorem the serving paths rest on: an output row
        // depends only on its own A-row, never on the batch it rides in —
        // for BOTH kernels, including ragged row tails (7 = 6 + 1 vs
        // 4 + 3 microkernel splits).
        let mut rng = Rng::new(18);
        let bt = Matrix::randn(37, 29, 1.0, &mut rng);
        let xa = Matrix::randn(4, 29, 1.0, &mut rng);
        let xb = Matrix::randn(3, 29, 1.0, &mut rng);
        let cat = xa.vcat(&xb);
        for kind in test_kinds() {
            let mut full = Matrix::zeros(7, 37);
            matmul_nt_into_with(kind, &cat, &bt, &mut full, false);
            let mut ya = Matrix::zeros(4, 37);
            matmul_nt_into_with(kind, &xa, &bt, &mut ya, false);
            let mut yb = Matrix::zeros(3, 37);
            matmul_nt_into_with(kind, &xb, &bt, &mut yb, false);
            assert_eq!(full.data, ya.vcat(&yb).data, "{kind:?}: rows must be position-independent");
        }
    }

    #[test]
    fn matmul_parallel_matches_serial() {
        // Large enough to trigger the parallel path.
        let mut rng = Rng::new(3);
        let a = Matrix::randn(128, 96, 1.0, &mut rng);
        let b = Matrix::randn(96, 128, 1.0, &mut rng);
        let big = a.matmul(&b);
        // Serial reference via explicit loop.
        let mut refm = Matrix::zeros(128, 128);
        for i in 0..128 {
            for j in 0..128 {
                let mut acc = 0.0f32;
                for kk in 0..96 {
                    acc += a.at(i, kk) * b.at(kk, j);
                }
                *refm.at_mut(i, j) = acc;
            }
        }
        assert!(approx_eq(&big, &refm, 1e-3));
    }

    #[test]
    fn backing_storage_is_32_byte_aligned() {
        // The alignment contract of the SIMD layer: every constructor and
        // every derived matrix exposes a 32B-aligned base pointer.
        let mut rng = Rng::new(19);
        let m = Matrix::randn(9, 13, 1.0, &mut rng);
        for mat in [
            &m,
            &Matrix::zeros(3, 5),
            &m.transpose(),
            &m.slice_cols(2, 11),
            &m.slice_rows(1, 8),
            &m.hcat(&m),
            &m.vcat(&m),
            &m.scale(2.0),
        ] {
            assert_eq!(
                mat.data.as_ptr() as usize % crate::tensor::avec::ALIGN,
                0,
                "matrix backing storage must stay 32B-aligned"
            );
        }
        // col_into writes into caller storage and must not disturb it.
        let mut buf = vec![0.0f32; 9];
        m.col_into(4, &mut buf);
        assert_eq!(buf, m.col(4));
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(8, 5, 1.0, &mut rng);
        let x: Vec<f32> = rng.normal_vec(5, 1.0);
        let xm = Matrix::from_vec(5, 1, x.clone());
        let y = a.matvec(&x);
        let ym = a.matmul(&xm);
        for i in 0..8 {
            assert!((y[i] - ym.at(i, 0)).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(33, 65, 1.0, &mut rng);
        assert!(approx_eq(&a.transpose().transpose(), &a, 1e-9));
    }

    #[test]
    fn hcat_slice_roundtrip() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(4, 3, 1.0, &mut rng);
        let b = Matrix::randn(4, 2, 1.0, &mut rng);
        let cat = a.hcat(&b);
        assert_eq!(cat.shape(), (4, 5));
        assert!(approx_eq(&cat.slice_cols(0, 3), &a, 1e-9));
        assert!(approx_eq(&cat.slice_cols(3, 5), &b, 1e-9));
    }

    #[test]
    fn vcat_slice_rows_roundtrip() {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(3, 4, 1.0, &mut rng);
        let b = Matrix::randn(2, 4, 1.0, &mut rng);
        let cat = a.vcat(&b);
        assert!(approx_eq(&cat.slice_rows(0, 3), &a, 1e-9));
        assert!(approx_eq(&cat.slice_rows(3, 5), &b, 1e-9));
    }

    #[test]
    fn permute_rows_then_cols_is_conjugation() {
        // (P A) then undoing with inverse perm restores A.
        let mut rng = Rng::new(8);
        let a = Matrix::randn(6, 6, 1.0, &mut rng);
        let perm = rng.permutation(6);
        let mut inv = vec![0usize; 6];
        for (i, &p) in perm.iter().enumerate() {
            inv[p] = i;
        }
        let pa = a.permute_rows(&perm);
        assert!(approx_eq(&pa.permute_rows(&inv), &a, 1e-9));
    }

    #[test]
    fn permute_cols_matches_matmul_with_permutation_matrix() {
        let mut rng = Rng::new(9);
        let a = Matrix::randn(4, 5, 1.0, &mut rng);
        let perm = rng.permutation(5);
        // P with P[i, perm[i]] = 1; permute_cols(perm) must equal A @ P^T.
        let mut p = Matrix::zeros(5, 5);
        for (i, &j) in perm.iter().enumerate() {
            *p.at_mut(i, j) = 1.0;
        }
        let via_mm = a.matmul(&p.transpose());
        assert!(approx_eq(&a.permute_cols(&perm), &via_mm, 1e-6));
    }

    #[test]
    fn permute_rows_matches_matmul_with_permutation_matrix() {
        let mut rng = Rng::new(10);
        let a = Matrix::randn(5, 4, 1.0, &mut rng);
        let perm = rng.permutation(5);
        let mut p = Matrix::zeros(5, 5);
        for (i, &j) in perm.iter().enumerate() {
            *p.at_mut(i, j) = 1.0;
        }
        let via_mm = p.matmul(&a);
        assert!(approx_eq(&a.permute_rows(&perm), &via_mm, 1e-6));
    }

    #[test]
    fn norms_and_distance() {
        let a = Matrix::from_vec(1, 3, vec![3.0, 0.0, 4.0]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-9);
        let b = Matrix::zeros(1, 3);
        assert!((a.sq_dist(&b) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn mean_of_matrices() {
        let a = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        let m = Matrix::mean_of(&[&a, &b]);
        assert_eq!(m.data, vec![2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "matmul dim mismatch")]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        let _ = a.matmul(&b);
    }

    #[test]
    fn blocked_matmul_nt_crosses_tile_boundaries() {
        // Shapes straddling the panel/tile edges (63..65 around the j-tile,
        // 255..300 around the k-panel) must agree with the naive kernel.
        let mut rng = Rng::new(11);
        for (m, n, k) in [(3, 63, 255), (5, 64, 256), (7, 65, 300), (2, 130, 257), (1, 9, 1)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(n, k, 1.0, &mut rng);
            let got = a.matmul_nt(&b);
            let want = a.matmul_nt_naive(&b);
            assert!(
                got.sq_dist(&want) < 1e-4 * want.frob_norm_sq().max(1.0),
                "{m}x{k} @ ({n}x{k})^T: {}",
                got.sq_dist(&want)
            );
        }
    }

    #[test]
    fn matmul_nt_acc_accumulates() {
        let mut rng = Rng::new(12);
        let a = Matrix::randn(6, 20, 1.0, &mut rng);
        let b = Matrix::randn(10, 20, 1.0, &mut rng);
        let seed = Matrix::randn(6, 10, 1.0, &mut rng);
        let mut out = seed.clone();
        a.matmul_nt_acc(&b, &mut out);
        let want = seed.add(&a.matmul_nt_naive(&b));
        assert!(out.sq_dist(&want) < 1e-6);
    }

    #[test]
    fn matmul_acc_into_accumulates() {
        let mut rng = Rng::new(13);
        let a = Matrix::randn(4, 7, 1.0, &mut rng);
        let b = Matrix::randn(7, 9, 1.0, &mut rng);
        let seed = Matrix::randn(4, 9, 1.0, &mut rng);
        let mut out = seed.clone();
        matmul_acc_into(&a, &b, &mut out);
        let want = seed.add(&a.matmul(&b));
        assert!(out.sq_dist(&want) < 1e-6);
    }

    #[test]
    fn col_into_matches_col() {
        let mut rng = Rng::new(14);
        let a = Matrix::randn(17, 5, 1.0, &mut rng);
        for c in 0..5 {
            let mut buf = vec![0.0f32; 17];
            a.col_into(c, &mut buf);
            assert_eq!(buf, a.col(c));
        }
    }
}
