//! Dense row-major `f32` matrix — the arithmetic substrate for everything
//! (no BLAS is available offline).
//!
//! The matmul kernel uses the i-k-j loop order (C[i,:] += A[i,k] * B[k,:]),
//! which streams both C and B rows sequentially so LLVM auto-vectorizes the
//! inner loop, plus row-parallelism over the persistent worker pool for
//! large outputs. `matmul_nt` is cache-blocked: `other` is packed into
//! j×k panels that stay L1/L2-resident across a worker's whole row chunk.
//! This is the L3 hot path profiled in EXPERIMENTS.md §Perf.

use crate::util::threads::{parallel_row_chunks_mut, parallel_rows_mut};
use crate::util::Rng;
use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix[{}x{}]", self.rows, self.cols)?;
        if self.rows * self.cols <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

/// Work below this threshold runs single-threaded (pool dispatch costs
/// more than the arithmetic for tiny matrices in the decode hot loop).
pub(crate) const PAR_MIN_FLOPS: usize = 1 << 20;

impl Matrix {
    // ------------------------------------------------------------ creation
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Gaussian init with the given std (the Switch-Transformer-style init).
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Matrix {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols, std) }
    }

    pub fn identity(n: usize) -> Matrix {
        Matrix::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    // ------------------------------------------------------------- access
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows];
        self.col_into(c, &mut out);
        out
    }

    /// Strided copy of column `c` into `out` — the allocation-free variant
    /// of [`Self::col`] for the restore hot path (design-matrix bias
    /// extraction runs once per cache miss).
    pub fn col_into(&self, c: usize, out: &mut [f32]) {
        assert!(c < self.cols, "col_into: column {c} out of range");
        assert_eq!(out.len(), self.rows, "col_into: length mismatch");
        for (r, o) in out.iter_mut().enumerate() {
            *o = self.data[r * self.cols + c];
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn n_params(&self) -> usize {
        self.rows * self.cols
    }

    // -------------------------------------------------------------- matmul
    /// C = self @ other.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch {:?} @ {:?}", self.shape(), other.shape());
        let mut out = Matrix::zeros(self.rows, other.cols);
        matmul_into(self, other, &mut out);
        out
    }

    /// C = self @ other^T (other stored row-major; its rows are the columns
    /// of the product).
    ///
    /// §Perf: cache-blocked and panel-packed — see [`matmul_nt_into`].
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.rows);
        matmul_nt_into(self, other, &mut out, false);
        out
    }

    /// out += self @ other^T (accumulating form for the fused residual
    /// corrections).
    pub fn matmul_nt_acc(&self, other: &Matrix, out: &mut Matrix) {
        matmul_nt_into(self, other, out, true);
    }

    /// Reference (pre-optimization) form of [`Self::matmul_nt`]: one serial
    /// dot product per output element. Kept for §Perf before/after
    /// benchmarking and as a correctness cross-check in tests.
    pub fn matmul_nt_naive(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt dim mismatch");
        let (m, n, k) = (self.rows, other.rows, self.cols);
        let mut out = Matrix::zeros(m, n);
        for r in 0..m {
            let a = self.row(r);
            let row_out = out.row_mut(r);
            for (j, out_v) in row_out.iter_mut().enumerate() {
                let b = other.row(j);
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += a[kk] * b[kk];
                }
                *out_v = acc;
            }
        }
        out
    }

    /// C = self^T @ other.
    pub fn matmul_tn(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_tn dim mismatch");
        let (m, n, k) = (self.cols, other.cols, self.rows);
        let mut out = Matrix::zeros(m, n);
        for kk in 0..k {
            let a_row = self.row(kk);
            let b_row = other.row(kk);
            for i in 0..m {
                let a = a_row[i];
                if a == 0.0 {
                    continue;
                }
                let out_row = &mut out.data[i * n..(i + 1) * n];
                for (o, &b) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// y = self @ x for a vector x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|r| {
                let row = self.row(r);
                let mut acc = 0.0f32;
                for (a, b) in row.iter().zip(x.iter()) {
                    acc += a * b;
                }
                acc
            })
            .collect()
    }

    // ---------------------------------------------------------- elementwise
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&self, alpha: f32) -> Matrix {
        let data = self.data.iter().map(|a| a * alpha).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        let data = self.data.iter().map(|&a| f(a)).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    // --------------------------------------------------------------- norms
    pub fn frob_norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.frob_norm_sq().sqrt()
    }

    /// ||self - other||_F^2 — the paper's approximation-error building block.
    pub fn sq_dist(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }

    // ------------------------------------------------------------ reshaping
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        let mut out = Matrix::zeros(self.rows, self.cols + other.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        out
    }

    /// Vertical concatenation.
    pub fn vcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Columns `[lo, hi)` as a new matrix.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.cols);
        let mut out = Matrix::zeros(self.rows, hi - lo);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[lo..hi]);
        }
        out
    }

    /// Rows `[lo, hi)` as a new matrix (cheap contiguous copy).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.rows);
        Matrix {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }

    /// Rows permuted: out[i, :] = self[perm[i], :]  (i.e. out = P @ self where
    /// P[i, perm[i]] = 1 — the `T_k W_k` operation of the paper).
    pub fn permute_rows(&self, perm: &[usize]) -> Matrix {
        assert_eq!(perm.len(), self.rows);
        let mut out = Matrix::zeros(self.rows, self.cols);
        for (i, &src) in perm.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(src));
        }
        out
    }

    /// Columns permuted: out[:, j] = self[:, perm[j]] (= self @ P^T for the
    /// same P as `permute_rows` — used for `W2_k T_k^T`).
    pub fn permute_cols(&self, perm: &[usize]) -> Matrix {
        assert_eq!(perm.len(), self.cols);
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = out.row_mut(r);
            for (j, &p) in perm.iter().enumerate() {
                dst[j] = src[p];
            }
        }
        out
    }

    /// Mean of a set of equally shaped matrices.
    pub fn mean_of(mats: &[&Matrix]) -> Matrix {
        assert!(!mats.is_empty());
        let mut out = Matrix::zeros(mats[0].rows, mats[0].cols);
        for m in mats {
            out.add_assign(m);
        }
        out.scale(1.0 / mats.len() as f32)
    }
}

/// Core i-k-j matmul kernel with optional row-parallelism: C = A @ B.
pub fn matmul_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    matmul_into_impl(a, b, out, false);
}

/// Accumulating i-k-j matmul: out += A @ B (the fused low-rank path).
pub fn matmul_acc_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    matmul_into_impl(a, b, out, true);
}

fn matmul_into_impl(a: &Matrix, b: &Matrix, out: &mut Matrix, accumulate: bool) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    assert_eq!(a.cols, b.rows, "matmul dim mismatch {:?} @ {:?}", a.shape(), b.shape());
    assert_eq!((out.rows, out.cols), (m, n), "matmul output shape");
    let kernel = |r: usize, out_row: &mut [f32]| {
        if !accumulate {
            out_row.fill(0.0);
        }
        let a_row = a.row(r);
        for kk in 0..k {
            let av = a_row[kk];
            if av == 0.0 {
                continue;
            }
            let b_row = &b.data[kk * n..kk * n + n];
            for (o, &bv) in out_row.iter_mut().zip(b_row.iter()) {
                *o += av * bv;
            }
        }
    };
    if 2 * m * n * k >= PAR_MIN_FLOPS && m > 1 {
        parallel_rows_mut(&mut out.data, m, n, |r, row| kernel(r, row));
    } else {
        for r in 0..m {
            let row = &mut out.data[r * n..(r + 1) * n];
            kernel(r, row);
        }
    }
}

/// j-tile width (rows of `other` processed per packed panel) and k-panel
/// depth of the blocked `matmul_nt` kernel. 64×256 f32 ≈ 64 KB — the panel
/// plus the active A-row slice stay L2-resident while being reused across a
/// worker's whole row chunk.
const NT_JB: usize = 64;
const NT_KB: usize = 256;

/// out (+)= a @ otherᵀ — the cache-blocked, panel-packed upgrade of the
/// 4-column kernel.
///
/// §Perf: per (j-tile, k-panel) pair the relevant rows of `other` are
/// packed once into a contiguous scratch panel and reused for every row of
/// the worker's chunk (the seed's kernel re-streamed all of `other` per
/// output row). The inner loop keeps the 8-/4-wide independent accumulators
/// that expose ILP to LLVM's auto-vectorizer.
pub fn matmul_nt_into(a: &Matrix, other: &Matrix, out: &mut Matrix, accumulate: bool) {
    assert_eq!(a.cols, other.cols, "matmul_nt dim mismatch");
    let (m, n, k) = (a.rows, other.rows, a.cols);
    assert_eq!((out.rows, out.cols), (m, n), "matmul_nt output shape");
    if n == 0 {
        return;
    }
    let chunk_kernel = |r0: usize, chunk: &mut [f32]| {
        let rows = chunk.len() / n;
        if !accumulate {
            chunk.fill(0.0);
        }
        // Single k-panel (covers every decode-shape matmul): `other`'s
        // contiguous rows already ARE the packed layout, so run the tile
        // kernel straight over them — zero allocation, zero copy.
        if k <= NT_KB {
            let mut jb = 0usize;
            while jb < n {
                let je = (jb + NT_JB).min(n);
                let jw = je - jb;
                for i in 0..rows {
                    let a_row = a.row(r0 + i);
                    let out_row = &mut chunk[i * n + jb..i * n + je];
                    nt_tile(a_row, &other.data[jb * k..], k, jw, out_row);
                }
                jb = je;
            }
            return;
        }
        let mut pack = vec![0.0f32; NT_JB * NT_KB];
        let mut kb = 0usize;
        while kb < k {
            let ke = (kb + NT_KB).min(k);
            let kw = ke - kb;
            let mut jb = 0usize;
            while jb < n {
                let je = (jb + NT_JB).min(n);
                let jw = je - jb;
                for (t, j) in (jb..je).enumerate() {
                    pack[t * kw..(t + 1) * kw].copy_from_slice(&other.row(j)[kb..ke]);
                }
                for i in 0..rows {
                    let a_row = &a.row(r0 + i)[kb..ke];
                    let out_row = &mut chunk[i * n + jb..i * n + je];
                    nt_tile(a_row, &pack, kw, jw, out_row);
                }
                jb = je;
            }
            kb = ke;
        }
    };
    if m * n * k >= PAR_MIN_FLOPS && m > 1 {
        parallel_row_chunks_mut(&mut out.data, m, n, |r0, chunk| chunk_kernel(r0, chunk));
    } else {
        chunk_kernel(0, &mut out.data);
    }
}

/// One packed tile: out[j] += dot(a_row, pack row j) for `jw` columns, with
/// 8-/4-wide independent accumulators.
#[inline]
fn nt_tile(a_row: &[f32], pack: &[f32], kw: usize, jw: usize, out: &mut [f32]) {
    let mut j = 0usize;
    while j + 8 <= jw {
        let b0 = &pack[j * kw..(j + 1) * kw];
        let b1 = &pack[(j + 1) * kw..(j + 2) * kw];
        let b2 = &pack[(j + 2) * kw..(j + 3) * kw];
        let b3 = &pack[(j + 3) * kw..(j + 4) * kw];
        let b4 = &pack[(j + 4) * kw..(j + 5) * kw];
        let b5 = &pack[(j + 5) * kw..(j + 6) * kw];
        let b6 = &pack[(j + 6) * kw..(j + 7) * kw];
        let b7 = &pack[(j + 7) * kw..(j + 8) * kw];
        let mut s = [0.0f32; 8];
        for kk in 0..kw {
            let av = a_row[kk];
            s[0] += av * b0[kk];
            s[1] += av * b1[kk];
            s[2] += av * b2[kk];
            s[3] += av * b3[kk];
            s[4] += av * b4[kk];
            s[5] += av * b5[kk];
            s[6] += av * b6[kk];
            s[7] += av * b7[kk];
        }
        for (o, sv) in out[j..j + 8].iter_mut().zip(s) {
            *o += sv;
        }
        j += 8;
    }
    while j + 4 <= jw {
        let b0 = &pack[j * kw..(j + 1) * kw];
        let b1 = &pack[(j + 1) * kw..(j + 2) * kw];
        let b2 = &pack[(j + 2) * kw..(j + 3) * kw];
        let b3 = &pack[(j + 3) * kw..(j + 4) * kw];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for kk in 0..kw {
            let av = a_row[kk];
            s0 += av * b0[kk];
            s1 += av * b1[kk];
            s2 += av * b2[kk];
            s3 += av * b3[kk];
        }
        out[j] += s0;
        out[j + 1] += s1;
        out[j + 2] += s2;
        out[j + 3] += s3;
        j += 4;
    }
    while j < jw {
        let b0 = &pack[j * kw..(j + 1) * kw];
        let mut acc = 0.0f32;
        for kk in 0..kw {
            acc += a_row[kk] * b0[kk];
        }
        out[j] += acc;
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx_eq(a: &Matrix, b: &Matrix, tol: f64) -> bool {
        a.shape() == b.shape() && a.sq_dist(b).sqrt() < tol
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(7, 5, 1.0, &mut rng);
        let i = Matrix::identity(5);
        assert!(approx_eq(&a.matmul(&i), &a, 1e-6));
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(9, 6, 1.0, &mut rng);
        let b = Matrix::randn(6, 11, 1.0, &mut rng);
        let c0 = a.matmul(&b);
        let c1 = a.matmul_nt(&b.transpose());
        let c2 = a.transpose().matmul_tn(&b);
        let c3 = a.matmul_nt_naive(&b.transpose());
        assert!(approx_eq(&c0, &c1, 1e-4));
        assert!(approx_eq(&c0, &c2, 1e-4));
        assert!(approx_eq(&c0, &c3, 1e-4));
    }

    #[test]
    fn matmul_parallel_matches_serial() {
        // Large enough to trigger the parallel path.
        let mut rng = Rng::new(3);
        let a = Matrix::randn(128, 96, 1.0, &mut rng);
        let b = Matrix::randn(96, 128, 1.0, &mut rng);
        let big = a.matmul(&b);
        // Serial reference via explicit loop.
        let mut refm = Matrix::zeros(128, 128);
        for i in 0..128 {
            for j in 0..128 {
                let mut acc = 0.0f32;
                for kk in 0..96 {
                    acc += a.at(i, kk) * b.at(kk, j);
                }
                *refm.at_mut(i, j) = acc;
            }
        }
        assert!(approx_eq(&big, &refm, 1e-3));
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(8, 5, 1.0, &mut rng);
        let x: Vec<f32> = rng.normal_vec(5, 1.0);
        let xm = Matrix::from_vec(5, 1, x.clone());
        let y = a.matvec(&x);
        let ym = a.matmul(&xm);
        for i in 0..8 {
            assert!((y[i] - ym.at(i, 0)).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(33, 65, 1.0, &mut rng);
        assert!(approx_eq(&a.transpose().transpose(), &a, 1e-9));
    }

    #[test]
    fn hcat_slice_roundtrip() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(4, 3, 1.0, &mut rng);
        let b = Matrix::randn(4, 2, 1.0, &mut rng);
        let cat = a.hcat(&b);
        assert_eq!(cat.shape(), (4, 5));
        assert!(approx_eq(&cat.slice_cols(0, 3), &a, 1e-9));
        assert!(approx_eq(&cat.slice_cols(3, 5), &b, 1e-9));
    }

    #[test]
    fn vcat_slice_rows_roundtrip() {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(3, 4, 1.0, &mut rng);
        let b = Matrix::randn(2, 4, 1.0, &mut rng);
        let cat = a.vcat(&b);
        assert!(approx_eq(&cat.slice_rows(0, 3), &a, 1e-9));
        assert!(approx_eq(&cat.slice_rows(3, 5), &b, 1e-9));
    }

    #[test]
    fn permute_rows_then_cols_is_conjugation() {
        // (P A) then undoing with inverse perm restores A.
        let mut rng = Rng::new(8);
        let a = Matrix::randn(6, 6, 1.0, &mut rng);
        let perm = rng.permutation(6);
        let mut inv = vec![0usize; 6];
        for (i, &p) in perm.iter().enumerate() {
            inv[p] = i;
        }
        let pa = a.permute_rows(&perm);
        assert!(approx_eq(&pa.permute_rows(&inv), &a, 1e-9));
    }

    #[test]
    fn permute_cols_matches_matmul_with_permutation_matrix() {
        let mut rng = Rng::new(9);
        let a = Matrix::randn(4, 5, 1.0, &mut rng);
        let perm = rng.permutation(5);
        // P with P[i, perm[i]] = 1; permute_cols(perm) must equal A @ P^T.
        let mut p = Matrix::zeros(5, 5);
        for (i, &j) in perm.iter().enumerate() {
            *p.at_mut(i, j) = 1.0;
        }
        let via_mm = a.matmul(&p.transpose());
        assert!(approx_eq(&a.permute_cols(&perm), &via_mm, 1e-6));
    }

    #[test]
    fn permute_rows_matches_matmul_with_permutation_matrix() {
        let mut rng = Rng::new(10);
        let a = Matrix::randn(5, 4, 1.0, &mut rng);
        let perm = rng.permutation(5);
        let mut p = Matrix::zeros(5, 5);
        for (i, &j) in perm.iter().enumerate() {
            *p.at_mut(i, j) = 1.0;
        }
        let via_mm = p.matmul(&a);
        assert!(approx_eq(&a.permute_rows(&perm), &via_mm, 1e-6));
    }

    #[test]
    fn norms_and_distance() {
        let a = Matrix::from_vec(1, 3, vec![3.0, 0.0, 4.0]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-9);
        let b = Matrix::zeros(1, 3);
        assert!((a.sq_dist(&b) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn mean_of_matrices() {
        let a = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        let m = Matrix::mean_of(&[&a, &b]);
        assert_eq!(m.data, vec![2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "matmul dim mismatch")]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        let _ = a.matmul(&b);
    }

    #[test]
    fn blocked_matmul_nt_crosses_tile_boundaries() {
        // Shapes straddling the NT_JB/NT_KB tile edges (63..65 around 64,
        // 255..300 around 256) must agree with the naive kernel.
        let mut rng = Rng::new(11);
        for (m, n, k) in [(3, 63, 255), (5, 64, 256), (7, 65, 300), (2, 130, 257), (1, 9, 1)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(n, k, 1.0, &mut rng);
            let got = a.matmul_nt(&b);
            let want = a.matmul_nt_naive(&b);
            assert!(
                got.sq_dist(&want) < 1e-4 * want.frob_norm_sq().max(1.0),
                "{m}x{k} @ ({n}x{k})^T: {}",
                got.sq_dist(&want)
            );
        }
    }

    #[test]
    fn matmul_nt_acc_accumulates() {
        let mut rng = Rng::new(12);
        let a = Matrix::randn(6, 20, 1.0, &mut rng);
        let b = Matrix::randn(10, 20, 1.0, &mut rng);
        let seed = Matrix::randn(6, 10, 1.0, &mut rng);
        let mut out = seed.clone();
        a.matmul_nt_acc(&b, &mut out);
        let want = seed.add(&a.matmul_nt_naive(&b));
        assert!(out.sq_dist(&want) < 1e-6);
    }

    #[test]
    fn matmul_acc_into_accumulates() {
        let mut rng = Rng::new(13);
        let a = Matrix::randn(4, 7, 1.0, &mut rng);
        let b = Matrix::randn(7, 9, 1.0, &mut rng);
        let seed = Matrix::randn(4, 9, 1.0, &mut rng);
        let mut out = seed.clone();
        matmul_acc_into(&a, &b, &mut out);
        let want = seed.add(&a.matmul(&b));
        assert!(out.sq_dist(&want) < 1e-6);
    }

    #[test]
    fn col_into_matches_col() {
        let mut rng = Rng::new(14);
        let a = Matrix::randn(17, 5, 1.0, &mut rng);
        for c in 0..5 {
            let mut buf = vec![0.0f32; 17];
            a.col_into(c, &mut buf);
            assert_eq!(buf, a.col(c));
        }
    }
}
