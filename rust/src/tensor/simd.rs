//! AVX2+FMA microkernels (x86_64 only; compiled out elsewhere).
//!
//! Every function here is `unsafe` and `#[target_feature(enable =
//! "avx2,fma")]`: callers (the drivers in `tensor/kernel.rs`) must have
//! verified CPU support through [`super::kernel::kernel_kind`] before
//! entering. Pointers/slices must satisfy the bounds stated per function.
//!
//! **Determinism contract** (the property the serving parity theorems rest
//! on): for a fixed kernel mode, every output element is computed by an
//! arithmetic sequence that depends ONLY on the reduction extent (`k`,
//! nnz pattern, row length) and the element's column position — never on
//! the batch row count, the element's row position, the thread that ran
//! it, or the tile it landed in. Zero-padded pack lanes keep ragged edges
//! on the same sequence (lanes are independent). SIMD results may differ
//! from the scalar twin in final bits (FMA, lane-split reductions,
//! polynomial `exp`); `RESMOE_SIMD` pins one mode per process.

use std::arch::x86_64::*;

// --------------------------------------------------------------- reductions

/// Horizontal sum in a fixed lane order: ((0+4)+(2+6)) + ((1+5)+(3+7)).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn hsum(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
    _mm_cvtss_f32(s)
}

/// Horizontal max (order-insensitive: max is associative and commutative).
#[target_feature(enable = "avx2,fma")]
unsafe fn hmax(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let s = _mm_max_ps(lo, hi);
    let s = _mm_max_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_max_ss(s, _mm_shuffle_ps::<1>(s, s));
    _mm_cvtss_f32(s)
}

// ------------------------------------------------------------- vectored exp

/// Vectorized `exp` (Cephes/avx_mathfun structure): range-reduce by
/// `n = round(x/ln 2)`, degree-5 polynomial on the reduced argument,
/// scale by `2^n` through the exponent field. |rel err| < 2e-7 on the
/// clamped domain (validated by `scripts/sim_simd.py` in exact f32
/// arithmetic). Clamps keep results finite: exp(88.38) < f32::MAX.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn vexp(x: __m256) -> __m256 {
    let one = _mm256_set1_ps(1.0);
    let x = _mm256_min_ps(x, _mm256_set1_ps(88.376_26));
    let x = _mm256_max_ps(x, _mm256_set1_ps(-87.336_55));
    // n = round-to-nearest-even(x / ln 2) — via the int round-trip, which
    // both rounds and yields the integer the 2^n scaling needs.
    let n = _mm256_cvtps_epi32(_mm256_mul_ps(x, _mm256_set1_ps(std::f32::consts::LOG2_E)));
    let fx = _mm256_cvtepi32_ps(n);
    // Cody–Waite: r = x - n*ln2_hi - n*ln2_lo.
    let r = _mm256_fnmadd_ps(fx, _mm256_set1_ps(0.693_359_375), x);
    let r = _mm256_fnmadd_ps(fx, _mm256_set1_ps(-2.121_944_4e-4), r);
    let r2 = _mm256_mul_ps(r, r);
    let mut p = _mm256_set1_ps(1.987_569_15e-4);
    p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.398_199_95e-3));
    p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(8.333_451_9e-3));
    p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(4.166_579_6e-2));
    p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.666_666_55e-1));
    p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(5.000_000_1e-1));
    let y = _mm256_add_ps(_mm256_fmadd_ps(p, r2, r), one);
    let pow2 = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
        n,
        _mm256_set1_epi32(0x7f),
    )));
    _mm256_mul_ps(y, pow2)
}

// ------------------------------------------------- GEMM NT microkernels
// C-tile (R x 16) += A-rows (broadcast along k) x packed B micropanel.
// pack layout: k-major, 16 lanes per k step (lanes beyond jw zero-padded
// by the driver). Accumulators start at zero; the tile is ADDED to C at
// the end, so per-element order is: panel-sum in strict k order, then one
// add into C — identical for every row count R (rows are independent).

macro_rules! mk_nt_r {
    ($name:ident, $rows:expr) => {
        /// # Safety
        /// avx2+fma verified; `a` has `$rows` rows of ≥ `kw` floats at
        /// stride `lda`; `pack` holds `kw*16` floats; `c` has `$rows` rows
        /// of ≥ `jw` floats at stride `ldc`; `jw ≤ 16`.
        #[target_feature(enable = "avx2,fma")]
        unsafe fn $name(
            a: *const f32,
            lda: usize,
            pack: *const f32,
            kw: usize,
            c: *mut f32,
            ldc: usize,
            jw: usize,
        ) {
            let mut acc = [[_mm256_setzero_ps(); 2]; $rows];
            let mut p = pack;
            for kk in 0..kw {
                let b0 = _mm256_loadu_ps(p);
                let b1 = _mm256_loadu_ps(p.add(8));
                p = p.add(16);
                for r in 0..$rows {
                    let av = _mm256_broadcast_ss(&*a.add(r * lda + kk));
                    acc[r][0] = _mm256_fmadd_ps(av, b0, acc[r][0]);
                    acc[r][1] = _mm256_fmadd_ps(av, b1, acc[r][1]);
                }
            }
            if jw == 16 {
                for r in 0..$rows {
                    let cr = c.add(r * ldc);
                    _mm256_storeu_ps(cr, _mm256_add_ps(_mm256_loadu_ps(cr), acc[r][0]));
                    let cr8 = cr.add(8);
                    _mm256_storeu_ps(cr8, _mm256_add_ps(_mm256_loadu_ps(cr8), acc[r][1]));
                }
            } else {
                let mut tmp = [0.0f32; 16];
                for r in 0..$rows {
                    _mm256_storeu_ps(tmp.as_mut_ptr(), acc[r][0]);
                    _mm256_storeu_ps(tmp.as_mut_ptr().add(8), acc[r][1]);
                    let cr = c.add(r * ldc);
                    for (j, t) in tmp.iter().enumerate().take(jw) {
                        *cr.add(j) += t;
                    }
                }
            }
        }
    };
}

mk_nt_r!(mk_nt_1, 1);
mk_nt_r!(mk_nt_2, 2);
mk_nt_r!(mk_nt_3, 3);
mk_nt_r!(mk_nt_4, 4);
mk_nt_r!(mk_nt_5, 5);
mk_nt_r!(mk_nt_6, 6);

/// Row-count dispatcher for the NT microkernel (`rows ∈ 1..=6`).
///
/// # Safety
/// See the per-kernel contract in [`mk_nt_r`].
#[target_feature(enable = "avx2,fma")]
pub unsafe fn mk_nt(
    rows: usize,
    a: *const f32,
    lda: usize,
    pack: *const f32,
    kw: usize,
    c: *mut f32,
    ldc: usize,
    jw: usize,
) {
    match rows {
        1 => mk_nt_1(a, lda, pack, kw, c, ldc, jw),
        2 => mk_nt_2(a, lda, pack, kw, c, ldc, jw),
        3 => mk_nt_3(a, lda, pack, kw, c, ldc, jw),
        4 => mk_nt_4(a, lda, pack, kw, c, ldc, jw),
        5 => mk_nt_5(a, lda, pack, kw, c, ldc, jw),
        6 => mk_nt_6(a, lda, pack, kw, c, ldc, jw),
        _ => unreachable!("mk_nt rows must be 1..=6"),
    }
}

// --------------------------------------- int8 dequant-fused NT microkernels
// Same C-tile/fold as mk_nt, but the packed B micropanel holds int8 codes
// (k-major, 16 lanes per k step) plus a 16-lane per-column scale vector.
// Each k step converts 16 codes to f32 in registers and multiplies by the
// scales — ONE rounding, identical to a materialized `code as f32 * scale`
// dequant — then runs the byte-identical FMA fold, so the fused kernel is
// bitwise equal to dequant-then-GEMM under this kernel kind. Ragged column
// tails use masked loads/stores (mask depends only on `jw`, never on the
// row, preserving the determinism contract).

/// Lane masks for a ragged 16-wide column tail: lane `j` of the first
/// (second) mask is all-ones iff `j < jw` (`j + 8 < jw`).
///
/// # Safety
/// avx2+fma verified.
#[target_feature(enable = "avx2,fma")]
unsafe fn tail_masks(jw: usize) -> (__m256i, __m256i) {
    let idx = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
    let m0 = _mm256_cmpgt_epi32(_mm256_set1_epi32(jw as i32), idx);
    let m1 = _mm256_cmpgt_epi32(_mm256_set1_epi32(jw as i32 - 8), idx);
    (m0, m1)
}

/// 16 int8 codes at `p` → two 8-lane f32 vectors scaled by `(s0, s1)`.
///
/// # Safety
/// avx2+fma verified; 16 readable bytes at `p`.
#[inline]
#[target_feature(enable = "avx2,fma")]
unsafe fn dequant16(p: *const i8, s0: __m256, s1: __m256) -> (__m256, __m256) {
    let raw = _mm_loadu_si128(p as *const __m128i);
    let lo = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(raw));
    let hi = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_srli_si128::<8>(raw)));
    (_mm256_mul_ps(lo, s0), _mm256_mul_ps(hi, s1))
}

macro_rules! mk_nt_q_r {
    ($name:ident, $rows:expr) => {
        /// # Safety
        /// avx2+fma verified; `a` has `$rows` rows of ≥ `kw` floats at
        /// stride `lda`; `pack` holds `kw*16` int8 codes; `scales` points
        /// at 16 readable f32 (per-lane column scales, padding lanes 0.0);
        /// `c` has `$rows` rows of ≥ `jw` floats at stride `ldc`; `jw ≤ 16`.
        #[target_feature(enable = "avx2,fma")]
        unsafe fn $name(
            a: *const f32,
            lda: usize,
            pack: *const i8,
            kw: usize,
            scales: *const f32,
            c: *mut f32,
            ldc: usize,
            jw: usize,
        ) {
            let s0 = _mm256_loadu_ps(scales);
            let s1 = _mm256_loadu_ps(scales.add(8));
            let mut acc = [[_mm256_setzero_ps(); 2]; $rows];
            let mut p = pack;
            for kk in 0..kw {
                let (b0, b1) = dequant16(p, s0, s1);
                p = p.add(16);
                for r in 0..$rows {
                    let av = _mm256_broadcast_ss(&*a.add(r * lda + kk));
                    acc[r][0] = _mm256_fmadd_ps(av, b0, acc[r][0]);
                    acc[r][1] = _mm256_fmadd_ps(av, b1, acc[r][1]);
                }
            }
            if jw == 16 {
                for r in 0..$rows {
                    let cr = c.add(r * ldc);
                    _mm256_storeu_ps(cr, _mm256_add_ps(_mm256_loadu_ps(cr), acc[r][0]));
                    let cr8 = cr.add(8);
                    _mm256_storeu_ps(cr8, _mm256_add_ps(_mm256_loadu_ps(cr8), acc[r][1]));
                }
            } else {
                // Padding lanes of acc are exactly 0 (zero codes × any
                // scale), and the masks keep them from touching memory.
                let (m0, m1) = tail_masks(jw);
                for r in 0..$rows {
                    let cr = c.add(r * ldc);
                    let cur0 = _mm256_maskload_ps(cr, m0);
                    _mm256_maskstore_ps(cr, m0, _mm256_add_ps(cur0, acc[r][0]));
                    let cr8 = cr.add(8);
                    let cur1 = _mm256_maskload_ps(cr8, m1);
                    _mm256_maskstore_ps(cr8, m1, _mm256_add_ps(cur1, acc[r][1]));
                }
            }
        }
    };
}

mk_nt_q_r!(mk_nt_q_1, 1);
mk_nt_q_r!(mk_nt_q_2, 2);
mk_nt_q_r!(mk_nt_q_3, 3);
mk_nt_q_r!(mk_nt_q_4, 4);
mk_nt_q_r!(mk_nt_q_5, 5);
mk_nt_q_r!(mk_nt_q_6, 6);

/// Row-count dispatcher for the int8 NT microkernel (`rows ∈ 1..=6`).
///
/// # Safety
/// See the per-kernel contract in [`mk_nt_q_r`].
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
pub unsafe fn mk_nt_q(
    rows: usize,
    a: *const f32,
    lda: usize,
    pack: *const i8,
    kw: usize,
    scales: *const f32,
    c: *mut f32,
    ldc: usize,
    jw: usize,
) {
    match rows {
        1 => mk_nt_q_1(a, lda, pack, kw, scales, c, ldc, jw),
        2 => mk_nt_q_2(a, lda, pack, kw, scales, c, ldc, jw),
        3 => mk_nt_q_3(a, lda, pack, kw, scales, c, ldc, jw),
        4 => mk_nt_q_4(a, lda, pack, kw, scales, c, ldc, jw),
        5 => mk_nt_q_5(a, lda, pack, kw, scales, c, ldc, jw),
        6 => mk_nt_q_6(a, lda, pack, kw, scales, c, ldc, jw),
        _ => unreachable!("mk_nt_q rows must be 1..=6"),
    }
}

// ------------------------------------------------- GEMM NN microkernels
// C-tile (R x 16) += A-rows x B-strip, B streamed row-major at stride ldb
// (each k step loads B[k][j..j+16] contiguously — no packing needed except
// for ragged column tails, where the driver passes a zero-padded ldb=16
// scratch so the 16-float loads stay in bounds).

macro_rules! mk_nn_r {
    ($name:ident, $rows:expr) => {
        /// # Safety
        /// avx2+fma verified; `a`: `$rows` rows of ≥ `kw` floats at stride
        /// `lda`; `b`: `kw` rows of ≥ 16 readable floats at stride `ldb`;
        /// `c`: `$rows` rows of ≥ `jw` floats at stride `ldc`; `jw ≤ 16`.
        #[target_feature(enable = "avx2,fma")]
        unsafe fn $name(
            a: *const f32,
            lda: usize,
            b: *const f32,
            ldb: usize,
            kw: usize,
            c: *mut f32,
            ldc: usize,
            jw: usize,
        ) {
            let mut acc = [[_mm256_setzero_ps(); 2]; $rows];
            for kk in 0..kw {
                let br = b.add(kk * ldb);
                let b0 = _mm256_loadu_ps(br);
                let b1 = _mm256_loadu_ps(br.add(8));
                for r in 0..$rows {
                    let av = _mm256_broadcast_ss(&*a.add(r * lda + kk));
                    acc[r][0] = _mm256_fmadd_ps(av, b0, acc[r][0]);
                    acc[r][1] = _mm256_fmadd_ps(av, b1, acc[r][1]);
                }
            }
            if jw == 16 {
                for r in 0..$rows {
                    let cr = c.add(r * ldc);
                    _mm256_storeu_ps(cr, _mm256_add_ps(_mm256_loadu_ps(cr), acc[r][0]));
                    let cr8 = cr.add(8);
                    _mm256_storeu_ps(cr8, _mm256_add_ps(_mm256_loadu_ps(cr8), acc[r][1]));
                }
            } else {
                let mut tmp = [0.0f32; 16];
                for r in 0..$rows {
                    _mm256_storeu_ps(tmp.as_mut_ptr(), acc[r][0]);
                    _mm256_storeu_ps(tmp.as_mut_ptr().add(8), acc[r][1]);
                    let cr = c.add(r * ldc);
                    for (j, t) in tmp.iter().enumerate().take(jw) {
                        *cr.add(j) += t;
                    }
                }
            }
        }
    };
}

mk_nn_r!(mk_nn_1, 1);
mk_nn_r!(mk_nn_2, 2);
mk_nn_r!(mk_nn_3, 3);
mk_nn_r!(mk_nn_4, 4);

/// Row-count dispatcher for the NN microkernel (`rows ∈ 1..=4`).
///
/// # Safety
/// See the per-kernel contract in [`mk_nn_r`].
#[target_feature(enable = "avx2,fma")]
pub unsafe fn mk_nn(
    rows: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    kw: usize,
    c: *mut f32,
    ldc: usize,
    jw: usize,
) {
    match rows {
        1 => mk_nn_1(a, lda, b, ldb, kw, c, ldc, jw),
        2 => mk_nn_2(a, lda, b, ldb, kw, c, ldc, jw),
        3 => mk_nn_3(a, lda, b, ldb, kw, c, ldc, jw),
        4 => mk_nn_4(a, lda, b, ldb, kw, c, ldc, jw),
        _ => unreachable!("mk_nn rows must be 1..=4"),
    }
}

// --------------------------------------- int8 dequant-fused NN microkernels
// B is streamed as int8 rows (stride `ldb` codes) with ONE scale per k step
// (B rows are the quantization rows here), broadcast and multiplied after
// the register conversion — again one rounding, bitwise equal to a
// materialized dequant feeding mk_nn. Ragged tails via masked stores.

macro_rules! mk_nn_q_r {
    ($name:ident, $rows:expr) => {
        /// # Safety
        /// avx2+fma verified; `a`: `$rows` rows of ≥ `kw` floats at stride
        /// `lda`; `b`: `kw` rows of ≥ 16 readable int8 codes at stride
        /// `ldb`; `scales`: `kw` readable f32 (per B-row); `c`: `$rows`
        /// rows of ≥ `jw` floats at stride `ldc`; `jw ≤ 16`.
        #[target_feature(enable = "avx2,fma")]
        unsafe fn $name(
            a: *const f32,
            lda: usize,
            b: *const i8,
            ldb: usize,
            scales: *const f32,
            kw: usize,
            c: *mut f32,
            ldc: usize,
            jw: usize,
        ) {
            let mut acc = [[_mm256_setzero_ps(); 2]; $rows];
            for kk in 0..kw {
                let sv = _mm256_broadcast_ss(&*scales.add(kk));
                let (b0, b1) = dequant16(b.add(kk * ldb), sv, sv);
                for r in 0..$rows {
                    let av = _mm256_broadcast_ss(&*a.add(r * lda + kk));
                    acc[r][0] = _mm256_fmadd_ps(av, b0, acc[r][0]);
                    acc[r][1] = _mm256_fmadd_ps(av, b1, acc[r][1]);
                }
            }
            if jw == 16 {
                for r in 0..$rows {
                    let cr = c.add(r * ldc);
                    _mm256_storeu_ps(cr, _mm256_add_ps(_mm256_loadu_ps(cr), acc[r][0]));
                    let cr8 = cr.add(8);
                    _mm256_storeu_ps(cr8, _mm256_add_ps(_mm256_loadu_ps(cr8), acc[r][1]));
                }
            } else {
                let (m0, m1) = tail_masks(jw);
                for r in 0..$rows {
                    let cr = c.add(r * ldc);
                    let cur0 = _mm256_maskload_ps(cr, m0);
                    _mm256_maskstore_ps(cr, m0, _mm256_add_ps(cur0, acc[r][0]));
                    let cr8 = cr.add(8);
                    let cur1 = _mm256_maskload_ps(cr8, m1);
                    _mm256_maskstore_ps(cr8, m1, _mm256_add_ps(cur1, acc[r][1]));
                }
            }
        }
    };
}

mk_nn_q_r!(mk_nn_q_1, 1);
mk_nn_q_r!(mk_nn_q_2, 2);
mk_nn_q_r!(mk_nn_q_3, 3);
mk_nn_q_r!(mk_nn_q_4, 4);

/// Row-count dispatcher for the int8 NN microkernel (`rows ∈ 1..=4`).
///
/// # Safety
/// See the per-kernel contract in [`mk_nn_q_r`].
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
pub unsafe fn mk_nn_q(
    rows: usize,
    a: *const f32,
    lda: usize,
    b: *const i8,
    ldb: usize,
    scales: *const f32,
    kw: usize,
    c: *mut f32,
    ldc: usize,
    jw: usize,
) {
    match rows {
        1 => mk_nn_q_1(a, lda, b, ldb, scales, kw, c, ldc, jw),
        2 => mk_nn_q_2(a, lda, b, ldb, scales, kw, c, ldc, jw),
        3 => mk_nn_q_3(a, lda, b, ldb, scales, kw, c, ldc, jw),
        4 => mk_nn_q_4(a, lda, b, ldb, scales, kw, c, ldc, jw),
        _ => unreachable!("mk_nn_q rows must be 1..=4"),
    }
}

// ----------------------------------------------------------------- GEMM TN

/// One broadcast-axpy row pass of the TN kernel: `out[0..n] += av * b[0..n]`
/// with FMA, vector body + scalar tail (tail position depends only on `n`).
///
/// # Safety
/// avx2+fma verified; both slices have length `n`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn tn_axpy(out: &mut [f32], av: f32, b: &[f32]) {
    let n = out.len();
    debug_assert_eq!(b.len(), n);
    let v = _mm256_set1_ps(av);
    let body = n - n % 8;
    let mut j = 0;
    while j < body {
        let o = out.as_mut_ptr().add(j);
        _mm256_storeu_ps(o, _mm256_fmadd_ps(v, _mm256_loadu_ps(b.as_ptr().add(j)), _mm256_loadu_ps(o)));
        j += 8;
    }
    for j in body..n {
        let bj = *b.get_unchecked(j);
        let o = out.get_unchecked_mut(j);
        *o = av.mul_add(bj, *o);
    }
}

// ---------------------------------------------------------------- CSR SpMM

/// `out[b][r] += Σ_i v_i · x[b][col_i]` for a tile of ≤8 batch rows,
/// gather-free: `xt` is the transposed activation panel (`k` cols × 8
/// lanes, lane-major, lanes ≥ `bw` zero-padded), so every nonzero turns
/// into one broadcast + one contiguous 8-lane FMA. Per (b, r) order: strict
/// CSR index order, one final add into `out` — lane position does not
/// affect the computed value.
///
/// # Safety
/// avx2+fma verified; `row_ptr/col_idx/values` form a valid CSR with
/// `n_rows` rows and column indices < the panel's k extent; `xt` holds
/// `k*8` floats; `out` element `(lane, r)` at `out + lane*ldo + r` is
/// writable for `lane < bw`, `r < n_rows`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn spmm_nt_tile(
    row_ptr: &[u32],
    col_idx: &[u32],
    values: &[f32],
    xt: *const f32,
    out: *mut f32,
    ldo: usize,
    bw: usize,
    n_rows: usize,
) {
    for r in 0..n_rows {
        let lo = *row_ptr.get_unchecked(r) as usize;
        let hi = *row_ptr.get_unchecked(r + 1) as usize;
        if lo == hi {
            continue;
        }
        let mut acc = _mm256_setzero_ps();
        for i in lo..hi {
            let v = _mm256_broadcast_ss(values.get_unchecked(i));
            let c = *col_idx.get_unchecked(i) as usize;
            acc = _mm256_fmadd_ps(v, _mm256_loadu_ps(xt.add(c * 8)), acc);
        }
        let mut tmp = [0.0f32; 8];
        _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
        for (lane, t) in tmp.iter().enumerate().take(bw) {
            *out.add(lane * ldo + r) += t;
        }
    }
}

/// Down-projection correction tile: `outt[c][lane] += h[lane][r] · v` over
/// the CSR in (r, i) order, vectorized across ≤8 batch lanes. `ht` is the
/// transposed h panel (rows lane-major ×8), `outt` a zeroed p×8 lane-major
/// accumulation panel the driver transposes back. Rows whose 8 h-lanes are
/// all exactly 0.0 are skipped — a value-preserving shortcut (+0-started
/// accumulators are unchanged by ±0 contributions).
///
/// # Safety
/// avx2+fma verified; valid CSR (`n_rows` rows, col indices < p); `ht`
/// holds `n_rows*8` floats; `outt` holds `p*8` floats.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn spmm_acc_tile(
    row_ptr: &[u32],
    col_idx: &[u32],
    values: &[f32],
    ht: *const f32,
    outt: *mut f32,
    n_rows: usize,
) {
    let zero = _mm256_setzero_ps();
    for r in 0..n_rows {
        let hv = _mm256_loadu_ps(ht.add(r * 8));
        if _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_EQ_OQ>(hv, zero)) == 0xff {
            continue;
        }
        let lo = *row_ptr.get_unchecked(r) as usize;
        let hi = *row_ptr.get_unchecked(r + 1) as usize;
        for i in lo..hi {
            let v = _mm256_broadcast_ss(values.get_unchecked(i));
            let c = *col_idx.get_unchecked(i) as usize * 8;
            let o = _mm256_loadu_ps(outt.add(c));
            _mm256_storeu_ps(outt.add(c), _mm256_fmadd_ps(v, hv, o));
        }
    }
}

/// Int8 twin of [`spmm_nt_tile`]: each nonzero dequantizes as
/// `code as f32 * scale_r` in scalar registers (the same single rounding as
/// a materialized dequant) before the identical broadcast-FMA fold, so the
/// fused SpMM is bitwise equal to `to_csr()` + [`spmm_nt_tile`].
///
/// # Safety
/// As [`spmm_nt_tile`], with `values` int8 and `scales` holding `n_rows`
/// readable f32 (per CSR row).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
pub unsafe fn spmm_nt_q_tile(
    row_ptr: &[u32],
    col_idx: &[u32],
    values: &[i8],
    scales: &[f32],
    xt: *const f32,
    out: *mut f32,
    ldo: usize,
    bw: usize,
    n_rows: usize,
) {
    for r in 0..n_rows {
        let lo = *row_ptr.get_unchecked(r) as usize;
        let hi = *row_ptr.get_unchecked(r + 1) as usize;
        if lo == hi {
            continue;
        }
        let s = *scales.get_unchecked(r);
        let mut acc = _mm256_setzero_ps();
        for i in lo..hi {
            let v = _mm256_set1_ps(*values.get_unchecked(i) as f32 * s);
            let c = *col_idx.get_unchecked(i) as usize;
            acc = _mm256_fmadd_ps(v, _mm256_loadu_ps(xt.add(c * 8)), acc);
        }
        let mut tmp = [0.0f32; 8];
        _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
        for (lane, t) in tmp.iter().enumerate().take(bw) {
            *out.add(lane * ldo + r) += t;
        }
    }
}

/// Int8 twin of [`spmm_acc_tile`] (same scalar-register dequant, same
/// all-zero-h-lane skip — the skip predicate reads only `h`).
///
/// # Safety
/// As [`spmm_acc_tile`], with `values` int8 and `scales` holding `n_rows`
/// readable f32.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn spmm_acc_q_tile(
    row_ptr: &[u32],
    col_idx: &[u32],
    values: &[i8],
    scales: &[f32],
    ht: *const f32,
    outt: *mut f32,
    n_rows: usize,
) {
    let zero = _mm256_setzero_ps();
    for r in 0..n_rows {
        let hv = _mm256_loadu_ps(ht.add(r * 8));
        if _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_EQ_OQ>(hv, zero)) == 0xff {
            continue;
        }
        let s = *scales.get_unchecked(r);
        let lo = *row_ptr.get_unchecked(r) as usize;
        let hi = *row_ptr.get_unchecked(r + 1) as usize;
        for i in lo..hi {
            let v = _mm256_set1_ps(*values.get_unchecked(i) as f32 * s);
            let c = *col_idx.get_unchecked(i) as usize * 8;
            let o = _mm256_loadu_ps(outt.add(c));
            _mm256_storeu_ps(outt.add(c), _mm256_fmadd_ps(v, hv, o));
        }
    }
}

// ------------------------------------------------------------- elementwise

/// `h[j] = silu(h[j]) * g[j]` — the SwiGLU combine. Ragged tails run
/// through the same `vexp` on a zero-padded temporary, so every column
/// position sees identical arithmetic regardless of row count.
///
/// # Safety
/// avx2+fma verified; `h.len() == g.len()`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn silu_mul_row(h: &mut [f32], g: &[f32]) {
    debug_assert_eq!(h.len(), g.len());
    let n = h.len();
    let one = _mm256_set1_ps(1.0);
    let nsign = _mm256_set1_ps(-0.0);
    let body = n - n % 8;
    let mut j = 0;
    while j < body {
        let x = _mm256_loadu_ps(h.as_ptr().add(j));
        let e = vexp(_mm256_xor_ps(x, nsign));
        let s = _mm256_div_ps(x, _mm256_add_ps(one, e));
        let y = _mm256_mul_ps(s, _mm256_loadu_ps(g.as_ptr().add(j)));
        _mm256_storeu_ps(h.as_mut_ptr().add(j), y);
        j += 8;
    }
    if body < n {
        let rem = n - body;
        let mut hx = [0.0f32; 8];
        let mut gx = [0.0f32; 8];
        hx[..rem].copy_from_slice(&h[body..]);
        gx[..rem].copy_from_slice(&g[body..]);
        let x = _mm256_loadu_ps(hx.as_ptr());
        let e = vexp(_mm256_xor_ps(x, nsign));
        let s = _mm256_div_ps(x, _mm256_add_ps(one, e));
        let y = _mm256_mul_ps(s, _mm256_loadu_ps(gx.as_ptr()));
        _mm256_storeu_ps(hx.as_mut_ptr(), y);
        h[body..].copy_from_slice(&hx[..rem]);
    }
}

/// In-place ReLU (bitwise identical to the scalar `v.max(0.0)`).
///
/// # Safety
/// avx2+fma verified.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn relu_inplace(h: &mut [f32]) {
    let n = h.len();
    let zero = _mm256_setzero_ps();
    let body = n - n % 8;
    let mut j = 0;
    while j < body {
        let p = h.as_mut_ptr().add(j);
        _mm256_storeu_ps(p, _mm256_max_ps(_mm256_loadu_ps(p), zero));
        j += 8;
    }
    for v in &mut h[body..] {
        *v = v.max(0.0);
    }
}

/// In-place softmax: exact max subtraction, `vexp` body (padded-tail
/// variant for ragged lengths), lane-split sum reduced in fixed order,
/// multiply by the reciprocal.
///
/// # Safety
/// avx2+fma verified.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn softmax_inplace(xs: &mut [f32]) {
    let n = xs.len();
    if n == 0 {
        return;
    }
    let body = n - n % 8;
    let mut vmax = _mm256_set1_ps(f32::NEG_INFINITY);
    let mut j = 0;
    while j < body {
        vmax = _mm256_max_ps(vmax, _mm256_loadu_ps(xs.as_ptr().add(j)));
        j += 8;
    }
    let mut m = hmax(vmax);
    for &v in &xs[body..] {
        m = m.max(v);
    }
    let vm = _mm256_set1_ps(m);
    let mut vsum = _mm256_setzero_ps();
    j = 0;
    while j < body {
        let e = vexp(_mm256_sub_ps(_mm256_loadu_ps(xs.as_ptr().add(j)), vm));
        _mm256_storeu_ps(xs.as_mut_ptr().add(j), e);
        vsum = _mm256_add_ps(vsum, e);
        j += 8;
    }
    let mut sum = hsum(vsum);
    if body < n {
        let rem = n - body;
        let mut tx = [0.0f32; 8];
        tx[..rem].copy_from_slice(&xs[body..]);
        let e = vexp(_mm256_sub_ps(_mm256_loadu_ps(tx.as_ptr()), vm));
        _mm256_storeu_ps(tx.as_mut_ptr(), e);
        xs[body..].copy_from_slice(&tx[..rem]);
        for &t in &tx[..rem] {
            sum += t;
        }
    }
    let inv = 1.0 / sum;
    let vinv = _mm256_set1_ps(inv);
    j = 0;
    while j < body {
        let p = xs.as_mut_ptr().add(j);
        _mm256_storeu_ps(p, _mm256_mul_ps(_mm256_loadu_ps(p), vinv));
        j += 8;
    }
    for v in &mut xs[body..] {
        *v *= inv;
    }
}

/// RMS-norm one row: lane-split sum of squares (fixed reduction order),
/// scalar `inv`, then `out[j] = x[j] * inv * g[j]` with the same two
/// roundings as the scalar twin.
///
/// # Safety
/// avx2+fma verified; all slices have equal length.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn rmsnorm_row(x: &[f32], gain: &[f32], out: &mut [f32], eps: f32) {
    let n = x.len();
    debug_assert!(gain.len() == n && out.len() == n);
    let body = n - n % 8;
    let mut vsum = _mm256_setzero_ps();
    let mut j = 0;
    while j < body {
        let v = _mm256_loadu_ps(x.as_ptr().add(j));
        vsum = _mm256_fmadd_ps(v, v, vsum);
        j += 8;
    }
    let mut ss = hsum(vsum);
    for &v in &x[body..] {
        ss = v.mul_add(v, ss);
    }
    let inv = 1.0 / (ss / n as f32 + eps).sqrt();
    let vinv = _mm256_set1_ps(inv);
    j = 0;
    while j < body {
        let v = _mm256_mul_ps(_mm256_loadu_ps(x.as_ptr().add(j)), vinv);
        let o = _mm256_mul_ps(v, _mm256_loadu_ps(gain.as_ptr().add(j)));
        _mm256_storeu_ps(out.as_mut_ptr().add(j), o);
        j += 8;
    }
    for jj in body..n {
        out[jj] = x[jj] * inv * gain[jj];
    }
}

/// Dot product: FMA lanes over the body, fixed-order horizontal sum, then
/// scalar tail terms appended in index order.
///
/// # Safety
/// avx2+fma verified; `a.len() == b.len()`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    debug_assert_eq!(b.len(), n);
    let body = n - n % 8;
    let mut acc = _mm256_setzero_ps();
    let mut j = 0;
    while j < body {
        acc = _mm256_fmadd_ps(
            _mm256_loadu_ps(a.as_ptr().add(j)),
            _mm256_loadu_ps(b.as_ptr().add(j)),
            acc,
        );
        j += 8;
    }
    let mut s = hsum(acc);
    for jj in body..n {
        s = a[jj].mul_add(b[jj], s);
    }
    s
}

/// `dst[j] += a * src[j]`, deliberately NON-fused (separate mul + add) so
/// the result is bitwise identical to the scalar twin — the dispatchers
/// rely on that for the combine/bias tier (see `tensor/kernel.rs`).
///
/// # Safety
/// avx2+fma verified; `dst.len() == src.len()`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn axpy_row(dst: &mut [f32], a: f32, src: &[f32]) {
    let n = dst.len();
    debug_assert_eq!(src.len(), n);
    let va = _mm256_set1_ps(a);
    let body = n - n % 8;
    let mut j = 0;
    while j < body {
        let p = dst.as_mut_ptr().add(j);
        let prod = _mm256_mul_ps(va, _mm256_loadu_ps(src.as_ptr().add(j)));
        _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), prod));
        j += 8;
    }
    for jj in body..n {
        dst[jj] += a * src[jj];
    }
}

/// `dst[j] += src[j]` (bitwise identical to scalar).
///
/// # Safety
/// avx2+fma verified; equal lengths.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn add_row(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    debug_assert_eq!(src.len(), n);
    let body = n - n % 8;
    let mut j = 0;
    while j < body {
        let p = dst.as_mut_ptr().add(j);
        _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), _mm256_loadu_ps(src.as_ptr().add(j))));
        j += 8;
    }
    for jj in body..n {
        dst[jj] += src[jj];
    }
}

/// `dst[j] *= src[j]` (bitwise identical to scalar).
///
/// # Safety
/// avx2+fma verified; equal lengths.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn mul_row(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    debug_assert_eq!(src.len(), n);
    let body = n - n % 8;
    let mut j = 0;
    while j < body {
        let p = dst.as_mut_ptr().add(j);
        _mm256_storeu_ps(p, _mm256_mul_ps(_mm256_loadu_ps(p), _mm256_loadu_ps(src.as_ptr().add(j))));
        j += 8;
    }
    for jj in body..n {
        dst[jj] *= src[jj];
    }
}
