//! 32-byte-aligned `f32` storage for [`Matrix`](super::Matrix) backing
//! buffers — the alignment contract of the SIMD microkernel layer
//! (`tensor/kernel.rs` / `tensor/simd.rs`).
//!
//! Implemented as a **padded `Vec<f32>` with an alignment offset** (no
//! unsafe allocation tricks): the buffer over-allocates by up to 7 floats
//! and exposes only the aligned tail. Every constructor and every growth
//! path re-derives the offset from the (possibly moved) allocation, so the
//! invariant `self.as_ptr() as usize % 32 == 0` holds for any non-empty
//! payload. Rust never moves the base pointer without going through one of
//! the guarded growth paths here (plain `Vec::push` only reallocates when
//! `len == capacity`, which [`AVec::push`] pre-empts).
//!
//! The kernels themselves use unaligned load/store instructions (same
//! throughput on every AVX2 part when the address is in fact aligned), so
//! the contract is about cache-line behaviour and future `load_ps`
//! eligibility, not faults — see README §Kernels.

use std::fmt;
use std::ops::{Deref, DerefMut};

/// Byte alignment of the payload base pointer.
pub const ALIGN: usize = 32;
/// Worst-case extra `f32` slots needed to reach a 32-byte boundary from a
/// 4-byte-aligned allocation.
const PAD: usize = ALIGN / 4 - 1;

/// Offset (in `f32` elements) from `ptr` to the next 32-byte boundary.
fn align_offset(ptr: *const f32) -> usize {
    let rem = ptr as usize % ALIGN;
    if rem == 0 {
        0
    } else {
        debug_assert_eq!(rem % 4, 0, "Vec<f32> allocation must be 4-byte aligned");
        (ALIGN - rem) / 4
    }
}

/// A `Vec<f32>` whose payload base is 32-byte aligned.
///
/// Derefs to `[f32]`, so slice reads/writes, iteration, and indexing all
/// look exactly like the plain `Vec` it replaced inside [`super::Matrix`].
#[derive(Default)]
pub struct AVec {
    /// Raw storage; the payload is `buf[off..]`.
    buf: Vec<f32>,
    off: usize,
}

impl AVec {
    /// Aligned zero-filled vector of length `n`.
    pub fn zeroed(n: usize) -> AVec {
        let mut buf = vec![0.0f32; n + PAD];
        let off = align_offset(buf.as_ptr());
        buf.truncate(off + n);
        AVec { buf, off }
    }

    /// Aligned empty vector able to hold `n` elements without reallocating.
    pub fn with_capacity(n: usize) -> AVec {
        let mut buf = Vec::with_capacity(n + PAD);
        let off = align_offset(buf.as_ptr());
        buf.resize(off, 0.0);
        AVec { buf, off }
    }

    /// Take ownership of `v`, re-copying into aligned storage only when the
    /// allocation happens to be misaligned (single write — no zero-fill
    /// pass — since collect-based `Matrix` constructors funnel through
    /// here).
    pub fn from_vec(v: Vec<f32>) -> AVec {
        if align_offset(v.as_ptr()) == 0 {
            return AVec { buf: v, off: 0 };
        }
        let mut out = AVec::with_capacity(v.len());
        out.buf.extend_from_slice(&v);
        out
    }

    /// Move the payload out as a plain `Vec` without copying the payload
    /// itself (the sub-32B alignment prefix is drained in place).
    pub fn into_vec(mut self) -> Vec<f32> {
        if self.off > 0 {
            self.buf.drain(..self.off);
        }
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len() - self.off
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy the payload out as a plain `Vec` (serialization / interop).
    pub fn to_vec(&self) -> Vec<f32> {
        self[..].to_vec()
    }

    pub fn push(&mut self, v: f32) {
        if self.buf.len() == self.buf.capacity() {
            self.grow(1);
        }
        // Cannot reallocate: capacity strictly exceeds length here.
        self.buf.push(v);
    }

    pub fn extend_from_slice(&mut self, s: &[f32]) {
        if self.buf.len() + s.len() > self.buf.capacity() {
            self.grow(s.len());
        }
        self.buf.extend_from_slice(s);
    }

    pub fn clear(&mut self) {
        self.buf.truncate(self.off);
    }

    /// Rebuild into a fresh allocation with room for `extra` more elements,
    /// re-deriving the alignment offset (reallocation moves the base).
    fn grow(&mut self, extra: usize) {
        let need = self.len() + extra;
        let cap = need.max(self.len() * 2).max(8);
        let mut buf = Vec::with_capacity(cap + PAD);
        let off = align_offset(buf.as_ptr());
        buf.resize(off, 0.0);
        buf.extend_from_slice(self);
        self.buf = buf;
        self.off = off;
    }
}

impl Deref for AVec {
    type Target = [f32];
    #[inline]
    fn deref(&self) -> &[f32] {
        &self.buf[self.off..]
    }
}

impl DerefMut for AVec {
    #[inline]
    fn deref_mut(&mut self) -> &mut [f32] {
        let off = self.off;
        &mut self.buf[off..]
    }
}

impl Clone for AVec {
    fn clone(&self) -> AVec {
        let mut out = AVec::zeroed(self.len());
        out.copy_from_slice(self);
        out
    }
}

impl fmt::Debug for AVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self[..], f)
    }
}

impl PartialEq for AVec {
    fn eq(&self, other: &AVec) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<Vec<f32>> for AVec {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<AVec> for Vec<f32> {
    fn eq(&self, other: &AVec) -> bool {
        self[..] == other[..]
    }
}

impl From<Vec<f32>> for AVec {
    fn from(v: Vec<f32>) -> AVec {
        AVec::from_vec(v)
    }
}

impl FromIterator<f32> for AVec {
    fn from_iter<I: IntoIterator<Item = f32>>(iter: I) -> AVec {
        AVec::from_vec(iter.into_iter().collect())
    }
}

impl<'a> IntoIterator for &'a AVec {
    type Item = &'a f32;
    type IntoIter = std::slice::Iter<'a, f32>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

impl<'a> IntoIterator for &'a mut AVec {
    type Item = &'a mut f32;
    type IntoIter = std::slice::IterMut<'a, f32>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_aligned(v: &AVec) {
        assert_eq!(v.as_ptr() as usize % ALIGN, 0, "payload base must be 32B-aligned");
    }

    #[test]
    fn constructors_are_aligned() {
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let z = AVec::zeroed(n);
            assert_eq!(z.len(), n);
            assert_aligned(&z);
            assert!(z.iter().all(|&x| x == 0.0));
            let f = AVec::from_vec((0..n).map(|i| i as f32).collect());
            assert_eq!(f.len(), n);
            assert_aligned(&f);
            for (i, &x) in f.iter().enumerate() {
                assert_eq!(x, i as f32);
            }
        }
    }

    #[test]
    fn growth_preserves_alignment_and_content() {
        let mut v = AVec::with_capacity(4);
        assert_aligned(&v);
        for i in 0..1000 {
            v.push(i as f32);
            assert_aligned(&v);
        }
        assert_eq!(v.len(), 1000);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as f32);
        }
        v.extend_from_slice(&[1.0; 123]);
        assert_aligned(&v);
        assert_eq!(v.len(), 1123);
        assert_eq!(v[1122], 1.0);
    }

    #[test]
    fn into_vec_roundtrips() {
        for n in [0usize, 3, 9, 64] {
            let want: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let v = AVec::from_vec(want.clone());
            assert_eq!(v.into_vec(), want);
        }
    }

    #[test]
    fn clone_and_eq() {
        let a = AVec::from_vec(vec![1.0, 2.0, 3.0]);
        let b = a.clone();
        assert_aligned(&b);
        assert_eq!(a, b);
        assert_eq!(a, vec![1.0, 2.0, 3.0]);
        assert_ne!(a, AVec::zeroed(3));
    }

    #[test]
    fn slice_ops_work_through_deref() {
        let mut v = AVec::zeroed(10);
        v.fill(2.0);
        v[3] = 5.0;
        assert_eq!(v.iter().sum::<f32>(), 2.0 * 9.0 + 5.0);
        let mut it = 0;
        for x in &v {
            it += (*x > 0.0) as usize;
        }
        assert_eq!(it, 10);
        for x in &mut v {
            *x *= 2.0;
        }
        assert_eq!(v[3], 10.0);
        v.clear();
        assert!(v.is_empty());
    }
}
