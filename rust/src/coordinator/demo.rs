//! End-to-end serving demo used by `resmoe serve` and
//! `examples/serving_demo.rs`: compress the model, stand up the server with
//! a bounded restore cache, fire a mixed workload from client threads, and
//! report throughput/latency plus the memory story.

use super::server::{Engine, Request, Response, Server, ServerConfig};
use crate::compress::{compress_model, ResMoE};
use crate::eval::Assets;
use crate::util::{format_bytes, Rng};
use anyhow::Result;

pub fn run_demo(assets: &Assets, cfg: ServerConfig, n_requests: usize) -> Result<()> {
    let model = &assets.model;
    let moe_blocks = model.moe_blocks().len();
    let top = (moe_blocks * 3).div_ceil(4);
    let mut rng = Rng::new(0);
    println!(
        "serving {} ({}) — compressing top {top} MoE layers with resmoe-up @ 25 %",
        model.cfg.name,
        if assets.pretrained { "pretrained" } else { "random fallback" }
    );
    let cm = compress_model(model, &ResMoE::up(), 0.25, top, None, &mut rng);
    let full_expert_bytes: usize = cm.report.total_bytes_before();
    let engine = Engine::compressed(model.clone(), cm.layers, cfg.cache_budget_bytes);
    let (compressed_bytes, _) = engine.resident_expert_bytes().unwrap();
    println!(
        "  resident compressed experts: {} (dense originals: {}); restore-cache budget {}",
        format_bytes(compressed_bytes),
        format_bytes(full_expert_bytes),
        format_bytes(cfg.cache_budget_bytes),
    );
    let server = Server::start(engine.clone(), cfg);
    // Mixed workload from 4 client threads.
    let lang = assets.language.clone();
    let max_seq = model.cfg.max_seq;
    let replies: Vec<_> = std::thread::scope(|scope| {
        let server = &server;
        let mut handles = Vec::new();
        for c in 0..4usize {
            let lang = lang.clone();
            handles.push(scope.spawn(move || {
                let mut rng = Rng::new(100 + c as u64);
                let mut out = Vec::new();
                for i in 0..n_requests / 4 {
                    let tokens = lang.generate(16 + rng.below(max_seq / 2), &mut rng);
                    let req = match i % 3 {
                        0 => Request::Score { tokens },
                        1 => Request::Generate {
                            prompt: tokens[..8.min(tokens.len())].to_vec(),
                            max_new: 8,
                        },
                        _ => Request::Score { tokens },
                    };
                    out.push(server.submit(req));
                }
                out
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let mut errors = 0usize;
    for r in &replies {
        let (resp, _) = r.recv().expect("reply");
        if matches!(resp, Response::Error(_)) {
            errors += 1;
        }
    }
    let metrics = server.shutdown();
    println!("  {}", metrics.summary());
    if let Some(cm) = engine.cache_metrics() {
        println!(
            "  restore cache: {:.1} % hit rate, {} restores ({:.2} ms total restore time), {} evictions",
            cm.hit_rate() * 100.0,
            cm.misses,
            cm.restore_ns as f64 / 1e6,
            cm.evictions
        );
    }
    if let Some((cb, used)) = engine.resident_expert_bytes() {
        println!(
            "  steady-state expert memory: {} compressed + {} cache = {} (dense: {})",
            format_bytes(cb),
            format_bytes(used),
            format_bytes(cb + used),
            format_bytes(full_expert_bytes)
        );
    }
    anyhow::ensure!(errors == 0, "{errors} requests failed");
    Ok(())
}
