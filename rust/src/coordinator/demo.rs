//! End-to-end serving demo used by `resmoe serve` and
//! `examples/serving_demo.rs`: compress the model, stand up the server with
//! a bounded restore cache, fire a mixed workload from client threads, and
//! report throughput/latency plus the memory story.

use super::metrics::{batch_summary, cache_summary, ServerMetrics};
use super::server::{Engine, Request, Response, Server, ServerConfig};
use crate::compress::{compress_model, ResMoE};
use crate::eval::Assets;
use crate::obs::trace;
use crate::util::json::Json;
use crate::util::{format_bytes, Rng};
use anyhow::{Context, Result};
use std::path::Path;

/// Fire `n_requests` at the server from 4 client threads (remainder spread
/// so every requested count is served), drain the replies, and return the
/// error count. `make(client, i, rng)` builds each request.
fn fire_workload<F>(server: &Server, n_requests: usize, seed_base: u64, make: F) -> usize
where
    F: Fn(usize, usize, &mut Rng) -> Request + Sync,
{
    let replies: Vec<_> = std::thread::scope(|scope| {
        let make = &make;
        let mut handles = Vec::new();
        for c in 0..4usize {
            let quota = n_requests / 4 + usize::from(c < n_requests % 4);
            handles.push(scope.spawn(move || {
                let mut rng = Rng::new(seed_base + c as u64);
                (0..quota).map(|i| server.submit(make(c, i, &mut rng))).collect::<Vec<_>>()
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    replies
        .iter()
        .filter(|r| matches!(r.recv().expect("reply").0, Response::Error(_)))
        .count()
}

/// Print the final registry snapshot (both demos end with one) and, when
/// `metrics_out` is set, write the machine-readable form consumed by the
/// ci.sh overhead/SLO gates: headline SLO numbers derived from the server
/// metrics plus the full instrument snapshot.
fn report_observability(
    engine: &Engine,
    metrics: &ServerMetrics,
    metrics_out: Option<&Path>,
) -> Result<()> {
    let snapshot = engine.metrics_snapshot();
    println!("--- final metrics snapshot (prometheus) ---");
    print!("{}", snapshot.to_prometheus());
    if let Some(path) = metrics_out {
        let cm = engine.cache_metrics();
        let doc = Json::obj(vec![
            ("kernel", Json::str(crate::tensor::kernel_label())),
            ("traced", Json::Bool(trace::enabled())),
            ("requests", Json::num(metrics.requests as f64)),
            ("req_s", Json::num(metrics.requests_per_s())),
            ("tok_s", Json::num(metrics.tokens_per_s())),
            ("p50_ms", Json::num(metrics.p50_ms())),
            ("p99_ms", Json::num(metrics.p99_ms())),
            ("hit_rate", Json::num(cm.as_ref().map_or(0.0, |c| c.hit_rate()))),
            (
                "prefetch_useful_rate",
                Json::num(cm.as_ref().map_or(0.0, |c| c.prefetch_usefulness())),
            ),
            ("snapshot", snapshot.to_json()),
        ]);
        std::fs::write(path, format!("{doc}\n"))
            .with_context(|| format!("writing metrics to {}", path.display()))?;
        println!("  metrics written to {}", path.display());
    }
    Ok(())
}

pub fn run_demo(
    assets: &Assets,
    cfg: ServerConfig,
    n_requests: usize,
    metrics_out: Option<&Path>,
) -> Result<()> {
    let model = &assets.model;
    let moe_blocks = model.moe_blocks().len();
    let top = (moe_blocks * 3).div_ceil(4);
    let mut rng = Rng::new(0);
    println!(
        "serving {} ({}) — compressing top {top} MoE layers with resmoe-up @ 25 %",
        model.cfg.name,
        if assets.pretrained { "pretrained" } else { "random fallback" }
    );
    let cm = compress_model(model, &ResMoE::up(), 0.25, top, None, &mut rng);
    let full_expert_bytes: usize = cm.report.total_bytes_before();
    let engine = Engine::compressed(model.clone(), cm.layers, cfg.cache_budget_bytes);
    let (compressed_bytes, _) = engine.resident_expert_bytes().unwrap();
    println!(
        "  resident compressed experts: {} (dense originals: {}); restore-cache budget {}",
        format_bytes(compressed_bytes),
        format_bytes(full_expert_bytes),
        format_bytes(cfg.cache_budget_bytes),
    );
    let server = Server::start(engine.clone(), cfg);
    // Mixed workload from 4 client threads.
    let lang = assets.language.clone();
    let max_seq = model.cfg.max_seq;
    let errors = fire_workload(&server, n_requests, 100, |_, i, rng| {
        let tokens = lang.generate(16 + rng.below(max_seq / 2), rng);
        if i % 3 == 1 {
            Request::Generate { prompt: tokens[..8.min(tokens.len())].to_vec(), max_new: 8 }
        } else {
            Request::Score { tokens }
        }
    });
    let metrics = server.shutdown();
    println!("  {}", metrics.summary());
    println!("  {}", batch_summary(&engine.batch_metrics()));
    if let Some(cm) = engine.cache_metrics() {
        println!(
            "  restore cache: {:.1} % hit rate, {} restores ({:.2} ms total restore time), {} evictions",
            cm.hit_rate() * 100.0,
            cm.misses,
            cm.restore_ns as f64 / 1e6,
            cm.evictions
        );
    }
    if let Some((cb, used)) = engine.resident_expert_bytes() {
        println!(
            "  steady-state expert memory: {} compressed + {} cache = {} (dense: {})",
            format_bytes(cb),
            format_bytes(used),
            format_bytes(cb + used),
            format_bytes(full_expert_bytes)
        );
    }
    report_observability(&engine, &metrics, metrics_out)?;
    anyhow::ensure!(errors == 0, "{errors} requests failed");
    Ok(())
}

/// Serve straight from a packed `RMES` artifact: open the store, load only
/// the backbone + skeletons, and let demand paging + async prefetch bring
/// residual shards in as the workload routes to them. Prints the memory
/// and paging story afterwards — the artifact-mode analog of [`run_demo`].
pub fn run_packed_demo(
    artifact: &Path,
    cfg: ServerConfig,
    n_requests: usize,
    metrics_out: Option<&Path>,
) -> Result<()> {
    let t0 = std::time::Instant::now();
    let engine = Engine::from_store(artifact, cfg.cache_budget_bytes)?;
    let store = engine.backing_store().expect("store-backed engine");
    println!(
        "serving packed artifact {} ({}, {} layers, {} expert shards) — opened in {:.1} ms",
        artifact.display(),
        format_bytes(store.file_bytes() as usize),
        store.blocks().len(),
        store.index().layers.iter().map(|l| l.experts.len()).sum::<usize>(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!(
        "  demand-paged expert bytes: {} (decoded) under a {} cache budget",
        format_bytes(store.total_expert_raw_bytes() as usize),
        format_bytes(cfg.cache_budget_bytes),
    );
    let max_seq = engine.model().cfg.max_seq;
    let vocab = engine.model().cfg.vocab_size;
    let server = Server::start(engine.clone(), cfg);
    let errors = fire_workload(&server, n_requests, 500, |_, i, rng| {
        let len = 8 + rng.below(max_seq / 2);
        let tokens: Vec<u32> = (0..len).map(|_| rng.below(vocab) as u32).collect();
        if i % 3 == 1 {
            Request::Generate { prompt: tokens[..6.min(tokens.len())].to_vec(), max_new: 6 }
        } else {
            Request::Score { tokens }
        }
    });
    let metrics = server.shutdown();
    engine.quiesce_prefetch();
    println!("  {}", metrics.summary());
    println!("  {}", batch_summary(&engine.batch_metrics()));
    if let Some(cm) = engine.cache_metrics() {
        println!("  {}", cache_summary(&cm));
    }
    if let Some((skeleton, dense, paged)) = engine.resident_breakdown() {
        println!(
            "  steady-state expert memory: {} skeletons + {} restored + {} paged shards = {} (full decoded experts: {})",
            format_bytes(skeleton),
            format_bytes(dense),
            format_bytes(paged),
            format_bytes(skeleton + dense + paged),
            format_bytes(store.total_expert_raw_bytes() as usize)
        );
    }
    println!(
        "  artifact I/O: {} of {} read ({:.1} %)",
        format_bytes(store.bytes_read() as usize),
        format_bytes(store.file_bytes() as usize),
        100.0 * store.bytes_read() as f64 / store.file_bytes().max(1) as f64
    );
    report_observability(&engine, &metrics, metrics_out)?;
    anyhow::ensure!(errors == 0, "{errors} requests failed");
    Ok(())
}
